"""Near-real-time training: a ~30M-parameter LM (pass a bigger
config for the ~100M variant; this default finishes on a 1-core CPU box) trained on token batches
produced BY the DOD-ETL pipeline — the BI "report" of this steelworks is a
model. Checkpoints carry the data-plane offsets so a restart resumes the
stream exactly.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.models.model import Model
from repro.optim import AdamWConfig, init_state
from repro.train.checkpoint import CheckpointManager
from repro.train.train_step import make_train_step


def lm_small() -> ModelConfig:
    return ModelConfig(
        arch="etl-lm-small", family="dense", n_layers=6, d_model=384,
        n_heads=6, n_kv_heads=2, d_ff=1536, vocab=4096, microbatches=1,
        remat=False)


def fact_tokenizer(facts: np.ndarray, vocab: int, seq: int, batch: int):
    """Quantize star-schema fact grains into token sequences: each fact
    contributes (equipment, bucketized KPIs) tokens — the stream IS the
    corpus."""
    if len(facts) == 0:
        return None
    cols = facts[:, [0, 3, 4, 5, 6]]
    toks = (np.clip(cols, 0, 1) * 62).astype(np.int64) + \
        np.array([0, 64, 128, 192, 256]) + 1
    flat = toks.reshape(-1) % (vocab - 1) + 1
    need = batch * seq
    reps = int(np.ceil(need / len(flat)))
    flat = np.tile(flat, reps)[:need]
    return flat.reshape(batch, seq)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default="/tmp/etl_lm_ckpt")
    args = ap.parse_args()

    # ---- the data plane: DOD-ETL over the plant stream
    cfg = steelworks_config(n_partitions=8)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(records_per_table=20_000,
                                                   n_equipment=8))
    sampler.generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=2)
    pipe.extract()
    pipe.bootstrap_caches()

    # ---- the model plane
    mcfg = lm_small()
    model = Model(mcfg)
    print(f"model: {sum(x.size for x in jax.tree.leaves(model.abstract())) / 1e6:.1f}M params")
    params = model.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    step_fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)))
    mgr = CheckpointManager(args.ckpt, keep_last=2)

    batch_size, seq = 4, 128
    t0 = time.time()
    fact_backlog = np.zeros((0, 10), np.float32)
    for step in range(1, args.steps + 1):
        # pull freshly transformed facts; the warehouse is the corpus
        if len(fact_backlog) < batch_size * seq // 4:
            pipe.step(max_records_per_partition=512)
            fact_backlog = pipe.warehouse.fact_table()
        tokens = fact_tokenizer(fact_backlog, mcfg.vocab, seq, batch_size)
        batch = {"tokens": jnp.asarray(tokens),
                 "targets": jnp.asarray(np.roll(tokens, -1, 1))}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 25 == 0 or step == 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0) / step:.2f}s/step)")
        if step % 100 == 0:
            mgr.save_async(step, {"params": params, "opt": opt},
                           extra={"stream": pipe.checkpoint()["listener_offsets"]})
    mgr.wait()
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s; "
          f"checkpoints (with stream offsets) in {args.ckpt}")


if __name__ == "__main__":
    main()
