"""Batched serving: prefill a request batch, then decode with a donated KV
cache — the serve-side twin of the dry-run's decode cells.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.models import build_model
from repro.train.serve_step import make_decode_step, make_prefill_step


def main():
    model = build_model("internlm2-1.8b", smoke=True)  # reduced config (CPU)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))

    batch, prompt_len, gen_len, max_len = 4, 48, 16, 64
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)

    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    t0 = time.time()
    next_tok, cache = prefill(params, {"tokens": prompts})
    # grow the cache to max_len (a real server preallocates max_len)
    def grow(c):
        out = {}
        for k, v in c.items():
            if isinstance(v, dict):
                out[k] = grow(v)
            elif k in ("k", "v") and v.ndim >= 3 and v.shape[-3] == prompt_len:
                pad = [(0, 0)] * v.ndim
                pad[-3] = (0, max_len - prompt_len)
                out[k] = jnp.pad(v, pad)
            else:
                out[k] = v
        return out
    cache = grow(cache)
    print(f"prefill: {batch} x {prompt_len} tokens in "
          f"{(time.time() - t0) * 1e3:.0f} ms")

    toks = [next_tok]
    t0 = time.time()
    for i in range(gen_len - 1):
        pos = prompt_len + i
        next_tok, logits, cache = decode(params, cache,
                                         toks[-1][:, None],
                                         jnp.asarray(pos, jnp.int32))
        toks.append(next_tok)
    dt = time.time() - t0
    out = jnp.stack(toks, axis=1)
    print(f"decode: {gen_len - 1} steps x {batch} seqs in {dt * 1e3:.0f} ms "
          f"({batch * (gen_len - 1) / dt:.0f} tok/s on CPU)")
    print("generated token ids (seq 0):", out[0].tolist())


if __name__ == "__main__":
    main()
