"""The paper's case study (§4): OEE reporting for a steelworks, including
the fault-tolerance drill (§4.1.3) and the ISA-95 complex-model comparison
(§4.1.4). The steady-state + failure phases run on the genuinely
concurrent cluster runtime (one executor per worker, live CDC polling,
end-to-end freshness percentiles) with the BI serving layer attached:
shift reports are answered from incrementally maintained materialized
views — O(n_units) per query, snapshot-isolated from the loading workers —
while the cluster is mid-run, each stamped with its report staleness; a
dashboard-refresh burst is then served through the batched query plane
(admission-coalesced, one vectorized gather dispatch per view).

    PYTHONPATH=src python examples/steelworks_etl.py
"""
import dataclasses
import time

import numpy as np

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.runtime.cluster import ConcurrentCluster
from repro.serving import (BatchedReportServer, MaterializedViewEngine,
                           ReportQuery, ReportServer, ReportSnapshot,
                           steelworks_views)


def run_plant(complex_model: bool, join_depth: int, n=8_000):
    cfg = steelworks_config(n_partitions=20, complex_model=complex_model)
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n, n_equipment=20)).generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=5, join_depth=join_depth)
    if complex_model:
        pipe.extract()
        pipe.bootstrap_caches()
    return cfg, pipe


def main():
    # ---- normal operation (simple process-specific model), live cluster
    # with the serving layer folding report views as workers load
    cfg, pipe = run_plant(False, 1)
    engine = MaterializedViewEngine(steelworks_views(20))
    engine.prewarm()
    # warm the fused transform+rollup buckets too, so the steady-state
    # window below shows streaming, not jit compilation
    if pipe.backend.device:
        w0 = pipe.workers[0]
        for size in (128, 256, 512, 1024):
            dummy = np.full((size, 8), -1.0, np.float32)
            pipe.backend.transform_and_rollup(
                dummy, w0.equipment, w0.quality,
                n_units=cfg.n_business_keys).to_host()
    server = ReportServer(engine)
    cluster = ConcurrentCluster(pipe, max_records_per_partition=200,
                                serving=engine)
    cluster.start()
    deadline = time.time() + 30          # wait out jit warm-up, then let
    while (cluster.records_done() < 2000                 # the stream and
           or engine.snapshot().epoch == 0) \
            and time.time() < deadline:                  # the fold cycle
        time.sleep(0.05)                 # reach steady state

    # ---- mid-run shift reports: the cluster is still loading, yet every
    # query reads one pinned epoch (no torn aggregates, no blocking)
    snap = server.snapshot()
    shift = snap.shift_report()
    top = snap.top_downtime(3)
    print(f"mid-run shift report @ epoch {shift.epoch} covering "
          f"{shift.rows} facts, staleness {shift.staleness_ms:.0f} ms")
    print("  worst downtime units: " + ", ".join(
        f"#{u} ({d:.0f}s off)" for u, d in
        zip(top.data['unit'], top.data['downtime_s'])))
    rep = cluster.report()
    sv = rep["serving"]
    print(f"steady state: {rep['records_s']:,.0f} records/s on "
          f"{rep['n_workers']} workers; freshness p50/p95 = "
          f"{rep['p50_ms']:.0f}/{rep['p95_ms']:.0f} ms; report staleness "
          f"p50/p95 = {sv['staleness_p50_ms']:.0f}/"
          f"{sv['staleness_p95_ms']:.0f} ms")

    # ---- one health() call: the unified observability plane. Per-worker
    # load, stage-queue depths, commit lag, freshness/staleness
    # percentiles and the merged counter registry, collected lock-free at
    # one instant — the observation vector an autoscaling controller (or
    # a wallboard) polls while the data plane keeps streaming.
    hp = cluster.health()
    busiest, bw = max(hp["workers"].items(),
                      key=lambda kv: kv[1]["records_done"])
    lag = hp["backlog"]
    c = hp["counters"]
    published = sum(v for k, v in c.items()
                    if k.startswith("broker.") and k.endswith(".published"))
    print(f"health @ {hp['wall_s']:.1f}s: backlog "
          f"{lag['operational_lag']} uncommitted + {lag['buffered']} "
          f"late-buffered; routing epoch {hp['routing_epoch']}; serving "
          f"epoch {hp['serving']['epoch']} "
          f"({hp['serving']['pending_deltas']} deltas pending)")
    print(f"  busiest worker {busiest}: {bw['records_done']} done @ "
          f"{bw['throughput_rps']:,.0f} rps, queues t/l "
          f"{bw['transform_q']}/{bw['load_q']}, "
          f"{bw['cache_rows']} cached master rows, partitions "
          f"{bw['partitions'][:4]}{'...' if len(bw['partitions']) > 4 else ''}")
    print(f"  counters: {published} broker msgs, cache hit/miss "
          f"{c.get('worker.cache_hits', 0)}/"
          f"{c.get('worker.cache_misses', 0)}")

    # ---- §4.1.3 failure drill: two workers die mid-shift, under load
    redump = cluster.fail_workers(["w1", "w3"])
    print(f"2/5 workers failed; partitions reassigned incrementally, "
          f"caches re-dumped in {redump * 1e3:.1f} ms")
    done = cluster.run_until_idle()
    cluster.stop_all()                   # folds the remaining view backlog
    rep = cluster.report()
    sv = rep["serving"]
    print(f"post-failure: {rep['records_s']:,.0f} records/s on "
          f"{rep['n_workers']} workers; stream completed, "
          f"{pipe.warehouse.rows_loaded} facts loaded, zero lost; views "
          f"at epoch {sv['epoch']} cover {sv['rows_folded']} facts")

    # ---- the BI deliverable: near-real-time OEE per equipment unit, all
    # 20 queries answered from ONE pinned epoch (mutually consistent)
    snap = server.snapshot()
    worst = min(range(20), key=lambda e: snap.oee(e).data["oee"])
    k = snap.oee(worst).data
    print(f"lowest-OEE unit: #{worst} OEE={k['oee']:.3f} "
          f"(A={k['availability']:.2f} P={k['performance']:.2f} "
          f"Q={k['quality']:.2f}) -> maintenance ticket")
    # the incremental answer is the full-rescan answer
    scan = pipe.warehouse.query_oee(worst)
    assert abs(k["oee"] - scan["oee"]) < 1e-4
    # ... and the per-unit KPI aggregate the fused transform+rollup
    # dispatches fed at load time reproduces the rescan in O(1): the hot
    # path never re-uploads a fact block for a separate rollup dispatch
    running = pipe.warehouse.kpi_running()
    full = pipe.warehouse.kpi_rollup(20, backend="numpy")
    assert running is not None and np.allclose(running, full, atol=1e-2)
    print(f"running KPI aggregate (O(1), fused rollups) matches the "
          f"full rescan over {pipe.warehouse.rows_loaded} facts")

    # ---- dashboard refresh burst: a wallboard redraw is hundreds of tiny
    # queries arriving at once. The batched front coalesces them, pins
    # each to the epoch current at admission, and answers all point
    # queries against a view in ONE vectorized gather dispatch — same
    # bytes as asking the snapshot one query at a time.
    engine.prewarm_read(batch_buckets=(512,))   # jit-warm the gather shape
    front = BatchedReportServer(server, max_batch=4096, max_wait_ms=2.0)
    front.start()
    burst = [ReportQuery("oee", unit=u) for u in range(20)] * 20 \
        + [ReportQuery("top_downtime", k=3), ReportQuery("shift_report"),
           ReportQuery("production_rate")] * 4
    t0 = time.perf_counter()
    tickets = [front.submit(q) for q in burst]
    answers = [t.result(timeout=5.0) for t in tickets]
    burst_ms = (time.perf_counter() - t0) * 1e3
    front.stop()
    st = front.stats()
    # batched answer == the per-query snapshot answer, same epoch or newer
    fresh = ReportSnapshot(tickets[0].snapshot)
    assert answers[0].data["oee"] == fresh.oee(0).data["oee"] \
        or np.isnan(answers[0].data["oee"])
    print(f"dashboard burst: {len(burst)} queries answered in "
          f"{burst_ms:.1f} ms ({len(burst) / burst_ms * 1e3:,.0f} qps) "
          f"across {st['batches']} coalesced batch(es), "
          f"mean batch {st['mean_batch']:.0f}")

    # ---- skewed shift: one hot caster + many cold finishing lines.
    # Real plants are Zipf-skewed — the caster emits most events. Static
    # hash%n pins its keys to fixed partitions (one worker drowns, the
    # rest idle); the skew-aware strategy watches the broker's per-key
    # load and repartitions MID-RUN: hot hash ranges split away, caches
    # migrate surgically (survivors stay warm), and per-worker load
    # evens out. Records keep flowing throughout — routing epochs keep
    # every already-published record readable.
    skew_cfg = steelworks_config(n_partitions=20, backend="numpy",
                                 partition_strategy="skew")
    skew_cfg = dataclasses.replace(skew_cfg, n_business_keys=100,
                                   buffer_capacity=32768)
    src2 = SourceDatabase()
    sampler2 = SteelworksSampler(skew_cfg, SamplerConfig(
        records_per_table=1000, n_equipment=100, zipf_s=1.2))
    sampler2.generate(src2)
    pipe_sk = DODETLPipeline(skew_cfg, src2, n_workers=4)
    pipe_sk.extract()
    pipe_sk.bootstrap_caches()

    def shares(counts):
        tot = max(sum(counts.values()), 1)
        return " ".join(f"{w}:{100 * c / tot:.0f}%"
                        for w, c in sorted(counts.items()))

    for _ in range(3):                   # shift starts under equal ranges
        sampler2.generate(src2, n_per_table=1000, tables=("production",))
        pipe_sk.extract()
        pipe_sk.step(200)
    pre = {w.name: w.metrics.records for w in pipe_sk.workers}
    mig = pipe_sk.repartition()          # coordinator reads its own load
    for _ in range(5):                   # metrics, splits the hot ranges
        sampler2.generate(src2, n_per_table=1000, tables=("production",))
        pipe_sk.extract()
        pipe_sk.step(200)
    pipe_sk.run_to_completion()
    post = {w.name: w.metrics.records - pre[w.name]
            for w in pipe_sk.workers}
    print(f"skewed shift (hot caster, Zipf 1.2): per-worker share "
          f"before adaptation  {shares(pre)}")
    print(f"  after skew-aware repartition (epoch {mig['epoch']})      "
          f"{shares(post)}")
    print(f"  surgical cache migration kept "
          f"{100 * mig['cache_retention']:.0f}% of cached master rows "
          f"({mig['retained_rows']} retained, {mig['gained_rows']} dumped "
          f"for gained keys only)")

    # ---- §4.1.4: the ISA-95 generalized model costs throughput
    t0 = time.perf_counter()
    cfg2, pipe2 = run_plant(True, 8, n=2_000)
    done = pipe2.run_to_completion()
    complex_rate = done / (time.perf_counter() - t0)
    print(f"ISA-95-style normalized model: {complex_rate:,.0f} records/s "
          f"(deep join chains; paper measured 10,090 -> 230)")


if __name__ == "__main__":
    main()
