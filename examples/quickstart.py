"""Quickstart: a complete DOD-ETL pipeline on synthetic steelworks data,
end to end on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.data.sampler import SamplerConfig, SteelworksSampler


def main():
    # 1. a source database with a CDC log, fed by the plant simulator
    cfg = steelworks_config(n_partitions=8)
    source = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=5_000, n_equipment=8, late_master_frac=0.05))
    sampler.generate(source)
    print(f"source: {source.log.size()} change records in the CDC log")

    # 2. DOD-ETL: Change Tracker -> Message Queue -> Stream Processor
    pipe = DODETLPipeline(cfg, source, n_workers=4)
    extracted = pipe.extract()
    dump_s = pipe.bootstrap_caches()
    print(f"extracted {extracted} records (log-based CDC; "
          f"{source.lookup_count} production-table queries)")
    print(f"cache bootstrap: {dump_s * 1e3:.1f} ms (Fig. 4 overhead)")

    # 3. stream to completion; late records ride the operational buffer
    done = pipe.run_to_completion()
    late = sum(w.transformer.records_late for w in pipe.workers)
    print(f"transformed {done} facts ({late} arrived before their master "
          f"data and were retried via the buffer)")

    # 4. near-real-time OLAP: the star schema is queryable immediately
    for eq in range(3):
        kpis = pipe.warehouse.query_oee(eq)
        print(f"  equipment {eq}: OEE={kpis['oee']:.3f} "
              f"A={kpis['availability']:.3f} P={kpis['performance']:.3f} "
              f"Q={kpis['quality']:.3f} ({int(kpis['rows'])} grains)")
    print(f"warehouse rows: {pipe.warehouse.rows_loaded}; "
          f"source look-backs by DOD-ETL: {source.lookup_count} (always 0)")


if __name__ == "__main__":
    main()
