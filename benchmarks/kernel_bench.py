"""Microbenchmarks for the jnp reference paths that back each Pallas kernel
(interpret-mode Pallas is not a timing proxy; these time the oracle compute
the kernels replace, giving a CPU cost baseline per record/token).
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def _time(f, *args, n=5) -> float:
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / n


def bench_attention() -> Dict[str, float]:
    from repro.models.attention import attend_chunked, attend_full
    key = jax.random.PRNGKey(0)
    b, h, s, d = 1, 8, 2048, 64
    q = jax.random.normal(key, (b, s, h, d), jnp.bfloat16)
    k = jax.random.normal(key, (b, s, h, d), jnp.bfloat16)
    v = jax.random.normal(key, (b, s, h, d), jnp.bfloat16)
    full = jax.jit(lambda *a: attend_full(*a, causal=True))
    chunked = jax.jit(lambda *a: attend_chunked(*a, causal=True))
    return {"attend_full_us": _time(full, q, k, v) * 1e6,
            "attend_chunked_us": _time(chunked, q, k, v) * 1e6}


def bench_gla() -> Dict[str, float]:
    from repro.models.gla import gla_chunk
    key = jax.random.PRNGKey(0)
    b, s, h, dk = 1, 2048, 8, 64
    q = jax.random.normal(key, (b, s, h, dk), jnp.float32)
    lw = -jnp.exp(jax.random.normal(key, (b, s, h, dk)))
    f = jax.jit(lambda q_, k_, v_, w_: gla_chunk(q_, k_, v_, w_)[0])
    return {"gla_chunk_us": _time(f, q, q, q, lw) * 1e6}


def bench_hash_join() -> Dict[str, float]:
    from repro.core.cache import InMemoryTable, lookup_ref
    rng = np.random.default_rng(0)
    tbl = InMemoryTable(8192)
    keys = rng.choice(10**6, 4096, replace=False).astype(np.int64)
    tbl.upsert(keys, rng.normal(size=(4096, 8)).astype(np.float32),
               np.arange(4096, dtype=np.int64))
    kt, vt, tt = tbl.device_state()
    q = jnp.asarray(rng.choice(keys, 4096), jnp.int32)
    t = _time(lambda *a: lookup_ref(*a)[0], q, kt, vt, tt)
    return {"hash_join_us": t * 1e6,
            "hash_join_ns_per_probe": t / 4096 * 1e9}


def bench_transform() -> Dict[str, float]:
    from repro.core.transformer import transform_kernel
    from repro.core.cache import InMemoryTable
    rng = np.random.default_rng(0)
    eq, qu = InMemoryTable(4096), InMemoryTable(4096)
    eq.upsert(np.arange(20, dtype=np.int64),
              rng.normal(size=(20, 8)).astype(np.float32),
              np.arange(20, dtype=np.int64))
    qu.upsert(np.arange(4096, dtype=np.int64),
              rng.normal(size=(4096, 8)).astype(np.float32),
              np.arange(4096, dtype=np.int64))
    prod = np.abs(rng.normal(size=(4096, 8))).astype(np.float32)
    prod[:, 0] = np.arange(4096)
    prod[:, 1] = np.arange(4096) % 20
    t = _time(lambda p: transform_kernel(p, *eq.device_state(),
                                         *qu.device_state())[0],
              jnp.asarray(prod))
    return {"transform_us_per_4096": t * 1e6,
            "transform_records_s": 4096 / t}
