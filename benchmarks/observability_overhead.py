"""Observability overhead benchmark → ``BENCH_observability.json``.

Measures the two costs the observability plane's design pins:

  * ``null_span``  — the DISABLED seam. Every stage hot path runs
    ``with self.tracer.span(...)`` unconditionally; with ``NULL_TRACER``
    that is two attribute lookups, a call returning the shared
    ``_NullSpan`` singleton, and a no-op ``__exit__``. Measured as a
    paired microbenchmark: a representative per-dispatch numeric payload
    bare vs wrapped in a null span, plus the raw ns/span of the seam
    alone. The fraction must stay ≤ 1%.

  * ``paired``     — the ENABLED plane. Alternating full pipeline runs
    (same pre-generated workload, fresh pipeline each cycle) with the
    default ``NULL_TRACER`` vs a live ``StageTracer`` + a registry
    snapshot read at the end. Reported as paired per-cycle throughput
    ratios (traced/null) — on a noisy shared host only the paired ratio
    is meaningful — whose median must stay above 0.95 (≤ 5% overhead).

The traced run's export is also validated (all three sequential stage
seams present, Chrome-trace JSON round-trips) — the ``trace_valid`` gate.

    PYTHONPATH=src python -m benchmarks.observability_overhead [--smoke]

Gated in CI via ``benchmarks/compare_baseline.py`` against
``baselines/BENCH_observability_smoke.json``.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.observability import NULL_TRACER, StageTracer

N_PARTITIONS = 8
N_WORKERS = 2


# ------------------------------------------------------------- null seam
def bench_null_span(payload_rows: int = 4096, iters: int = 200,
                    reps: int = 5) -> Dict[str, float]:
    """Paired medians: representative per-dispatch numeric work bare vs
    wrapped in a NULL_TRACER span, plus the seam's raw ns/span."""
    rng = np.random.default_rng(0)
    a = rng.normal(size=(payload_rows, 16)).astype(np.float32)
    tracer = NULL_TRACER

    def work():
        return float((a * a).sum())

    bare_s, wrapped_s = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            work()
        bare_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        for _ in range(iters):
            with tracer.span("transform.dispatch") as sp:
                work()
                sp.put("records", payload_rows)
        wrapped_s.append(time.perf_counter() - t0)
    bare = float(np.median(bare_s))
    wrapped = float(np.median(wrapped_s))

    # seam alone: span enter/exit with no payload
    n_raw = 200_000
    t0 = time.perf_counter()
    for _ in range(n_raw):
        with tracer.span("x"):
            pass
    raw = time.perf_counter() - t0

    frac = max(0.0, (wrapped - bare) / bare) if bare > 0 else 0.0
    return {
        "payload_rows": payload_rows,
        "bare_us_per_dispatch": round(bare / iters * 1e6, 3),
        "wrapped_us_per_dispatch": round(wrapped / iters * 1e6, 3),
        "ns_per_null_span": round(raw / n_raw * 1e9, 1),
        "null_overhead_fraction": round(frac, 5),
    }


# --------------------------------------------------------- enabled plane
def _build_pipeline(n_records: int, tracer) -> DODETLPipeline:
    import dataclasses
    cfg = steelworks_config(n_partitions=N_PARTITIONS, backend="numpy")
    cfg = dataclasses.replace(cfg, buffer_capacity=4 * n_records)
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n_records, n_equipment=N_PARTITIONS,
        late_master_frac=0.02)).generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=N_WORKERS, tracer=tracer)
    pipe.extract()
    pipe.bootstrap_caches()
    return pipe


def _run_once(n_records: int, traced: bool) -> Dict[str, float]:
    tracer = StageTracer() if traced else NULL_TRACER
    pipe = _build_pipeline(n_records, tracer)
    t0 = time.perf_counter()
    done = pipe.run_to_completion()
    wall = time.perf_counter() - t0
    out = {"records": done, "wall_s": round(wall, 4),
           "records_s": round(done / wall, 1) if wall > 0 else 0.0}
    if traced:
        # read the full plane the way a live poller would
        snap = pipe.metrics.snapshot()
        doc = tracer.to_chrome()
        names = set(tracer.span_names())
        out["span_events"] = len(tracer.events())
        out["trace_valid"] = int(
            {"ingest.fetch", "transform.dispatch", "load.commit"} <= names
            and all(e.get("dur", 0.0) >= 0.0 for e in doc["traceEvents"]
                    if e["ph"] == "X")
            and json.loads(json.dumps(doc)) == doc
            and snap["counters"].get("worker.cache_hits", 0) >= 0)
    return out


def bench_paired(n_records: int, cycles: int) -> Dict[str, object]:
    """Alternating null/traced full-pipeline cycles; the paired per-cycle
    ratio is the noise-robust overhead figure."""
    per_cycle: List[Dict[str, float]] = []
    ratios: List[float] = []
    trace_valid = 1
    for c in range(cycles):
        null = _run_once(n_records, traced=False)
        traced = _run_once(n_records, traced=True)
        trace_valid &= traced.get("trace_valid", 0)
        r = traced["records_s"] / null["records_s"] \
            if null["records_s"] else 0.0
        ratios.append(r)
        per_cycle.append({"cycle": c, "null_records_s": null["records_s"],
                          "traced_records_s": traced["records_s"],
                          "ratio_traced_vs_null": round(r, 4),
                          "span_events": traced.get("span_events", 0)})
    med = float(np.median(ratios))
    return {
        "per_cycle": per_cycle,
        "median_ratio_traced_vs_null": round(med, 4),
        "overhead_enabled_fraction": round(max(0.0, 1.0 - med), 4),
        "trace_valid": int(trace_valid),
    }


def summary(quick: bool = False) -> Dict[str, float]:
    """Small figures for ``benchmarks.run``."""
    n = 2_000 if quick else 6_000
    null = bench_null_span(iters=50 if quick else 200, reps=3)
    paired = bench_paired(n, cycles=1 if quick else 3)
    return {
        "ns_per_null_span": null["ns_per_null_span"],
        "null_overhead_fraction": null["null_overhead_fraction"],
        "median_ratio_traced_vs_null":
            paired["median_ratio_traced_vs_null"],
        "overhead_enabled_fraction": paired["overhead_enabled_fraction"],
        "trace_valid": paired["trace_valid"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: small workload, fewer cycles")
    ap.add_argument("--out", default="BENCH_observability.json")
    args = ap.parse_known_args()[0]

    if args.smoke:
        n, cycles, iters, reps = 2_000, 3, 50, 3
    elif args.quick:
        n, cycles, iters, reps = 4_000, 3, 100, 3
    else:
        n, cycles, iters, reps = 12_000, 5, 200, 5

    results: Dict[str, object] = {
        "workload": {
            "n_records": n, "cycles": cycles,
            "n_partitions": N_PARTITIONS, "n_workers": N_WORKERS,
            "note": ("paired alternating cycles on the sequential "
                     "runtime (deterministic, jit-free numpy backend); "
                     "on a noisy shared container only the paired "
                     "ratios are meaningful (docs/BENCHMARKS.md)"),
        },
    }
    print("null seam: bare vs NULL_TRACER-wrapped dispatch payload")
    results["null_span"] = bench_null_span(iters=iters, reps=reps)
    print(f"  {results['null_span']}")
    print(f"paired: {cycles} null/traced pipeline cycles @ {n} records")
    results["paired"] = bench_paired(n, cycles)
    print(f"  median ratio traced/null: "
          f"{results['paired']['median_ratio_traced_vs_null']}")

    null_frac = results["null_span"]["null_overhead_fraction"]
    enabled_frac = results["paired"]["overhead_enabled_fraction"]
    results["gates"] = {
        "complete": 1,
        "trace_valid": results["paired"]["trace_valid"],
        "null_overhead_ok": int(null_frac <= 0.01),
        "overhead_enabled_ok": int(enabled_frac <= 0.05),
        "throughput_ratio_traced_vs_null":
            results["paired"]["median_ratio_traced_vs_null"],
    }
    print(f"gates: {results['gates']}")
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
