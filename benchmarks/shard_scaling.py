"""Sharded serving-plane scaling benchmark → ``BENCH_shard.json``.

The sharded warehouse/serving plane (``repro.runtime.shard_plane``)
splits every view's SEGMENT COLUMNS across shards: each shard folds the
full delta with foreign segments masked to the -1 identity, and the
segment-compacted fold (``_fold_blocks``) then does work proportional to
the shard's *owned active columns* — so K shards each fold ~1/K of the
columns and, on a real mesh, the cluster's fold wall is the max over
shards. Cross-shard reads pay one explicit merge (owner-gather for the
published front; pairwise tree reduce for the collective path).

Three arms per shard count K ∈ {1, 2, 4}:

* **modeled** — the deterministic unit-cost barrier model (the CI gate).
  Per fold block the compacted tree costs ``rows_pow2 × cols_pow2``
  elementwise ops; per delta the cluster cost is the max over shards of
  that unit cost, summed over deltas. Exactly reproducible (seeded
  workload, integer costs): with S a power of two and dense deltas the
  per-shard active-column count is exactly S/K, so the model exposes the
  plane's true parallel speedup with zero host noise.
* **measured** — each shard's masked fold executed SERIALLY on this
  host, walled individually; simulated parallel wall = max over shards
  (shards share nothing on the write path, so on a K-device mesh they
  run concurrently — max is the honest wall model). The merge
  (owner-gather of the [K, S, W] stack) is walled separately and
  reported as ``merge_overhead_fraction`` of the total read-side cost.
  Host-noise caveat: docs/BENCHMARKS.md.
* **parity** — booleans, no noise band: sharded-engine published fronts
  bitwise-identical to the single-device engine across every steelworks
  view; backend owner-gather == unsharded fold; tree reduce == owner
  gather; and (subprocess, 4 forced host devices) the REAL ``shard_map``
  mesh fold bitwise-identical to the single-device jax engine.

    PYTHONPATH=src python -m benchmarks.shard_scaling [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.backend import FOLD_BLOCK, available_backends, get_backend
from repro.runtime.shard_plane import (ShardedViewEngine, owner_gather,
                                       tree_reduce)
from repro.serving.engine import MaterializedViewEngine
from repro.serving.views import steelworks_views

SHARD_COUNTS = (1, 2, 4)


# ------------------------------------------------------------------ workload
def synth_deltas(n_deltas: int, rows: int, n_segments: int, n_lanes: int,
                 seed: int = 0) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Dense KPI deltas: every row hits a uniform-random segment (the
    write-path regime sharding targets — every shard busy every delta)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_deltas):
        seg = rng.integers(0, n_segments, rows).astype(np.int64)
        vals = rng.uniform(0.0, 100.0, (rows, n_lanes)).astype(np.float32)
        out.append((seg, vals))
    return out


def _static_owners(n_segments: int, k: int) -> np.ndarray:
    return (np.arange(n_segments, dtype=np.int64) * k) // n_segments


# ------------------------------------------------------------------- modeled
def _pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def _block_cost(seg: np.ndarray, n_segments: int,
                owned: np.ndarray = None) -> int:
    """Unit cost of one shard's compacted fold over one delta: per
    FOLD_BLOCK chunk, rows padded to a power of two times active columns
    padded to a power of two (>= 8, capped at n_segments) — exactly the
    tree shape ``_fold_blocks`` executes."""
    cost = 0
    for i in range(0, len(seg), FOLD_BLOCK):
        blk = seg[i:i + FOLD_BLOCK]
        live = np.unique(blk[(blk >= 0) & (blk < n_segments)])
        if owned is not None:
            live = live[owned[live]]
        if not len(live):
            continue
        cost += _pow2(len(blk)) * min(max(_pow2(len(live)), 8), n_segments)
    return cost


def run_modeled(deltas, n_segments: int) -> Dict:
    """Deterministic barrier model: cluster cost per delta = max over
    shards; speedup(K) = single-device cost / sharded cluster cost."""
    single = sum(_block_cost(seg, n_segments) for seg, _ in deltas)
    out = {"single_cost": single, "speedup": {}, "cluster_cost": {}}
    for k in SHARD_COUNTS:
        owners = _static_owners(n_segments, k)
        cluster = 0
        for seg, _ in deltas:
            cluster += max(_block_cost(seg, n_segments, owners == sh)
                           for sh in range(k))
        out["cluster_cost"][str(k)] = cluster
        out["speedup"][str(k)] = round(single / cluster, 3) if cluster else 0
    return out


# ------------------------------------------------------------------ measured
def run_measured(deltas, n_segments: int, repeats: int = 3) -> Dict:
    """Serial per-shard folds, walled individually; simulated parallel
    wall = Σ_deltas max_shard wall. Medians over ``repeats`` interleaved
    passes (all arms timed in the same pass — paired, like every other
    benchmark here)."""
    be = get_backend("numpy")
    samples = {str(k): [] for k in SHARD_COUNTS}
    merge_samples, single_samples = [], []
    parity_backend = True
    for _ in range(repeats):
        t0 = time.perf_counter()
        ref = None
        for seg, vals in deltas:
            ref = be.fold_segments(seg, vals, n_segments)
        single_samples.append(time.perf_counter() - t0)
        for k in SHARD_COUNTS:
            owners = _static_owners(n_segments, k)
            wall = 0.0
            tables = []
            for seg, vals in deltas:
                shard_walls, shard_tables = [], []
                for sh in range(k):
                    masked = np.where(
                        (seg >= 0) & (seg < n_segments)
                        & (owners[np.clip(seg, 0, n_segments - 1)] == sh),
                        seg, np.int64(-1))
                    t0 = time.perf_counter()
                    shard_tables.append(
                        be.fold_segments(masked, vals, n_segments))
                    shard_walls.append(time.perf_counter() - t0)
                wall += max(shard_walls)
                tables = shard_tables
            samples[str(k)].append(wall)
            if k == max(SHARD_COUNTS):
                t0 = time.perf_counter()
                merged = owner_gather(tables, owners)
                merge_samples.append(time.perf_counter() - t0)
                parity_backend &= merged.tobytes() == ref.tobytes()
    med = lambda xs: sorted(xs)[len(xs) // 2]  # noqa: E731
    single = med(single_samples)
    walls = {k: med(v) for k, v in samples.items()}
    merge = med(merge_samples)
    kmax = str(max(SHARD_COUNTS))
    return {
        "single_wall_s": round(single, 4),
        "parallel_wall_s": {k: round(w, 4) for k, w in walls.items()},
        "speedup": {k: round(single / w, 3) if w else 0
                    for k, w in walls.items()},
        "merge_wall_s": round(merge, 5),
        "merge_overhead_fraction": round(merge / (walls[kmax] + merge), 4)
        if walls[kmax] + merge else 0.0,
        "parity_backend_bitwise": bool(parity_backend),
    }


# -------------------------------------------------------------------- parity
def _mk_facts(rng, n: int, n_units: int) -> np.ndarray:
    f = np.zeros((n, 10), np.float32)
    f[:, 0] = rng.integers(0, n_units, n)
    f[:, 1] = rng.uniform(0, 10_000, n)
    f[:, 2] = f[:, 1] + rng.uniform(1, 50, n)
    f[:, 3:7] = rng.uniform(0, 1, (n, 4))
    f[:, 7] = rng.uniform(0, 40, n)
    f[:, 8] = rng.uniform(0, 10, n)
    f[:, 9] = (rng.uniform(0, 1, n) > 0.1).astype(np.float32)
    return f


def run_engine_parity(n_units: int = 32, n_deltas: int = 5,
                      rows: int = 1_500) -> Dict:
    """ShardedViewEngine on every shard count vs the plain engine, same
    synthetic fact stream: published fronts must be bitwise-identical
    and the tree-reduce path must match the owner-gather front."""
    rng = np.random.default_rng(7)
    stream = [_mk_facts(rng, rows, n_units) for _ in range(n_deltas)]
    specs = steelworks_views(n_units)
    ref = MaterializedViewEngine(specs, backend="numpy")
    for d in stream:
        ref.publish(d)
    ref.fold_pending()
    want = {s.name: ref.snapshot().view(s.name).table.tobytes()
            for s in specs}
    parity = tree_ok = True
    for k in SHARD_COUNTS:
        eng = ShardedViewEngine(specs, n_shards=k, backend="numpy")
        for d in stream:
            eng.publish(d)
        eng.fold_pending()
        snap = eng.snapshot()
        for s in specs:
            parity &= snap.view(s.name).table.tobytes() == want[s.name]
            tree_ok &= eng.tree_reduced_table(s.name).tobytes() \
                == want[s.name]
    return {"parity_engine_bitwise": bool(parity),
            "tree_reduce_bitwise": bool(tree_ok)}


_MESH_DRILL = textwrap.dedent("""
    import numpy as np
    from repro.launch.mesh import virtual_devices, make_shard_mesh
    virtual_devices(4)
    import jax
    from repro.core.backend import get_backend
    from repro.runtime.shard_plane import ShardedViewEngine
    from repro.serving.engine import MaterializedViewEngine
    from repro.serving.views import steelworks_views

    rng = np.random.default_rng(11)
    n_units = 16
    specs = steelworks_views(n_units)

    def mk(n):
        f = np.zeros((n, 10), np.float32)
        f[:, 0] = rng.integers(0, n_units, n)
        f[:, 1] = rng.uniform(0, 10000, n)
        f[:, 2] = f[:, 1] + rng.uniform(1, 50, n)
        f[:, 3:7] = rng.uniform(0, 1, (n, 4))
        f[:, 7] = rng.uniform(0, 40, n)
        f[:, 8] = rng.uniform(0, 10, n)
        f[:, 9] = 1.0
        return f

    be = get_backend("jax")
    eng = ShardedViewEngine(specs, n_shards=4, backend="jax")
    ref = MaterializedViewEngine(specs, backend="jax")
    be.set_mesh(make_shard_mesh(4))
    try:
        for _ in range(4):
            d = mk(int(rng.integers(200, 3000)))
            eng.publish(d); ref.publish(d)
            eng.fold_pending(); ref.fold_pending()
    finally:
        be.set_mesh(None)
    s, r = eng.snapshot(), ref.snapshot()
    ok = all(s.view(sp.name).table.tobytes()
             == r.view(sp.name).table.tobytes() for sp in specs)
    print("MESH_PARITY", "OK" if ok else "FAIL", jax.device_count())
""")


def run_mesh_drill(timeout_s: int = 600) -> Dict:
    """The REAL thing: a subprocess with 4 forced host devices folds via
    ``shard_map`` on an actual 4-device mesh and must stay bitwise equal
    to the single-device jax engine. Subprocess because device count
    binds at jax initialization (this process is already initialized)."""
    if "jax" not in available_backends():
        return {"mesh_parity": False, "skipped": "jax unavailable"}
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _MESH_DRILL], env=env,
                         capture_output=True, text=True, timeout=timeout_s)
    ok = out.returncode == 0 and "MESH_PARITY OK" in out.stdout
    res = {"mesh_parity": bool(ok)}
    if not ok:
        res["stderr_tail"] = out.stderr[-1500:]
    return res


# ---------------------------------------------------------------------- main
def _gates(modeled: Dict, measured: Dict, parity: Dict,
           mesh: Dict) -> Dict:
    return {
        "parity_engine_bitwise": parity["parity_engine_bitwise"],
        "tree_reduce_bitwise": parity["tree_reduce_bitwise"],
        "parity_backend_bitwise": measured["parity_backend_bitwise"],
        "mesh_parity": mesh["mesh_parity"],
        "speedup_modeled_2": modeled["speedup"]["2"],
        "speedup_modeled_4": modeled["speedup"]["4"],
        "merge_overhead_fraction": measured["merge_overhead_fraction"],
        "complete": True,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: small S, 1 repeat")
    ap.add_argument("--skip-mesh", action="store_true",
                    help="skip the 4-device subprocess drill")
    ap.add_argument("--out", default="BENCH_shard.json")
    args = ap.parse_known_args()[0]

    if args.smoke:
        S, rows, n_deltas, repeats = 512, 2_048, 3, 1
    elif args.quick:
        S, rows, n_deltas, repeats = 1_024, 4_096, 4, 3
    else:
        S, rows, n_deltas, repeats = 1_024, 8_192, 6, 5
    n_lanes = 4
    deltas = synth_deltas(n_deltas, rows, S, n_lanes)

    results = {
        "workload": {
            "n_segments": S, "rows_per_delta": rows, "n_deltas": n_deltas,
            "n_lanes": n_lanes, "repeats": repeats,
            "shard_counts": list(SHARD_COUNTS),
            "note": ("modeled = deterministic unit-cost barrier model "
                     "(rows_pow2 x owned_active_cols_pow2 per block, "
                     "cluster cost = max over shards) — the CI gate; "
                     "measured = serial per-shard folds on THIS host, "
                     "parallel wall simulated as max over shards "
                     "(docs/BENCHMARKS.md caveat applies)"),
        }
    }
    results["modeled"] = run_modeled(deltas, S)
    print(f"modeled speedup: {results['modeled']['speedup']}")
    results["measured"] = run_measured(deltas, S, repeats)
    print(f"measured (simulated-parallel) speedup: "
          f"{results['measured']['speedup']}, merge overhead "
          f"{results['measured']['merge_overhead_fraction']}")
    results["parity"] = run_engine_parity()
    mesh = {"mesh_parity": False, "skipped": "--skip-mesh"} \
        if args.skip_mesh else run_mesh_drill()
    results["mesh"] = mesh
    print(f"parity: {results['parity']}, mesh: {mesh}")

    results["gates"] = _gates(results["modeled"], results["measured"],
                              results["parity"], mesh)
    print("gates:", results["gates"])
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


def summary(quick: bool = False) -> Dict:
    """Small figures for ``benchmarks.run``."""
    S, rows, n_deltas = (512, 2_048, 3) if quick else (1_024, 4_096, 4)
    deltas = synth_deltas(n_deltas, rows, S, 4)
    modeled = run_modeled(deltas, S)
    parity = run_engine_parity()
    return {
        "speedup_modeled_2": modeled["speedup"]["2"],
        "speedup_modeled_4": modeled["speedup"]["4"],
        "parity_engine_bitwise": int(parity["parity_engine_bitwise"]),
        "tree_reduce_bitwise": int(parity["tree_reduce_bitwise"]),
    }


if __name__ == "__main__":
    main()
