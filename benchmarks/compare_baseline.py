"""Perf-trend CI gate: diff fresh smoke-mode BENCH_*.json results against
the checked-in baselines under ``benchmarks/baselines/``.

The tier-1 suite proves the code is *correct*; this gate proves it has
not gotten *slower*. It compares only the curated headline metrics below
— paired speedup ratios and boolean contracts — never raw millisecond
timings, which are meaningless across hosts. Ratios are host-relative
(both sides of every paired benchmark run on the same machine in the
same process), so a checked-in ratio from one box is comparable to a
fresh ratio from another up to scheduler noise; the default noise band
is 50% (a metric regresses only when it drops below ``baseline / 1.5``).
Boolean gates (parity, completeness, bitwise equality) have no noise
band: a flip from true to false always fails.

Usage (CI runs the first form after each smoke benchmark):

    python -m benchmarks.compare_baseline /tmp/BENCH_views_smoke.json
    python -m benchmarks.compare_baseline --write-baselines FILE [FILE...]
    python -m benchmarks.compare_baseline --band 1.5 FILE [FILE...]

Exit status is non-zero iff at least one gate regressed; every
regression is listed on stdout. ``--write-baselines`` copies the given
fresh results over the checked-in baselines (run locally after an
intentional perf change, then commit the diff).

New benchmark axes register here by adding (path, kind) rows to GATES —
unknown files compare nothing and pass with a warning so the gate never
blocks an unrelated PR.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys
from typing import Dict, List, Sequence, Tuple

BASELINE_DIR = pathlib.Path(__file__).resolve().parent / "baselines"

# Per baseline file: (dotted JSON path, kind). Kinds:
#   higher — ratio metric, regression iff current < baseline / band
#   lower  — ratio metric, regression iff current > baseline * band
#   bool   — contract, regression iff baseline true and current false
#   exact  — integer contract (dispatch counts), regression iff changed
GATES: Dict[str, List[Tuple[str, str]]] = {
    "BENCH_views_smoke.json": [
        ("query_latency.parity_ok", "bool"),
        ("query_latency.speedup_at_largest", "higher"),
        ("staleness_e2e.complete", "bool"),
        ("batched.parity_ok", "bool"),
        ("batched.per_batch.1024.epochs_monotonic", "bool"),
        ("batched.per_batch.1024.speedup_vs_loop", "higher"),
        ("scan_fold.bitwise_ok", "bool"),
        ("scan_fold.read_speedup_at_largest", "higher"),
    ],
    "BENCH_dispatch_smoke.json": [
        ("round_trips.round_trips_per_worker_step.pre", "exact"),
        ("round_trips.round_trips_per_worker_step.post", "exact"),
        ("sustained.paired_median_device_plane_vs_pre_pr", "higher"),
        ("sustained.paired_median_concurrent_serving_vs_pre_pr", "higher"),
    ],
    "BENCH_sustained_smoke.json": [
        ("dodetl.2.complete", "bool"),
        ("speedup_vs_baseline.2", "higher"),
    ],
    "BENCH_skew_smoke.json": [
        ("gates.complete", "bool"),
        ("gates.warehouse_byte_identical", "bool"),
        ("gates.cache_retention", "higher"),
        ("gates.imbalance_post", "lower"),
    ],
    "BENCH_recovery_smoke.json": [
        ("gates.complete", "bool"),
        ("gates.byte_identical", "bool"),
        ("gates.kill9_exactly_once", "bool"),
        ("gates.sublinear_ok", "bool"),
        ("overhead.checkpoint_overhead_ratio", "lower"),
        ("scaling.recovery_speedup_vs_cold", "higher"),
    ],
    "BENCH_observability_smoke.json": [
        ("gates.complete", "bool"),
        ("gates.trace_valid", "bool"),
        ("gates.null_overhead_ok", "bool"),
        ("gates.overhead_enabled_ok", "bool"),
        ("gates.throughput_ratio_traced_vs_null", "higher"),
    ],
    "BENCH_control_smoke.json": [
        ("gates.complete", "bool"),
        ("gates.controller_acted", "bool"),
        ("gates.spike_recovered", "bool"),
        ("gates.human_calls_zero", "bool"),
        ("gates.detection_within_bound", "bool"),
        ("gates.byte_identical", "bool"),
        ("gates.restart_ok", "bool"),
        ("gates.poison_quarantined", "bool"),
        ("gates.no_crash_loop", "bool"),
        ("detection.latency_s", "lower"),
    ],
    "BENCH_shard_smoke.json": [
        ("gates.complete", "bool"),
        ("gates.parity_engine_bitwise", "bool"),
        ("gates.parity_backend_bitwise", "bool"),
        ("gates.tree_reduce_bitwise", "bool"),
        ("gates.mesh_parity", "bool"),
        ("gates.speedup_modeled_2", "higher"),
        ("gates.speedup_modeled_4", "higher"),
        ("gates.merge_overhead_fraction", "lower"),
    ],
}


def _lookup(doc: dict, path: str):
    node = doc
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def _baseline_name(current: pathlib.Path) -> str:
    # /tmp/BENCH_views_smoke.json -> BENCH_views_smoke.json
    return current.name


def compare(current_path: pathlib.Path, band: float) -> List[str]:
    """Return the list of regression messages (empty = pass)."""
    name = _baseline_name(current_path)
    gates = GATES.get(name)
    if gates is None:
        print(f"[compare_baseline] no gates registered for {name}; "
              f"nothing to compare")
        return []
    baseline_path = BASELINE_DIR / name
    if not baseline_path.exists():
        print(f"[compare_baseline] no checked-in baseline {baseline_path}; "
              f"run --write-baselines to create one")
        return []
    base = json.loads(baseline_path.read_text())
    cur = json.loads(current_path.read_text())
    regressions: List[str] = []
    for path, kind in gates:
        b, c = _lookup(base, path), _lookup(cur, path)
        if b is None:
            # baseline predates this metric: not a regression, just note it
            print(f"  {name}:{path} absent from baseline (new metric, "
                  f"current={c}) — refresh with --write-baselines")
            continue
        if c is None:
            regressions.append(f"{name}:{path} missing from current "
                               f"results (baseline={b})")
            continue
        if kind == "bool":
            ok = (not b) or bool(c)
        elif kind == "exact":
            ok = c == b
        elif kind == "higher":
            ok = float(c) >= float(b) / band
        else:  # lower
            ok = float(c) <= float(b) * band
        marker = "ok " if ok else "REG"
        print(f"  [{marker}] {name}:{path}  baseline={b}  current={c}  "
              f"({kind}, band={band})")
        if not ok:
            regressions.append(
                f"{name}:{path} regressed ({kind}): baseline={b}, "
                f"current={c}, band={band}")
    return regressions


def write_baselines(paths: Sequence[pathlib.Path]) -> None:
    BASELINE_DIR.mkdir(parents=True, exist_ok=True)
    for p in paths:
        json.loads(p.read_text())       # refuse to check in malformed JSON
        dst = BASELINE_DIR / _baseline_name(p)
        shutil.copyfile(p, dst)
        print(f"wrote {dst}")


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("results", nargs="+", type=pathlib.Path,
                    help="fresh smoke-mode BENCH_*.json file(s)")
    ap.add_argument("--band", type=float, default=1.5,
                    help="noise band for ratio metrics (default 1.5 = "
                         "fail when >50%% worse than baseline)")
    ap.add_argument("--write-baselines", action="store_true",
                    help="overwrite checked-in baselines with the given "
                         "results instead of comparing")
    args = ap.parse_args(argv)
    if args.write_baselines:
        write_baselines(args.results)
        return 0
    regressions: List[str] = []
    for p in args.results:
        regressions += compare(p, args.band)
    if regressions:
        print(f"\n{len(regressions)} perf regression(s) vs "
              f"benchmarks/baselines/:")
        for r in regressions:
            print(f"  - {r}")
        return 1
    print("\nperf-trend gate: all metrics within band of baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
