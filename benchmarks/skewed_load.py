"""Skew-aware adaptive partitioning benchmark → ``BENCH_skew.json``.

The paper's load-balancing argument (§3.1.1 "efficient data partitioning")
under a REALISTIC key distribution: a steelworks emits most production
events from a few hot units (casters), so business keys are drawn
Zipf(s)-skewed for s ∈ {0, 0.8, 1.2}. Under static ``hash % n`` routing a
hot key pins one partition — and its worker — while the rest idle; the
skew-aware strategy observes the broker's per-key publish load mid-run and
splits hot hash ranges / merges cold ones (``ConcurrentCluster.
repartition`` / ``DODETLPipeline.repartition``), with surgical cache
migration keeping the survivors warm.

Two harnesses per (s, strategy):

* **modeled** — the deterministic barrier loop (the ``SimulatedCluster``
  execution model: per-round cluster time = max over workers). Per-worker
  record counts are exactly reproducible, so the worker-load **imbalance
  ratio** (max/mean records per worker) and the **cache-retention
  fraction** of the mid-run repartition are noise-free — these are the CI
  gates. Modeled throughput ratios (skew vs static per interleaved cycle,
  median over cycles) show what balance buys a cluster with one core per
  worker.
* **concurrent** — the real ``ConcurrentCluster`` (4 workers × 3 stage
  threads) on the same workload, paired static/skew cycles adjacent in
  time. On the noisy shared 2-core container that produced the checked-in
  file, total work — not per-worker balance — bounds wall time, so this
  arm under-reports the balance dividend; trust only the paired medians
  and read docs/BENCHMARKS.md before comparing absolute rates.

Every arm asserts zero record loss and that static and skew runs produce
byte-identical canonical warehouses (routing must never change WHAT is
computed).

    PYTHONPATH=src python -m benchmarks.skewed_load [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional

import numpy as np

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.runtime.cluster import ConcurrentCluster

ZIPFS = (0.0, 0.8, 1.2)


def build(strategy: str, zipf_s: float, n_base: int, n_partitions: int,
          n_workers: int, n_units: int, seed: int = 0):
    """Seed masters + a base operational backlog; production WAVES are
    then streamed by the caller (the repartition must have a future to
    redirect — routing epochs only steer records published after the
    switch; the already-published backlog drains under its old epoch).

    ``n_units`` is deliberately larger than the paper's 20 (a finer
    business-key grain — think production lines, not areas): a business
    key is the ATOMIC unit of worker affinity, so under Zipf(1.2) over
    only 20 keys the single hottest key carries ~35% of the stream and
    NO strategy can balance 4 workers below max/mean ≈ 1.4. At 200 keys
    the hot key is ~20% < the 25% per-worker mean, so balance is
    achievable — and the strategies can be told apart."""
    cfg = steelworks_config(n_partitions=n_partitions, backend="numpy",
                            partition_strategy=strategy)
    import dataclasses as _dc
    cfg = _dc.replace(cfg, buffer_capacity=65536, n_business_keys=n_units)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n_base, n_equipment=n_units,
        zipf_s=zipf_s, seed=seed))
    sampler.generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers)
    return pipe, sampler, src


def _imbalance(counts: Dict[str, int]) -> float:
    v = np.array(list(counts.values()), float)
    return float(v.max() / v.mean()) if v.sum() else 1.0


def run_modeled(strategy: str, zipf_s: float, n_base: int, waves: int,
                chunk: int, n_partitions: int, n_workers: int, cap: int,
                repartition_round: int, adapt: bool,
                n_units: int = 200) -> Dict:
    """Deterministic barrier rounds, fed one production wave per round so
    publishes are spread across routing epochs. ``adapt`` fires
    ``pipe.repartition()`` after ``repartition_round`` rounds — mid-run,
    with the broker's load counters warmed, exactly like a coordinator
    watching its metrics.

    The primary cluster-time figure is the UNIT-COST barrier model:
    per-round cost = max over workers of records processed that round
    (one core per worker, uniform per-record cost), summed over rounds.
    It is exactly reproducible — per-worker record counts are
    deterministic — which is what the noisy shared host demands (see
    docs/BENCHMARKS.md); the measured max-wall sum is reported next to it
    for transparency but inherits the host's scheduler noise."""
    total = n_base + waves * chunk
    pipe, sampler, src = build(strategy, zipf_s, n_base, n_partitions,
                               n_workers, n_units)
    pipe.extract()
    pipe.bootstrap_caches()
    eq = pipe.master_topic_map["equipment"]
    qu = pipe.master_topic_map["quality"]
    walls, round_costs = [], []
    done, rounds, stalls, fed = 0, 0, 0, 0
    migration = None
    pre_counts: Optional[Dict[str, int]] = None
    while True:
        if fed < waves:
            sampler.generate(src, n_per_table=chunk, tables=("production",))
            fed += 1
        pipe.extract()
        for w in pipe.workers:
            w.pump_master(eq, w.equipment)
            w.pump_master(qu, w.quality)
        round_walls, worker_records, got = [], [], 0
        for w in pipe.workers:
            t0 = time.perf_counter()
            got_w = 0
            for topic in pipe.operational_topics:
                got_w += w.process_operational(topic, cap)
            round_walls.append(time.perf_counter() - t0)
            worker_records.append(got_w)
            got += got_w
        walls.append(max(round_walls))
        round_costs.append((max(worker_records), got))
        done += got
        rounds += 1
        if adapt and rounds == repartition_round:
            pre_counts = {w.name: w.metrics.records for w in pipe.workers}
            migration = pipe.repartition()
        buffered = sum(len(w.buffer) for w in pipe.workers)
        stalls = stalls + 1 if got == 0 else 0
        if fed >= waves and ((got == 0 and buffered == 0) or stalls >= 3):
            break
    counts = {w.name: w.metrics.records for w in pipe.workers}
    unit_cost = sum(c for c, _ in round_costs)
    # sustained window: rounds after the adaptation point (the SAME index
    # split in the static arm, so both arms are compared on the part of
    # the stream a steady-state cluster would spend its life in)
    sus_cost = sum(c for c, _ in round_costs[repartition_round:])
    sus_records = sum(g for _, g in round_costs[repartition_round:])
    out = {
        "records": done,
        "rounds": rounds,
        "cluster_cost_records": unit_cost,   # Σ max worker records/round
        "throughput_modeled": round(done / unit_cost, 4) if unit_cost else 0,
        "records_sustained": sus_records,
        "throughput_sustained": round(sus_records / sus_cost, 4)
        if sus_cost else 0,
        "measured_wall_s": round(sum(walls), 4),
        "imbalance": round(_imbalance(counts), 4),
        "per_worker_records": counts,
        "complete": done == total,
    }
    if migration is not None:
        post = {w: counts[w] - pre_counts.get(w, 0) for w in counts}
        out["imbalance_pre"] = round(_imbalance(pre_counts), 4)
        out["imbalance_post"] = round(_imbalance(post), 4)
        out["migration"] = migration
    return out, pipe


def run_concurrent(strategy: str, zipf_s: float, n_base: int, waves: int,
                   chunk: int, n_partitions: int, n_workers: int, cap: int,
                   adapt: bool, repartition_frac: float = 0.25,
                   n_units: int = 200) -> Dict:
    """The real cluster on the same workload: CDC extraction thread +
    3 stage threads per worker, a feeder thread streaming production
    waves; the skew arm repartitions once ~25% of the stream has landed
    (load metrics warmed, most of the stream still ahead)."""
    import threading
    total = n_base + waves * chunk
    pipe, sampler, src = build(strategy, zipf_s, n_base, n_partitions,
                               n_workers, n_units)

    def feed():
        for _ in range(waves):
            sampler.generate(src, n_per_table=chunk, tables=("production",))
            time.sleep(0.002)        # let extraction interleave the waves

    cluster = ConcurrentCluster(pipe, max_records_per_partition=cap)
    cluster.start()
    feeder = threading.Thread(target=feed)
    feeder.start()
    migration = None
    if adapt:
        deadline = time.time() + 60
        while cluster.records_done() < total * repartition_frac \
                and time.time() < deadline:
            time.sleep(0.005)
        migration = cluster.repartition()
    feeder.join()
    done = cluster.run_until_idle(timeout=180)
    cluster.stop_all()
    rep = cluster.report()
    counts = {name: rt.records_done
              for name, rt in cluster.runtimes.items() if not rt.dead}
    out = {
        "records": done,
        "records_s": rep["records_s"],
        "wall_s": rep["wall_s"],
        "imbalance": round(_imbalance(counts), 4),
        "complete": done == total,
    }
    if migration is not None:
        out["migration"] = migration
    return out, pipe


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: s=1.2 only, 1 cycle, small workload")
    ap.add_argument("--out", default="BENCH_skew.json")
    args = ap.parse_known_args()[0]

    if args.smoke:
        n_base, chunk, waves, cycles, zipfs = 500, 500, 4, 1, (1.2,)
    elif args.quick:
        n_base, chunk, waves, cycles, zipfs = 1_000, 1_000, 9, 2, (1.2,)
    else:
        n_base, chunk, waves, cycles, zipfs = 2_000, 2_000, 9, 3, ZIPFS
    n = n_base + waves * chunk
    n_partitions, n_workers, cap = 20, 4, 200
    modeled_cap = None      # the barrier arm is uncapped: a per-partition
                            # fetch cap would throttle the deliberately
                            # load-concentrated hot partitions' drain and
                            # measure the cap, not the balance
    n_units = 10 * n_partitions
    repartition_round = 3

    results = {
        "workload": {
            "n_base": n_base, "chunk": chunk, "waves": waves,
            "total_ops": n, "n_partitions": n_partitions,
            "n_units": n_units, "modeled_cap": modeled_cap,
            "n_workers": n_workers, "max_records_per_partition": cap,
            "zipf_s": list(zipfs), "cycles": cycles,
            "repartition_after_round": repartition_round,
            "note": ("modeled = deterministic barrier rounds (cluster "
                     "time = max worker wall; counts exact); concurrent "
                     "= real ConcurrentCluster — on the noisy 2-core "
                     "container only paired/interleaved medians are "
                     "meaningful (docs/BENCHMARKS.md)"),
        },
        "modeled": {}, "concurrent": {},
    }

    for s in zipfs:
        key = f"zipf_{s}"
        speedups, wall_ratios, stat_runs, skew_runs = [], [], [], []
        table_ref = None
        for _ in range(cycles):          # interleaved: static, skew, ...
            stat, pipe_a = run_modeled("static", s, n_base, waves, chunk,
                                       n_partitions, n_workers, modeled_cap,
                                       repartition_round, adapt=False,
                                       n_units=n_units)
            skew, pipe_b = run_modeled("skew", s, n_base, waves, chunk,
                                       n_partitions, n_workers, modeled_cap,
                                       repartition_round, adapt=True,
                                       n_units=n_units)
            a = pipe_a.warehouse.canonical_fact_table()
            b = pipe_b.warehouse.canonical_fact_table()
            assert a.shape == b.shape and a.tobytes() == b.tobytes(), \
                "routing changed WHAT was computed"
            table_ref = a.shape
            speedups.append(skew["throughput_sustained"]
                            / max(stat["throughput_sustained"], 1e-9))
            wall_ratios.append(stat["measured_wall_s"]
                               / max(skew["measured_wall_s"], 1e-9))
            stat_runs.append(stat)
            skew_runs.append(skew)
        mid = sorted(range(cycles), key=lambda i: speedups[i])[cycles // 2]
        results["modeled"][key] = {
            "static": stat_runs[mid],
            "skew": skew_runs[mid],
            # unit-cost barrier model: deterministic, identical per cycle
            "speedup_sustained_unit_cost": round(speedups[mid], 3),
            "speedup_whole_run_unit_cost": round(
                skew_runs[mid]["throughput_modeled"]
                / max(stat_runs[mid]["throughput_modeled"], 1e-9), 3),
            # measured max-wall ratios: paired per cycle, noisy host
            "paired_measured_wall_ratios": [round(x, 3)
                                            for x in wall_ratios],
            "median_paired_wall_ratio": round(
                sorted(wall_ratios)[cycles // 2], 3),
            "warehouse_byte_identical": True,
            "canonical_shape": list(table_ref),
        }
        print(f"modeled {key}: imbalance static "
              f"{stat_runs[mid]['imbalance']} -> skew "
              f"{skew_runs[mid].get('imbalance_post', skew_runs[mid]['imbalance'])}, "
              f"sustained unit-cost speedup "
              f"{results['modeled'][key]['speedup_sustained_unit_cost']}x "
              f"(whole run "
              f"{results['modeled'][key]['speedup_whole_run_unit_cost']}x, "
              f"measured wall ratio "
              f"{results['modeled'][key]['median_paired_wall_ratio']}x)")

    # real-concurrency probe at the heaviest skew
    s = max(zipfs)
    key = f"zipf_{s}"
    speedups, stat_runs, skew_runs = [], [], []
    for _ in range(cycles):
        stat, _ = run_concurrent("static", s, n_base, waves, chunk,
                                 n_partitions, n_workers, cap, adapt=False,
                                 n_units=n_units)
        skew, _ = run_concurrent("skew", s, n_base, waves, chunk,
                                 n_partitions, n_workers, cap, adapt=True,
                                 n_units=n_units)
        speedups.append(skew["records_s"] / max(stat["records_s"], 1))
        stat_runs.append(stat)
        skew_runs.append(skew)
    mid = sorted(range(cycles), key=lambda i: speedups[i])[cycles // 2]
    results["concurrent"][key] = {
        "static": stat_runs[mid],
        "skew": skew_runs[mid],
        "paired_speedups": [round(x, 3) for x in speedups],
        "median_paired_speedup": round(sorted(speedups)[cycles // 2], 3),
    }
    print(f"concurrent {key}: paired speedup "
          f"{results['concurrent'][key]['median_paired_speedup']}x, "
          f"retention {skew_runs[mid].get('migration', {}).get('cache_retention')}")

    # ------------------------------------------------------------- CI gates
    heavy = results["modeled"][f"zipf_{max(zipfs)}"]
    gates = {
        "complete": all(r["static"]["complete"] and r["skew"]["complete"]
                        for r in results["modeled"].values()),
        "warehouse_byte_identical": all(
            r["warehouse_byte_identical"]
            for r in results["modeled"].values()),
        "cache_retention": heavy["skew"]["migration"]["cache_retention"],
        "imbalance_pre": heavy["skew"]["imbalance_pre"],
        "imbalance_post": heavy["skew"]["imbalance_post"],
        "imbalance_static": heavy["static"]["imbalance"],
    }
    results["gates"] = gates
    print("gates:", gates)

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


def summary(quick: bool = False) -> Dict[str, float]:
    """Small single-cycle figures for ``benchmarks.run``."""
    n_base = chunk = 500 if quick else 1_000
    waves = 4
    stat, _ = run_modeled("static", 1.2, n_base, waves, chunk, 20, 4, None,
                          3, adapt=False)
    skew, _ = run_modeled("skew", 1.2, n_base, waves, chunk, 20, 4, None,
                          3, adapt=True)
    return {
        "imbalance_static": stat["imbalance"],
        "imbalance_skew_post": skew.get("imbalance_post", skew["imbalance"]),
        "cache_retention": skew.get("migration", {}).get("cache_retention",
                                                         1.0),
        "modeled_speedup": round(
            skew["throughput_sustained"]
            / max(stat["throughput_sustained"], 1e-9), 3),
        "complete": int(stat["complete"] and skew["complete"]),
    }


if __name__ == "__main__":
    main()
