"""Device-plane dispatch/sync overhead benchmark — the measurement suite
of the zero-copy ``FactBlock`` hot path (-> ``BENCH_dispatch.json``).

Three measurements, all on the jax backend by default:

  * ``round_trips``     — instrumented backend counters
                          (``op_dispatches`` / ``host_syncs``) over one
                          representative BI step on a fixed fact block:
                          the PRE-PR op sequence (transform with an
                          immediate host sync, a separate per-unit
                          ``segment_reduce`` round trip, a serving-layer
                          delta fold) against the DEVICE PLANE (one fused
                          ``transform_and_rollup`` dispatch, zero syncs
                          until ``FactBlock.to_host()`` at the load
                          boundary). The worker step drops from 3
                          host↔device round trips to 1; the serving fold
                          keeps its single (now segment-compacted) trip in
                          the maintenance stage, off the worker's hot path.
  * ``sustained``       — paired, interleaved A/B single-worker
                          sustained-load cycles over the steelworks
                          workload (same feeder/closed loop as
                          ``benchmarks.sustained_load``):
                            A = the pre-PR coalesced sequential round loop
                                (ONE dispatch per step, immediate host
                                sync, no fused rollup — reproduced
                                verbatim),
                            B = the device-plane loop: fetch N+1 and
                                dispatch it while step N's block is still
                                computing / copying D2H, then materialize
                                N at its load boundary (the same software
                                pipeline the concurrent runtime's
                                transform->load stages execute on threads),
                            C = the SHIPPED single-worker
                                ``ConcurrentCluster`` with the serving
                                engine attached (same views, compacted
                                folds in the maintenance stage) — the
                                headline arm.
                          Headline = median of per-cycle B/A ratios
                          (paired/interleaved — the only trustworthy
                          estimator on the noisy 2-core reference host,
                          see docs/BENCHMARKS.md).
  * ``fold_compaction`` — paired timings of the segment-compacted fold vs
                          a verbatim reproduction of the uncompacted
                          halving tree on sparse deltas, plus the bitwise
                          equality check that makes compaction legal.

    PYTHONPATH=src python -m benchmarks.dispatch_overhead [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import threading
import time
from typing import Dict, Optional

# pin XLA intra-op parallelism BEFORE jax initializes (one core per worker
# thread — identical accounting to benchmarks.sustained_load)
_PIN = "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1"
if "xla_cpu_multi_thread_eigen" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _PIN).strip()

import numpy as np

from benchmarks.sustained_load import (Workload, feed_waves, prewarm,
                                       seed_source)
from repro.core import DODETLPipeline, RecordBatch
from repro.core.backend import get_backend
from repro.core.cache import InMemoryTable


# =========================================================== 1. round trips
def _bench_tables(rng, n_units: int, n_prod: int):
    eq = InMemoryTable(max(256, 4 * n_units))
    eqp = np.zeros((n_units, 8), np.float32)
    eqp[:, 1] = np.arange(n_units)
    eqp[:, 4] = 100.0
    eqp[:, 5] = (rng.random(n_units) > 0.3).astype(np.float32)
    eqp[:, 6] = 5.0 + rng.random(n_units).astype(np.float32)
    eqp[:, 7] = 50.0
    eq.upsert(np.arange(n_units), eqp, np.arange(n_units, dtype=np.int64))
    qu = InMemoryTable(1 << max(10, (4 * n_prod).bit_length()))
    qp = np.zeros((n_prod, 8), np.float32)
    qp[:, 3] = np.arange(n_prod)
    qp[:, 4] = rng.integers(0, 3, n_prod)
    qu.upsert(np.arange(n_prod), qp, np.arange(n_prod, dtype=np.int64))
    return eq, qu


def measure_round_trips(backend: str = "jax", n: int = 2048,
                        n_units: int = 20) -> Dict:
    """Count dispatches + blocking host syncs over one BI step (one fact
    block through transform -> per-unit rollup -> serving fold) for the
    pre-PR op sequence vs the device plane."""
    rng = np.random.default_rng(0)
    eq, qu = _bench_tables(rng, n_units, 4 * n)
    prod = np.zeros((n, 8), np.float32)
    prod[:, 0] = rng.integers(0, 4 * n, n)
    prod[:, 1] = rng.integers(0, n_units, n)
    prod[:, 3] = rng.uniform(0, 50, n)
    prod[:, 4] = prod[:, 3] + rng.uniform(1, 30, n)
    prod[:, 5] = rng.uniform(1, 100, n)
    be = get_backend(backend)

    # warm every jit outside the counted window
    be.transform(prod, eq, qu)
    be.transform_and_rollup(prod, eq, qu, n_units=n_units).to_host()
    facts_w, found_w = be.transform(prod, eq, qu)
    be.segment_reduce(facts_w[found_w], n_units)
    be.fold_segments(facts_w[found_w][:, 0].astype(np.int64),
                     facts_w[found_w][:, 3:7], n_units)

    # ---- pre-PR: three separate ops, each ferrying the block H->D->H
    be.reset_stats()
    facts, found = be.transform(prod, eq, qu)            # trip 1: transform
    good = facts[found]
    be.segment_reduce(good, n_units)                     # trip 2: rollup
    be.fold_segments(good[:, 0].astype(np.int64),        # trip 3: view fold
                     good[:, 3:7], n_units)
    pre = {"dispatches": be.op_dispatches, "host_syncs": be.host_syncs}

    # ---- device plane: ONE fused dispatch; the block stays on device
    # until the load boundary (to_host = the step's single round trip);
    # the rollup rides the same dispatch and the same sync
    be.reset_stats()
    block = be.transform_and_rollup(prod, eq, qu,
                                    n_units=n_units).start_host_copy()
    before_load = {"dispatches": be.op_dispatches,
                   "host_syncs": be.host_syncs}
    h_facts, h_found = block.to_host()
    rollup = block.rollup_host()
    post = {"dispatches": be.op_dispatches, "host_syncs": be.host_syncs}

    # the serving fold is no longer on the worker step: it runs in the
    # maintenance stage, segment-compacted (counted separately)
    be.reset_stats()
    good2 = h_facts[h_found]
    be.fold_segments(good2[:, 0].astype(np.int64), good2[:, 3:7], n_units)
    fold = {"dispatches": be.op_dispatches, "host_syncs": be.host_syncs}

    np.testing.assert_allclose(rollup, be.segment_reduce(good2, n_units),
                               rtol=1e-5, atol=1e-4)
    return {
        "backend": backend, "block_rows": n, "n_units": n_units,
        "pre_pr_worker_step": pre,
        "device_plane_before_load": before_load,
        "device_plane_worker_step": post,
        "serving_fold_per_delta": fold,
        "round_trips_per_worker_step": {
            "pre": pre["host_syncs"], "post": post["host_syncs"]},
        "note": ("host_syncs = blocking device->host materializations per "
                 "worker step (transform + per-unit rollup + load "
                 "boundary). The serving-layer fold keeps one compacted "
                 "trip per delta in its own maintenance stage."),
    }


# ======================================================== 2. sustained A/B
# Both arms run the full single-worker BI-SERVING step the paper's
# deployment needs (and examples/steelworks_etl.py runs): transform the
# fetched block, load it, maintain the per-unit KPI aggregate, fold the
# delta into every steelworks report view. The pre-PR op sequence ferries
# the block host<->device three times per step (transform sync, separate
# segment_reduce, full-width view folds); the device plane does ONE fused
# dispatch + ONE sync at the load boundary and folds compacted.

def _make_views(wl: Workload):
    """The steelworks report suite plus a long-horizon dashboard view:
    per-shift production-rate windows over a 288-window ring (~2 weeks of
    4000-tick shifts). The workload's event time advances wave over wave,
    so each delta lands in the ~20 newest windows of 288 — long-horizon
    windowed views are SPARSE per delta by construction, which is what
    segment compaction exploits: the pre-PR fold ran the halving tree
    over all 288 columns for every delta."""
    import dataclasses as _dc

    from repro.serving import production_rate_windows, steelworks_views
    views = list(steelworks_views(wl.n_partitions))
    views.append(_dc.replace(
        production_rate_windows(n_windows=288, window_len=4000.0),
        name="production_rate_shift_ring"))
    return tuple(views)


def _fold_into(states, views, good, fold_fn):
    from repro.core.backend import combine_fold
    for spec in views:
        agg = fold_fn(spec.segments(good), spec.values(good),
                      spec.n_segments)
        states[spec.name] = combine_fold(states[spec.name], agg)


def _fresh_states(views):
    from repro.core.backend import empty_fold_state
    return {s.name: empty_fold_state(s.n_segments, s.n_lanes)
            for s in views}


def _warm_fold_shapes(views, be) -> None:
    """Compile the fold buckets the measured loops hit (jit caches are
    process-global, so this runs once): every row bucket at full
    coverage — compacted op AND uncompacted reproduction — plus the
    sparse width ladder at the big buckets steady-state deltas produce.
    Rare unlisted shapes (tiny retry sweeps) compile small, cheap trees
    on first hit in either arm."""
    from repro.core.backend import FOLD_BLOCK
    for spec in {(s.n_segments, s.n_lanes) for s in views}:
        S, L = spec
        m = 8
        while m <= FOLD_BLOCK:
            vals = np.zeros((m, L), np.float32)
            be.fold_segments(np.arange(m, dtype=np.int64) % S, vals, S)
            _uncompacted_fold_jax(np.arange(m, dtype=np.int64) % S, vals, S)
            m *= 2
        for m in (FOLD_BLOCK // 2, FOLD_BLOCK):
            vals = np.zeros((m, L), np.float32)
            width = 8
            while width < S:
                be.fold_segments(np.arange(m, dtype=np.int64) % width,
                                 vals, S)
                width *= 2


def _pre_pr_sequential(wl: Workload, views) -> Dict:
    """THE reference of this PR: the pre-PR coalesced single-worker round
    loop — one transform dispatch per step with an IMMEDIATE blocking host
    sync (`sequential.1_coalesced` of benchmarks.sustained_load as of the
    previous PR), plus the pre-PR BI epilogue per step: a separate
    ``segment_reduce`` dispatch for the per-unit KPI aggregate and
    full-width (uncompacted) view folds of the loaded delta."""
    cfg, src, sampler = seed_source(wl)
    pipe = DODETLPipeline(cfg, src, n_workers=1, join_depth=wl.join_depth)
    for w in pipe.workers:          # pre-PR dispatch: facts only, no fused
        w.transformer.n_units = None    # rollup riding the kernel
    prewarm(pipe, wl)
    be = pipe.backend
    cap = wl.cap_for(1)
    w = pipe.workers[0]
    tr = w.transformer
    states = _fresh_states(views)
    kpi_agg = np.zeros((cfg.n_business_keys, 5), np.float32)
    feeder = threading.Thread(target=feed_waves, args=(sampler, src, wl))
    total, stalls = 0, 0
    t0 = time.perf_counter()
    feeder.start()
    while total < wl.total_ops and stalls < 200:
        pipe.extract()
        w.pump_master(pipe.master_topic_map["equipment"], w.equipment)
        w.pump_master(pipe.master_topic_map["quality"], w.quality)
        stepped = 0
        for topic in pipe.operational_topics:
            batch, counts = pipe.queue.consume_many(
                w.group, topic, w.partitions, cap)
            for p, c in counts.items():
                pipe.queue.commit(w.group, topic, p, c)
            block, merged = tr.process_block(batch)
            if block is None:
                continue
            good, _ = tr.finish(block, merged)   # trip 1: immediate sync
            w.warehouse.load_partitioned(good, cfg.n_partitions)
            if len(good):
                kpi_agg += be.segment_reduce(good,   # trip 2: rollup
                                             cfg.n_business_keys)
                _fold_into(states, views, good,      # trip 3: view folds
                           _uncompacted_fold_jax)
            stepped += len(good)
        total += stepped
        stalls = stalls + 1 if stepped == 0 else 0
    wall = time.perf_counter() - t0
    feeder.join()
    return {"records": total, "wall_s": round(wall, 4),
            "records_s": round(total / wall) if wall else 0,
            "kpi_rows": int(kpi_agg[:, 4].sum()),
            "view_rows": int(states[views[0].name][:, 0].sum())}


def _device_plane_sequential(wl: Workload, views) -> Dict:
    """The device-plane single-worker loop: ONE fused transform+rollup
    dispatch per step, block handed forward DEVICE-RESIDENT with its D2H
    copy enqueued asynchronously; the PREVIOUS step's block materializes
    at its load boundary — so device compute + copy overlap the load-side
    host work (the same overlap the concurrent runtime's transform->load
    stages get from threads) — and the view folds run segment-compacted."""
    cfg, src, sampler = seed_source(wl)
    pipe = DODETLPipeline(cfg, src, n_workers=1, join_depth=wl.join_depth)
    prewarm(pipe, wl)
    be = pipe.backend
    cap = wl.cap_for(1)
    w = pipe.workers[0]
    tr = w.transformer
    states = _fresh_states(views)
    feeder = threading.Thread(target=feed_waves, args=(sampler, src, wl))
    total, stalls = 0, 0
    pending = None                  # (block, merged batch) of step N-1

    def retire(p):
        block, merged = p
        good, _ = tr.finish(block, merged)      # the ONE sync, at load
        w.warehouse.load_partitioned(good, cfg.n_partitions,
                                     rollup=block.rollup_host())
        if len(good):
            _fold_into(states, views, good, be.fold_segments)
        return len(good)

    t0 = time.perf_counter()
    feeder.start()
    while total < wl.total_ops and stalls < 200:
        pipe.extract()
        w.pump_master(pipe.master_topic_map["equipment"], w.equipment)
        w.pump_master(pipe.master_topic_map["quality"], w.quality)
        stepped = 0
        for topic in pipe.operational_topics:
            batch, counts = pipe.queue.consume_many(
                w.group, topic, w.partitions, cap)
            for p, c in counts.items():
                pipe.queue.commit(w.group, topic, p, c)
            block, merged = tr.process_block(batch)
            if block is not None:
                block.start_host_copy()         # D2H rides the compute
            if pending is not None:
                stepped += retire(pending)      # overlaps block's compute
                pending = None
            if block is not None:
                pending = (block, merged)
        if pending is not None and stepped == 0:
            stepped += retire(pending)          # drain when idle
            pending = None
        total += stepped
        stalls = stalls + 1 if stepped == 0 else 0
    if pending is not None:
        total += retire(pending)
    wall = time.perf_counter() - t0
    feeder.join()
    running = w.warehouse.kpi_running()
    return {"records": total, "wall_s": round(wall, 4),
            "records_s": round(total / wall) if wall else 0,
            "kpi_rows": int(running[:, 4].sum())
            if running is not None else -1,
            "view_rows": int(states[views[0].name][:, 0].sum())}


def _concurrent_serving(wl: Workload, views) -> Dict:
    """The SHIPPED device-plane deployment, single worker: the
    ``ConcurrentCluster`` hot path (fused transform+rollup dispatch in the
    transform stage, device block handed to the load stage, one sync at
    the load boundary) with the ``MaterializedViewEngine`` attached — the
    same steelworks views, folded segment-compacted by the maintenance
    stage. The wall clock runs until the stream is drained AND the fold
    backlog is empty, so the serving work is fully charged."""
    from repro.runtime.cluster import ConcurrentCluster
    from repro.serving import MaterializedViewEngine
    cfg, src, sampler = seed_source(wl)
    pipe = DODETLPipeline(cfg, src, n_workers=1, join_depth=wl.join_depth)
    prewarm(pipe, wl)
    engine = MaterializedViewEngine(views, backend=wl.backend)
    cluster = ConcurrentCluster(pipe, max_records_per_partition=wl.cap_for(1),
                                serving=engine)
    feeder = threading.Thread(target=feed_waves, args=(sampler, src, wl))
    t0 = time.perf_counter()
    cluster.start()
    feeder.start()
    feeder.join()
    done = cluster.run_until_idle(timeout=600.0)
    deadline = time.perf_counter() + 60.0
    while engine.pending() and time.perf_counter() < deadline:
        time.sleep(0.001)            # charge the fold backlog to the wall
    wall = time.perf_counter() - t0
    cluster.stop_all()
    snap = engine.snapshot()
    return {"records": done, "wall_s": round(wall, 4),
            "records_s": round(done / wall) if wall else 0,
            "complete": done == wl.total_ops,
            "rows_folded": snap.rows_folded,
            "view_rows": int(snap.states[views[0].name].count.sum())}


def measure_sustained(wl: Workload, repeats: int) -> Dict:
    """Interleaved paired cycles: (A, B, C) adjacent in time per cycle,
    every arm doing the full BI-serving work (transform + per-unit KPI
    aggregate + view folds). Headline = median per-cycle C/A ratio — the
    shipped device-plane runtime against the pre-PR op sequence; B/A
    isolates the device plane in a single thread."""
    views = _make_views(wl)
    _warm_fold_shapes(views, get_backend(wl.backend))
    cycles = []
    for _ in range(repeats):
        a = _pre_pr_sequential(wl, views)
        b = _device_plane_sequential(wl, views)
        c = _concurrent_serving(wl, views)
        cycles.append({
            "pre_pr_coalesced": a, "device_plane": b,
            "concurrent_serving_1w": c,
            "device_plane_vs_pre_pr":
                round(b["records_s"] / max(a["records_s"], 1), 3),
            "concurrent_serving_vs_pre_pr":
                round(c["records_s"] / max(a["records_s"], 1), 3),
        })

    def med(key):
        rs = sorted(cy[key] for cy in cycles)
        return rs[len(rs) // 2]

    return {
        "workload": {**dataclasses.asdict(wl), "total_ops": wl.total_ops},
        "cycles": cycles,
        "paired_median_device_plane_vs_pre_pr":
            med("device_plane_vs_pre_pr"),
        "paired_median_concurrent_serving_vs_pre_pr":
            med("concurrent_serving_vs_pre_pr"),
        "note": ("single-worker BI-serving pipeline (transform + per-unit "
                 "KPI aggregate + steelworks view folds of every delta). "
                 "A = pre-PR op sequence (immediate sync, separate "
                 "segment_reduce dispatch, full-width folds — 3 block "
                 "round trips/step), B = device-plane loop in one thread "
                 "(one fused dispatch, one load-boundary sync, compacted "
                 "folds), C = the SHIPPED single-worker ConcurrentCluster "
                 "with the serving engine attached (same folds in the "
                 "maintenance stage; wall includes draining the fold "
                 "backlog). Interleaved A,B,C per cycle; medians of "
                 "paired per-cycle ratios"),
    }


# ====================================================== 3. fold compaction
def _uncompacted_fold_jax(seg, vals, n_segments):
    """Verbatim reproduction of the pre-compaction fold driver: the jitted
    halving tree over the FULL [block, n_segments, lanes] range."""
    from repro.core.backend import (FOLD_BLOCK, _fold_tree_jnp, combine_fold,
                                    empty_fold_state)
    import jax.numpy as jnp
    seg = np.asarray(seg, np.int64)
    vals = np.asarray(vals, np.float32)
    n, L = vals.shape
    out = empty_fold_state(n_segments, L)
    for lo in range(0, n, FOLD_BLOCK):
        s = seg[lo:lo + FOLD_BLOCK]
        v = vals[lo:lo + FOLD_BLOCK]
        m = len(s)
        bucket = max(8, 1 << (m - 1).bit_length())
        if bucket != m:
            s = np.concatenate([s, np.full(bucket - m, -1, np.int64)])
            v = np.concatenate([v, np.zeros((bucket - m, L), np.float32)])
        out = combine_fold(out, np.asarray(_fold_tree_jnp(
            jnp.asarray(s, jnp.int32), jnp.asarray(v), n_segments)))
    return out


def measure_fold_compaction(repeats: int = 5, n_rows: int = 4096,
                            n_segments: int = 256, lanes: int = 4) -> Dict:
    """Sparse deltas (the serving layer's common case: one worker's delta
    touches its own partitions' segments only) folded compacted vs the
    uncompacted reproduction — paired per-repeat ratios + bitwise check."""
    be = get_backend("jax")
    rng = np.random.default_rng(1)
    out = {"n_rows": n_rows, "n_segments": n_segments, "lanes": lanes,
           "sparsity": {}}
    for n_active in (1, 2, 8, n_segments):
        live = rng.choice(n_segments, n_active, replace=False)
        seg = rng.choice(live, n_rows)
        vals = rng.normal(size=(n_rows, lanes)).astype(np.float32)
        # warm both jit shapes, verify bitwise equality once
        compacted = be.fold_segments(seg, vals, n_segments)
        reference = _uncompacted_fold_jax(seg, vals, n_segments)
        bitwise = compacted.tobytes() == reference.tobytes()
        ratios = []
        for _ in range(repeats):              # paired, interleaved
            t0 = time.perf_counter()
            _uncompacted_fold_jax(seg, vals, n_segments)
            t_un = time.perf_counter() - t0
            t0 = time.perf_counter()
            be.fold_segments(seg, vals, n_segments)
            t_co = time.perf_counter() - t0
            ratios.append(t_un / max(t_co, 1e-9))
        ratios.sort()
        out["sparsity"][str(n_active)] = {
            "bitwise_equal": bool(bitwise),
            "median_paired_speedup": round(ratios[len(ratios) // 2], 2),
            "paired_speedups": [round(r, 2) for r in ratios],
        }
    return out


# ================================================================== driver
def summary(quick: bool = True) -> Dict:
    """Fast counter summary for benchmarks.run (no sustained sweep)."""
    rt = measure_round_trips(n=1024 if quick else 2048)
    fold = measure_fold_compaction(repeats=3 if quick else 5,
                                   n_rows=2048 if quick else 4096)
    sparse = fold["sparsity"]["2"]
    return {
        "round_trips_pre": rt["round_trips_per_worker_step"]["pre"],
        "round_trips_post": rt["round_trips_per_worker_step"]["post"],
        "fold_compaction_speedup_2_of_256":
            sparse["median_paired_speedup"],
        "fold_bitwise_equal": sparse["bitwise_equal"],
    }


def main() -> None:
    import sys
    sys.setswitchinterval(0.02)     # same rationale as sustained_load
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI harness check)")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--join-depth", type=int, default=None)
    ap.add_argument("--dispatch", type=int, default=8192)
    ap.add_argument("--backend", default="jax")
    ap.add_argument("--out", default="BENCH_dispatch.json")
    args = ap.parse_known_args()[0]

    if args.smoke:
        wl = Workload(n_base=800, waves=2, chunk=800, n_partitions=8,
                      join_depth=args.join_depth or 2,
                      backend=args.backend, dispatch=args.dispatch)
        repeats = args.repeats or 1
    else:
        # join_depth 2 ~ the paper's SIMPLE process-specific model (its
        # default deployment): the BI epilogue this suite measures is a
        # realistic fraction of the step. --join-depth 8/32 replays the
        # normalized ISA-95 cost profile where the transform dominates.
        # Shorter runs (60 waves) x more cycles beat the host's
        # seconds-timescale drift better than few long runs.
        wl = Workload(waves=60, join_depth=args.join_depth or 2,
                      backend=args.backend, dispatch=args.dispatch)
        repeats = args.repeats or 9

    results = {
        "host": {"cores": os.cpu_count()},
        "round_trips": measure_round_trips(backend=args.backend),
        "fold_compaction": measure_fold_compaction(
            repeats=3 if args.smoke else 7),
        "sustained": measure_sustained(wl, repeats),
    }
    rt = results["round_trips"]["round_trips_per_worker_step"]
    print(f"round trips per worker step: {rt['pre']} -> {rt['post']}")
    print(f"fold compaction: {results['fold_compaction']['sparsity']}")
    su = results["sustained"]
    print(f"sustained paired medians vs pre-PR coalesced loop: "
          f"device-plane {su['paired_median_device_plane_vs_pre_pr']}x, "
          f"shipped concurrent+serving "
          f"{su['paired_median_concurrent_serving_vs_pre_pr']}x")
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
