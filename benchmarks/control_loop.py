"""Self-healing control plane benchmark → ``BENCH_control.json``.

Three questions about the control plane (ARCHITECTURE.md "Control
plane"), each with a CI-gated answer:

* **Spike** — the autonomous elastic loop, end to end: a single-worker
  cluster serves a gently paced CDC stream (pre-spike freshness p95 is
  sampled), then a burst far above the per-worker backlog threshold
  lands at once. The controller must scale the cluster up on its own —
  zero human calls — drain the burst exactly-once, and once the backlog
  is gone the steady-state freshness p95 must return to within 2x the
  pre-spike figure (noise-floored: sub-floor percentiles compare against
  the floor, not against scheduler jitter).
* **Detection** — the grey-failure drill at benchmark scale: one stage
  thread freezes mid-stream; the supervisor must notice the silent
  heartbeat, confirm via in-band ping, force-evict (fencing the zombie's
  consumer group) and restart a re-hydrated replacement. Gated on the
  detection latency (hang instant -> eviction, bounded by the configured
  deadline + grace + supervision ticks) and on the healed stream being
  byte-identical to an uninterrupted sequential oracle.
* **Poison** — a deterministically failing record must be bisected out,
  quarantined in the dead-letter buffer with its offsets COMMITTED, and
  everything else must load — with zero evictions and zero restarts
  (quarantine, not crash-loop).

    PYTHONPATH=src python -m benchmarks.control_loop [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
import warnings
from typing import Dict

import numpy as np

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.durability.faults import TRANSFORM_DONE, FaultInjector
from repro.runtime.cluster import ConcurrentCluster
from repro.runtime.control import ControlConfig, QuiesceTimeoutWarning

N_PARTITIONS = 8
SEED = 11

# the test-suite supervision cadence: sub-second detection without
# flapping on a loaded CI box
FAST = dict(tick_s=0.02, heartbeat_deadline_s=0.4, ping_grace_s=0.2,
            warmup_s=0.2, restart_backoff_s=0.05, restart_backoff_max_s=0.5,
            restart_jitter_s=0.02, policy_interval_s=0.1,
            evict_lock_timeout_s=0.5, evict_join_timeout_s=0.5,
            scaling=False)

# freshness percentiles below this are scheduler noise on the numpy
# backend: the recovery gate compares against max(pre_p95, floor)
FRESHNESS_FLOOR_MS = 25.0


def build(n: int, *, n_workers: int = 1, late_frac: float = 0.0,
          fault=None, seed: int = SEED, tables=None):
    cfg = steelworks_config(n_partitions=N_PARTITIONS, backend="numpy")
    cfg = dataclasses.replace(cfg, buffer_capacity=65536)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n, n_equipment=N_PARTITIONS,
        late_master_frac=late_frac, seed=seed))
    sampler.generate(src, tables=tables)
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers, fault=fault)
    return cfg, src, pipe, sampler


def _oracle_facts(n: int, late_frac: float = 0.0) -> bytes:
    """Byte-level fact table of an uninterrupted single-worker run."""
    _, _, pipe, _ = build(n, late_frac=late_frac)
    pipe.extract()
    pipe.bootstrap_caches()
    pipe.run_to_completion()
    return pipe.warehouse.canonical_fact_table().tobytes()


def _stop_quietly(cluster) -> None:
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", QuiesceTimeoutWarning)
        cluster.stop_all()


# --------------------------------------------------------------------- spike
# this repo's synthetic numpy transform is deliberately cheap (one worker
# drains a 4k burst in ~10 ms — no backlog ever survives the policy's
# hysteresis window), so the spike arm emulates a production-cost
# transform: a fixed per-record delay on every transform dispatch
SPIKE_COST_PER_RECORD_S = 2e-4          # ~5k records/s per worker


def _slow_transform(worker, per_record_s: float) -> None:
    orig = worker.transformer.transform_block

    def wrapped(batch, eq, qu):
        time.sleep(per_record_s * len(batch))
        return orig(batch, eq, qu)

    worker.transformer.transform_block = wrapped


def _feed(sampler, src, cluster, batches: int, per: int,
          interval_s: float) -> int:
    """Paced CDC feeder: publish `per` production records, let the live
    extract loop tail them, sleep, repeat. Returns records fed."""
    for _ in range(batches):
        sampler.generate(src, n_per_table=per, tables=("production",))
        time.sleep(interval_s)
    cluster.run_until_idle(timeout=120)
    return batches * per


def bench_spike(n0: int, burst: int, *, pace_batches: int = 5,
                pace_per: int = 120, pace_interval_s: float = 0.15,
                max_workers: int = 3) -> Dict:
    """Load-spike drill: paced stream -> burst -> autonomous scale-up ->
    drain -> paced stream again. Gates on the controller acting by
    itself, exactly-once completion, and steady-state freshness p95
    recovering to within 2x the pre-spike figure.

    ``n0`` must be >= ``burst``: the seed generate creates the master
    rows (quality inspections join per prod_id) for prod_ids 0..n0-1,
    and the sampler's follow-up production-only waves reuse prod_ids
    0..k-1 — a wave larger than the seeded key space would late-buffer
    forever. Only MASTER tables are seeded (the production stream
    arrives exclusively through the paced feed), so the burst is the
    one backlog event the controller ever sees."""
    assert n0 >= burst and n0 >= pace_per
    ctl = ControlConfig(**{**FAST, "scaling": True,
                           "policy_interval_s": 0.05,
                           "hysteresis_samples": 2, "cooldown_s": 0.3,
                           "backlog_high_per_worker": 500,
                           "backlog_low_per_worker": 0,
                           "scale_down": False, "repartition": False,
                           "max_workers": max_workers})
    cfg, src, pipe, sampler = build(n0, n_workers=1,
                                    tables=("equipment", "quality"))
    for w in pipe.workers:
        _slow_transform(w, SPIKE_COST_PER_RECORD_S)
    orig_new_worker = pipe._new_worker

    def _new_worker(name, join_depth):        # controller-spawned workers
        w = orig_new_worker(name, join_depth)  # carry the same cost model
        _slow_transform(w, SPIKE_COST_PER_RECORD_S)
        return w

    pipe._new_worker = _new_worker
    cluster = ConcurrentCluster(pipe, max_records_per_partition=100,
                                poll_cdc=True, control=ctl)
    cluster.start()
    total = 0
    cluster.run_until_idle(timeout=120)           # pump the master seed
    # two unmeasured waves warm the cold code paths (first-dispatch cost
    # would inflate the pre-spike p95 and soften the recovery gate)
    total += _feed(sampler, src, cluster, 2, pace_per, pace_interval_s)

    # phase A: gentle paced stream — the pre-spike freshness window
    cluster.freshness(drain=True)                 # discard warmup samples
    total += _feed(sampler, src, cluster, pace_batches, pace_per,
                   pace_interval_s)
    pre = cluster.freshness(drain=True)
    workers_pre = len(cluster.alive_workers())

    # phase B: the burst, all at once
    sampler.generate(src, n_per_table=burst, tables=("production",))
    total += burst
    t0 = time.perf_counter()
    cluster.run_until_idle(timeout=300)
    t_drain = time.perf_counter() - t0
    workers_post = len(cluster.alive_workers())
    cluster.freshness(drain=True)                 # discard the spike window

    # phase C: gentle paced stream again — post-recovery steady state
    total += _feed(sampler, src, cluster, pace_batches, pace_per,
                   pace_interval_s)
    post = cluster.freshness(drain=True)
    snap = cluster.control.snapshot()
    _stop_quietly(cluster)

    pre95 = max(float(pre["p95_ms"]), FRESHNESS_FLOOR_MS)
    post95 = max(float(post["p95_ms"]), FRESHNESS_FLOOR_MS)
    out = {
        "master_key_space": int(n0),
        "burst_records": int(burst),
        "total_records": int(total),
        "rows_loaded": int(pipe.warehouse.rows_loaded),
        "workers_pre_spike": int(workers_pre),
        "workers_post_spike": int(workers_post),
        "scale_ups": int(snap["scale_ups"]),
        "human_calls": 0,                          # autonomous by construction
        "burst_drain_wall_s": round(t_drain, 3),
        "freshness_pre_p95_ms": round(float(pre["p95_ms"]), 3),
        "freshness_post_p95_ms": round(float(post["p95_ms"]), 3),
        "freshness_floor_ms": FRESHNESS_FLOOR_MS,
        "recovery_ratio": round(post95 / pre95, 3),
        "complete": bool(pipe.warehouse.rows_loaded == total),
        "controller_acted": bool(snap["scale_ups"] >= 1
                                 and workers_post > workers_pre),
        "spike_recovered": bool(post95 <= 2.0 * pre95),
        "controller_crashed": bool(snap["crashed"]),
    }
    print(f"  spike: {total} records, burst {burst} drained in "
          f"{t_drain:.2f}s, workers {workers_pre}->{workers_post} "
          f"({snap['scale_ups']} scale-ups, 0 human calls), freshness p95 "
          f"{out['freshness_pre_p95_ms']} -> {out['freshness_post_p95_ms']} "
          f"ms (ratio {out['recovery_ratio']})")
    return out


# ----------------------------------------------------------------- detection
def bench_detection(n: int) -> Dict:
    """Grey-failure drill: hang a transform stage mid-stream, measure the
    supervisor's hang->eviction latency, verify the healed stream is
    byte-identical to the uninterrupted sequential oracle."""
    fault = FaultInjector({TRANSFORM_DONE: 3},
                          actions={TRANSFORM_DONE: "hang"})
    cfg, _, pipe, _ = build(n, n_workers=3, fault=fault)
    pipe.extract()
    cluster = ConcurrentCluster(pipe, poll_cdc=False,
                                max_records_per_partition=25,
                                control=ControlConfig(**FAST))
    cluster.start()
    assert fault.hung.wait(20.0), "hang seam never reached"
    done = cluster.run_until_idle(timeout=120)
    _stop_quietly(cluster)
    fault.release_hangs()

    ev = cluster.control.last_eviction
    latency = (ev["at_s"] - fault.hung_at_s) if ev else float("inf")
    bound = (FAST["heartbeat_deadline_s"] + FAST["ping_grace_s"]
             + 10 * FAST["tick_s"] + 2 * FAST["evict_join_timeout_s"] + 1.5)
    identical = (pipe.warehouse.canonical_fact_table().tobytes()
                 == _oracle_facts(n))
    snap = cluster.control.snapshot()
    out = {
        "records": int(n),
        "heartbeat_deadline_s": FAST["heartbeat_deadline_s"],
        "latency_s": round(latency, 3),
        "latency_bound_s": round(bound, 3),
        "evictions": int(snap["evictions"]),
        "restarts": int(snap["restarts"]),
        "rows_loaded": int(pipe.warehouse.rows_loaded),
        "complete": bool(done == n and pipe.warehouse.rows_loaded == n),
        "detection_within_bound": bool(ev is not None
                                       and 0 < latency < bound),
        "byte_identical": bool(identical),
        "restart_ok": bool(ev is not None and ev["restarted"]),
    }
    print(f"  detection: hang -> eviction in {out['latency_s']}s "
          f"(bound {out['latency_bound_s']}s), restarted="
          f"{out['restart_ok']}, byte_identical={identical}")
    return out


# -------------------------------------------------------------------- poison
class _PoisonError(Exception):
    pass


def _poison_transform(worker, key: int) -> None:
    orig = worker.transformer.transform_block

    def wrapped(batch, eq, qu):
        if np.any(batch.business_key == key):
            raise _PoisonError(f"poison key {key}")
        return orig(batch, eq, qu)

    worker.transformer.transform_block = wrapped


def bench_poison(n: int, bad_key: int = 3) -> Dict:
    """Poison-record drill: quarantine to the dead-letter buffer with
    committed offsets; zero evictions, zero restarts, no crash loop."""
    cfg, _, pipe, _ = build(n, n_workers=2)
    for w in pipe.workers:
        _poison_transform(w, bad_key)
    pipe.extract()
    cluster = ConcurrentCluster(pipe, poll_cdc=False,
                                control=ControlConfig(**FAST))
    cluster.start()
    cluster.run_until_idle(timeout=120)
    cluster.stop_all()

    quarantined = sum(len(rt.worker.dead_letter)
                      for rt in cluster.runtimes.values())
    snap = cluster.control.snapshot()
    out = {
        "records": int(n),
        "quarantined": int(quarantined),
        "rows_loaded": int(pipe.warehouse.rows_loaded),
        "residual_lag": int(cluster._operational_lag()),
        "evictions": int(snap["evictions"]),
        "restarts": int(snap["restarts"]),
        "breaker_open": bool(snap["breaker_open"]),
        "poison_quarantined": bool(
            quarantined > 0
            and pipe.warehouse.rows_loaded == n - quarantined
            and cluster._operational_lag() == 0),
        "no_crash_loop": bool(snap["restarts"] == 0
                              and snap["evictions"] == 0
                              and not snap["breaker_open"]),
    }
    print(f"  poison: {quarantined} quarantined, "
          f"{out['rows_loaded']}/{n} clean rows loaded, "
          f"restarts={out['restarts']}, lag={out['residual_lag']}")
    return out


# ------------------------------------------------------------------- drivers
def summary(quick: bool = False) -> Dict[str, float]:
    """Small single-cycle figures for ``benchmarks.run``."""
    n = 1_500 if quick else 3_000
    det = bench_detection(n)
    poi = bench_poison(n)
    return {
        "detection_latency_s": det["latency_s"],
        "detection_within_bound": int(det["detection_within_bound"]),
        "byte_identical": int(det["byte_identical"]),
        "poison_quarantined": int(poi["poison_quarantined"]),
        "no_crash_loop": int(poi["no_crash_loop"]),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: small streams, one cycle per arm")
    ap.add_argument("--out", default="BENCH_control.json")
    args = ap.parse_known_args()[0]

    if args.smoke:
        n0, burst, n_det, n_poi = 4_000, 4_000, 2_500, 2_500
    elif args.quick:
        n0, burst, n_det, n_poi = 8_000, 8_000, 4_000, 4_000
    else:
        n0, burst, n_det, n_poi = 16_000, 16_000, 8_000, 8_000

    results = {
        "workload": {
            "n_partitions": N_PARTITIONS,
            "spike_master_key_space": n0, "spike_burst_records": burst,
            "detection_records": n_det, "poison_records": n_poi,
            "note": ("spike runs a live paced CDC feed through the real "
                     "ConcurrentCluster with the autonomous controller; "
                     "detection/poison run pre-extracted streams so a "
                     "byte-identity oracle exists — on the noisy shared "
                     "container only the ratios and boolean contracts "
                     "are meaningful (docs/BENCHMARKS.md)"),
        },
    }
    print("spike: paced stream -> burst -> autonomous scale-up -> recovery")
    results["spike"] = bench_spike(n0, burst)
    print("detection: hung stage -> supervised evict + restart")
    results["detection"] = bench_detection(n_det)
    print("poison: deterministic bad record -> dead-letter quarantine")
    results["poison"] = bench_poison(n_poi)

    sp, det, poi = results["spike"], results["detection"], results["poison"]
    results["gates"] = {
        "complete": bool(sp["complete"] and det["complete"]),
        "controller_acted": bool(sp["controller_acted"]),
        "spike_recovered": bool(sp["spike_recovered"]),
        "human_calls_zero": bool(sp["human_calls"] == 0
                                 and not sp["controller_crashed"]),
        "detection_within_bound": bool(det["detection_within_bound"]),
        "byte_identical": bool(det["byte_identical"]),
        "restart_ok": bool(det["restart_ok"]),
        "poison_quarantined": bool(poi["poison_quarantined"]),
        "no_crash_loop": bool(poi["no_crash_loop"]),
    }
    print("gates:", results["gates"])

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
