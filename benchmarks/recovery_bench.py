"""Crash-recovery benchmark → ``BENCH_recovery.json``.

Three questions about the durability layer (ARCHITECTURE.md "Durability &
recovery"), each with a CI-gated answer:

* **Scaling** — recovery work must be O(suffix since the last
  checkpoint), never O(history). Histories of growing length are run
  with a FIXED checkpoint cadence, then recovered from the journal; the
  serving replay suffix (``replayed_chunks``) stays bounded while the
  chunk log grows, so the replay *fraction* falls — the structural
  sublinearity gate. Wall-clock recovery is compared against the cold
  alternative (re-extract + re-transform + re-fold the whole stream from
  the CDC log): ``recovery_speedup_vs_cold`` grows with history and
  gates as a host-relative paired ratio.
* **Overhead** — what the periodic checkpointer costs a sustained
  concurrent run: paired cycles of the same workload through the real
  ``ConcurrentCluster`` with and without a ``checkpoint_every_s``
  thread, adjacent in time; the gate is the median paired wall ratio
  (with / without — lower is better).
* **Kill -9** — the real thing, not an in-process analogue: a child
  process runs the pipeline with a ``mode="sigkill"`` injector armed at
  the load/commit seam and is destroyed by the kernel mid-stream; the
  parent recovers from the journal the corpse left behind, finishes the
  stream, and verifies the warehouse is byte-identical to an
  uninterrupted oracle — exactly-once through an actual SIGKILL.

    PYTHONPATH=src python -m benchmarks.recovery_bench [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal
import subprocess
import sys
import tempfile
import time
from typing import Dict, Optional

import numpy as np

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.durability import (DurabilityJournal, FaultInjector,
                              InjectedCrash, RecoveryCoordinator,
                              recover_pipeline)
from repro.durability.faults import LOAD_PRE_COMMIT
from repro.runtime.cluster import ConcurrentCluster
from repro.serving.engine import MaterializedViewEngine
from repro.serving.views import steelworks_views

N_PARTITIONS = 8
N_WORKERS = 2
SEED = 7


def build(n: int, seed: int = SEED, fault=None, join_depth=1):
    cfg = steelworks_config(n_partitions=N_PARTITIONS, backend="numpy")
    cfg = dataclasses.replace(cfg, buffer_capacity=65536)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n, n_equipment=N_PARTITIONS,
        late_master_frac=0.1, seed=seed))
    sampler.generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=N_WORKERS, fault=fault,
                          join_depth=join_depth)
    eng = MaterializedViewEngine(steelworks_views(cfg.n_business_keys),
                                 backend="numpy")
    pipe.warehouse.attach_serving(eng)
    return cfg, src, pipe, eng, sampler


def drive(pipe, eng, coord=None, ckpt_every=4, extract_per=400, cap=200,
          max_steps=2000):
    """Deterministic incremental loop (the test-suite drill loop at
    benchmark scale): extract a slice, one micro-batch step, fold views,
    checkpoint on cadence."""
    steps = stalls = 0
    log = pipe.source.log
    while steps < max_steps:
        steps += 1
        pipe.extract(extract_per)
        n = pipe.step(cap)
        eng.fold_pending()
        if coord is not None and steps % ckpt_every == 0:
            coord.checkpoint(pipe, engine=eng)
        lag = sum(max(0, log.next_lsn - l.offset)
                  for l in pipe.tracker.listeners)
        if lag > 0:
            stalls = 0
            continue
        if n == 0 and sum(len(w.buffer) for w in pipe.workers) == 0:
            break
        stalls = stalls + 1 if n == 0 else 0
        if stalls >= 3:
            break
    return steps


# ------------------------------------------------------------------- scaling
def bench_scaling(histories, suffix: int = 300, ckpt_every: int = 4) -> Dict:
    """Recovery cost vs history length with a FIXED un-checkpointed
    suffix: the checkpointed prefix grows, the post-checkpoint tail (the
    crash window recovery must re-process from the CDC log) stays
    constant. Recovery = journal restore + re-process the suffix; the
    cold alternative re-processes the WHOLE stream. Sublinearity shows
    up as the recovery/cold gap widening with history.

    Read the wall-clock ratio with care: this repo's synthetic transform
    is deliberately cheap and fully vectorized, so re-PROCESSING a
    record costs about the same as DECODING its journaled bytes — the
    ratio hovers near 1 and mostly measures npz decode speed. The
    architectural claim is the structural one (gated): re-processed work
    is bounded by the checkpoint gap while cold replay grows with
    history — with a production-cost transform the wall ratio follows.
    """
    jd = 1
    rows = []
    for n in histories:
        # phase 1: checkpointed prefix; phase 2: suffix after the last
        # checkpoint — production events the journal never saw
        cfg, src, pipe, eng, sampler = build(n, join_depth=jd)
        with tempfile.TemporaryDirectory() as root:
            coord = RecoveryCoordinator(DurabilityJournal(root))
            drive(pipe, eng, coord=coord, ckpt_every=ckpt_every)
            coord.checkpoint(pipe, engine=eng)       # last durable point
            sampler.generate(src, n_per_table=suffix,
                             tables=("production",))
            drive(pipe, eng)
            want = pipe.warehouse.canonical_fact_table().tobytes()
            seq = pipe.warehouse.commit_seq

            t0 = time.perf_counter()
            eng2 = MaterializedViewEngine(
                steelworks_views(cfg.n_business_keys), backend="numpy")
            pipe2, coord2, info = recover_pipeline(
                cfg, src, DurabilityJournal(root), engine=eng2,
                join_depth=jd)
            rows_at_restore = pipe2.warehouse.rows_loaded
            drive(pipe2, eng2)                       # re-process the tail
            t_recover = time.perf_counter() - t0
            assert info is not None
            assert pipe2.warehouse.canonical_fact_table().tobytes() == want

        # the cold alternative: no journal — re-run the whole stream
        cfg3, src3, pipe3, eng3, sampler3 = build(n, join_depth=jd)
        sampler3.generate(src3, n_per_table=suffix, tables=("production",))
        t0 = time.perf_counter()
        drive(pipe3, eng3)
        t_cold = time.perf_counter() - t0
        assert pipe3.warehouse.canonical_fact_table().tobytes() == want

        reproc = pipe2.warehouse.rows_loaded - rows_at_restore
        rows.append({
            "history_records": int(n * 3 + suffix),
            "commit_seq": int(seq),
            "restored_commit_seq": int(info["commit_seq"]),
            "reprocessed_rows": int(reproc),
            "reprocessed_fraction": round(
                reproc / pipe2.warehouse.rows_loaded, 4),
            "recover_wall_s": round(t_recover, 4),
            "cold_replay_wall_s": round(t_cold, 4),
            "speedup_vs_cold": round(t_cold / max(t_recover, 1e-9), 2),
        })
        print(f"  history {rows[-1]['history_records']}: recover+finish "
              f"{t_recover*1e3:.1f} ms (re-processed {reproc} of "
              f"{pipe2.warehouse.rows_loaded} rows), cold "
              f"{t_cold*1e3:.1f} ms -> {rows[-1]['speedup_vs_cold']}x")
    first, last = rows[0], rows[-1]
    return {
        "per_history": rows,
        "suffix_records": suffix,
        "ckpt_every_steps": ckpt_every,
        # structural sublinearity: re-processed work is set by the
        # checkpoint gap, not history length — its fraction of the
        # warehouse must FALL as the history grows
        "sublinear_ok": bool(
            last["reprocessed_fraction"] < 0.5
            and last["reprocessed_fraction"] < first["reprocessed_fraction"]
            and last["speedup_vs_cold"] >= first["speedup_vs_cold"]),
        "recovery_speedup_vs_cold": last["speedup_vs_cold"],
    }


# ------------------------------------------------------------------ overhead
def bench_overhead(n: int, cycles: int, every_s: float = 0.05) -> Dict:
    """Paired sustained cycles through the real concurrent runtime, with
    and without the periodic checkpointer thread."""
    ratios, walls = [], []
    steps_ckpt = 0
    for _ in range(cycles):
        pair = {}
        for arm in ("off", "on"):
            cfg, src, pipe, eng, _ = build(n)
            pipe.extract()
            root_ctx = tempfile.TemporaryDirectory()
            with root_ctx as root:
                coord = (RecoveryCoordinator(DurabilityJournal(root))
                         if arm == "on" else None)
                cluster = ConcurrentCluster(
                    pipe, max_records_per_partition=200, poll_cdc=False,
                    serving=eng, recovery=coord,
                    checkpoint_every_s=every_s if arm == "on" else None)
                t0 = time.perf_counter()
                cluster.start()
                cluster.run_until_idle(timeout=300)
                cluster.stop_all()
                pair[arm] = time.perf_counter() - t0
                if arm == "on":
                    steps_ckpt = len(coord.journal.steps())
                assert pipe.warehouse.rows_loaded == n
        ratios.append(pair["on"] / max(pair["off"], 1e-9))
        walls.append(pair)
    mid = sorted(range(cycles), key=lambda i: ratios[i])[cycles // 2]
    return {
        "records": int(n),
        "checkpoint_every_s": every_s,
        "journal_steps_written": int(steps_ckpt),
        "paired_wall_s": [{k: round(v, 4) for k, v in p.items()}
                          for p in walls],
        "paired_ratios": [round(r, 3) for r in ratios],
        "checkpoint_overhead_ratio": round(ratios[mid], 3),
    }


# -------------------------------------------------------------------- kill -9
def _child(root: str, n: int) -> None:
    """Child half of the kill-9 drill: run with a SIGKILL injector armed
    at the load/commit seam. This function does not return."""
    fault = FaultInjector({LOAD_PRE_COMMIT: 5}, mode="sigkill")
    cfg, src, pipe, eng, _ = build(n, fault=fault)
    coord = RecoveryCoordinator(DurabilityJournal(root, fault=fault))
    drive(pipe, eng, coord=coord, ckpt_every=2, extract_per=150, cap=60)
    # reaching here means the seam was never hit — fail loudly, not -9
    sys.exit(3)


def bench_kill9(n: int) -> Dict:
    """SIGKILL a child pipeline mid-stream, recover from its journal in
    the parent, verify exactly-once byte identity vs an oracle."""
    with tempfile.TemporaryDirectory() as root:
        env = dict(os.environ)
        src_dir = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.recovery_bench",
             "--child-kill9", root, "--n", str(n)],
            env=env, cwd=os.path.dirname(src_dir), capture_output=True,
            timeout=300)
        t_child = time.perf_counter() - t0
        killed = proc.returncode == -signal.SIGKILL
        steps_left = len(DurabilityJournal(root).steps())

        t0 = time.perf_counter()
        cfg, src, _, _, _ = build(n)
        eng2 = MaterializedViewEngine(steelworks_views(cfg.n_business_keys),
                                      backend="numpy")
        pipe2, coord2, info = recover_pipeline(
            cfg, src, DurabilityJournal(root), engine=eng2,
            n_workers=N_WORKERS)
        drive(pipe2, eng2, coord=coord2, ckpt_every=2, extract_per=150,
              cap=60)
        t_recover = time.perf_counter() - t0

    cfg_o, src_o, pipe_o, eng_o, _ = build(n)
    drive(pipe_o, eng_o, ckpt_every=2, extract_per=150, cap=60)
    identical = (pipe2.warehouse.canonical_fact_table().tobytes()
                 == pipe_o.warehouse.canonical_fact_table().tobytes())
    views_ok = all(
        eng2.snapshot().states[name].table.tobytes()
        == st.table.tobytes()
        for name, st in eng_o.snapshot().states.items())
    out = {
        "records": int(n),
        "child_killed_by_sigkill": bool(killed),
        "child_wall_s": round(t_child, 3),
        "journal_steps_survived": int(steps_left),
        "recovered_from_step": (None if info is None
                                else int(info["step"])),
        "recover_and_finish_wall_s": round(t_recover, 3),
        "rows_after_recovery": int(pipe2.warehouse.rows_loaded),
        "rows_expected": int(n),
        "kill9_exactly_once": bool(
            killed and identical and views_ok
            and pipe2.warehouse.rows_loaded == n),
    }
    print(f"  kill -9: child rc={proc.returncode}, "
          f"{steps_left} journal steps survived, recovered+finished in "
          f"{t_recover:.2f}s, exactly_once={out['kill9_exactly_once']}")
    return out


# ------------------------------------------------------------------- drivers
def summary(quick: bool = False) -> Dict[str, float]:
    """Small single-cycle figures for ``benchmarks.run``."""
    n = 1_000 if quick else 3_000
    scaling = bench_scaling([n // 2, n], ckpt_every=4)
    kill9 = bench_kill9(n // 2)
    return {
        "recover_wall_s": scaling["per_history"][-1]["recover_wall_s"],
        "reprocessed_fraction":
            scaling["per_history"][-1]["reprocessed_fraction"],
        "speedup_vs_cold": scaling["recovery_speedup_vs_cold"],
        "sublinear_ok": int(scaling["sublinear_ok"]),
        "kill9_exactly_once": int(kill9["kill9_exactly_once"]),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: short histories, 1 overhead cycle")
    ap.add_argument("--out", default="BENCH_recovery.json")
    ap.add_argument("--child-kill9", metavar="JOURNAL_DIR",
                    help=argparse.SUPPRESS)   # internal: kill-9 child half
    ap.add_argument("--n", type=int, default=1_500)
    args = ap.parse_known_args()[0]
    if args.child_kill9:
        _child(args.child_kill9, args.n)
        return

    if args.smoke:
        histories, overhead_n, cycles, kill_n = [500, 1_000, 2_000], \
            2_000, 1, 1_000
    elif args.quick:
        histories, overhead_n, cycles, kill_n = [1_000, 2_000, 4_000], \
            4_000, 3, 2_000
    else:
        histories, overhead_n, cycles, kill_n = \
            [2_000, 4_000, 8_000, 16_000], 8_000, 3, 4_000

    results = {
        "workload": {
            "n_partitions": N_PARTITIONS, "n_workers": N_WORKERS,
            "histories_per_table": histories, "overhead_records": overhead_n,
            "overhead_cycles": cycles, "kill9_records": kill_n,
            "note": ("scaling/kill9 run the deterministic sequential "
                     "drill loop; overhead runs the real "
                     "ConcurrentCluster with a periodic checkpointer — "
                     "on the noisy shared container only the paired "
                     "ratios are meaningful (docs/BENCHMARKS.md)"),
        },
    }
    print("scaling: recovery wall vs history (fixed checkpoint cadence)")
    results["scaling"] = bench_scaling(histories)
    print("overhead: paired cycles with/without the checkpointer")
    results["overhead"] = bench_overhead(overhead_n, cycles)
    print(f"overhead ratio (with/without): "
          f"{results['overhead']['checkpoint_overhead_ratio']}")
    print("kill -9: child SIGKILL mid-stream, parent recovers")
    results["kill9"] = bench_kill9(kill_n)

    results["gates"] = {
        "complete": bool(
            results["kill9"]["rows_after_recovery"]
            == results["kill9"]["rows_expected"]),
        "byte_identical": bool(results["kill9"]["kill9_exactly_once"]),
        "kill9_exactly_once": bool(results["kill9"]["kill9_exactly_once"]),
        "sublinear_ok": bool(results["scaling"]["sublinear_ok"]),
    }
    print("gates:", results["gates"])

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
