"""One benchmark per paper table/figure (real measurements on this host).

  table2_baseline   — §4.1.1 / Table 2: Stream Processor with vs without
                      DOD-ETL (records/s; paper: 10,090 vs 1,230 = 8.2x)
  fig4_init         — Fig. 4: per-worker In-memory cache dump overhead
  fig5_listener     — Fig. 5: Listener scalability, both experiments
                      (grow-log vs fixed-log; saturation by shared log scan)
  fig6_processor    — Fig. 6: Stream Processor scaling with workers
                      (measured per-partition cost, barrier model)
  table2_fault      — §4.1.3: 5 -> 3 workers mid-run, throughput + zero
                      consistency errors
  table2_production — §4.1.4: simple vs ISA-95-complex data model
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.configs.dod_etl import steelworks_config
from repro.core import (BaselineStreamProcessor, DODETLPipeline,
                        SourceDatabase)
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.runtime.cluster import SimulatedCluster


def _mk(n_records=20_000, n_partitions=20, n_workers=10, late=0.02,
        complex_model=False, join_depth=1, seed=0):
    cfg = steelworks_config(n_partitions=n_partitions,
                            complex_model=complex_model)
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n_records, n_equipment=n_partitions,
        late_master_frac=late, seed=seed)).generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers,
                          join_depth=join_depth)
    return cfg, src, pipe


def table2_baseline(n_records=20_000) -> Dict[str, float]:
    """DOD-ETL vs unmodified stream processor, same workload + KPIs."""
    cfg, src, pipe = _mk(n_records)
    pipe.extract()
    pipe.bootstrap_caches()
    t0 = time.perf_counter()
    done = pipe.run_to_completion()
    dod_s = time.perf_counter() - t0
    dod_rate = done / dod_s

    # baseline: record-at-a-time + per-record source look-backs.
    # Measured on a slice, rate extrapolates (cost is linear per record).
    cfg2, src2, _ = _mk(n_records)
    baseline = BaselineStreamProcessor(cfg2, src2)
    prod_tid = [t.name for t in cfg2.tables].index("production")
    batches = [b.filter(b.table_id == prod_tid) for b in src2.log._batches]
    batches = [b for b in batches if len(b)]
    slice_n = min(2_000, n_records)
    t0 = time.perf_counter()
    out_n = 0
    for b in batches:
        take = b if out_n + len(b) <= slice_n else b.take(
            np.arange(slice_n - out_n))
        facts = baseline.process(take)
        out_n += len(facts)
        if out_n >= slice_n:
            break
    base_s = time.perf_counter() - t0
    base_rate = out_n / base_s
    return {
        "dodetl_records_s": round(dod_rate),
        "baseline_records_s": round(base_rate),
        "speedup": round(dod_rate / base_rate, 2),
        "paper_speedup": 8.2,
        "source_lookups_dodetl": src.lookup_count,
        "source_lookups_baseline": src2.lookup_count,
    }


def fig4_init(n_workers=10, n_records=20_000) -> Dict[str, float]:
    cfg, src, pipe = _mk(n_records, n_workers=n_workers)
    pipe.extract()
    dumps = []
    for w in pipe.workers:
        dumps.append(w.reset_caches(pipe.master_topic_map,
                                    cfg.n_business_keys))
    return {
        "workers": n_workers,
        "mean_dump_s": round(float(np.mean(dumps)), 4),
        "max_dump_s": round(float(np.max(dumps)), 4),
        "cv": round(float(np.std(dumps) / (np.mean(dumps) + 1e-12)), 3),
    }


def fig5_listener(max_tables=16, rows_per_table=2_000) -> List[Dict]:
    """Two experiments over #tables: (a) grow-log — insertions only into
    extracted tables; (b) fixed-log — 16 tables always inserted, extraction
    count varies. Saturation mechanism: every Listener scans the SHARED log."""
    from repro.configs.dod_etl import ETLConfig, TableConfig
    from repro.core import MessageQueue
    from repro.core.listener import ChangeTracker
    from repro.core.records import make_batch

    def run(n_tables: int, n_inserted: int) -> float:
        tables = tuple(
            TableConfig(f"t{i}", "operational", "id", "eq",
                        tuple("abcdefgh")) for i in range(max_tables))
        cfg = ETLConfig(tables=tables, n_partitions=4, n_business_keys=4)
        src = SourceDatabase()
        rng = np.random.default_rng(0)
        for i in range(n_inserted):
            ids = np.arange(rows_per_table, dtype=np.int64)
            src.apply(make_batch(i, 0, ids, ids % 4, ids,
                                 rng.normal(size=(rows_per_table, 8))))
        queue = MessageQueue()
        tracker = ChangeTracker(cfg, src.log, queue)
        listeners = tracker.listeners[:n_tables]
        t0 = time.perf_counter()
        got = sum(l.poll() for l in listeners)
        wall = time.perf_counter() - t0
        return got / wall if wall > 0 else 0.0

    rows = []
    for n in (1, 2, 4, 8, 12, 16):
        rows.append({
            "tables": n,
            "grow_log_records_s": round(run(n, n)),
            "fixed_log_records_s": round(run(n, max_tables)),
        })
    return rows


def fig6_processor(max_workers=20, n_partitions=20, n_records=20_000
                   ) -> List[Dict]:
    """Throughput vs workers; real per-partition costs, barrier model
    (cluster time per round = max over worker walls, as a real barrier
    would observe). Scaling saturates at #partitions, as in the paper."""
    rows = []
    for n_workers in (1, 2, 4, 8, 12, 16, 20):
        cfg, src, pipe = _mk(n_records, n_partitions=n_partitions,
                             n_workers=n_workers)
        cluster = SimulatedCluster(pipe)
        pipe.extract()
        pipe.bootstrap_caches()
        cluster.run_round(max_records_per_partition=50)   # jit warm-up
        cluster.history.clear()
        while True:
            stats = cluster.run_round(max_records_per_partition=500)
            if stats.records == 0:
                break
        h = [s for s in cluster.history if s.records]
        recs = sum(s.records for s in h)
        wall = sum(s.cluster_wall_s for s in h)
        rows.append({"workers": n_workers,
                     "records_s": round(recs / wall) if wall else 0})
    return rows


def table2_fault(n_records=20_000) -> Dict[str, float]:
    """Both windows measure FULL rounds (fixed records/round) with warm jit,
    so before/after rates are apples-to-apples; the re-dump cost is charged
    to the post-failure window (the paper's §4.1.3 observation)."""
    cap = 1_000
    n_records = max(n_records, 40_000)
    # join_depth=3 makes per-record compute dominate host overhead so the
    # barrier model resolves the worker loss
    cfg, src, pipe = _mk(n_records, n_partitions=10, n_workers=5,
                         join_depth=3)
    cluster = SimulatedCluster(pipe)
    pipe.extract()
    pipe.bootstrap_caches()
    # warm-up (jit compilation) outside the measured window
    cluster.run_round(max_records_per_partition=cap)
    cluster.run_round(max_records_per_partition=cap)
    cluster.history.clear()
    for _ in range(4):
        cluster.run_round(max_records_per_partition=cap)
    # full-round size observed (hash skew can leave partitions empty)
    quota = max(s.records for s in cluster.history)
    bh = [s for s in cluster.history if s.records >= 0.9 * quota]
    before = (sum(s.records for s in bh) /
              sum(s.cluster_wall_s for s in bh))
    cluster.fail_workers(["w1", "w3"])
    n_before_fail = len(cluster.history)
    while True:
        stats = cluster.run_round(max_records_per_partition=cap)
        if stats.records == 0:
            break
    after_h = [s for s in cluster.history[n_before_fail:]
               if s.records >= 0.9 * quota] or \
        [s for s in cluster.history[n_before_fail:] if s.records]
    after = (sum(s.records for s in after_h) /
             sum(s.cluster_wall_s + s.cache_redump_s for s in after_h))
    # consistency: oracle single-worker run (same dataset size!)
    cfg2, src2, pipe2 = _mk(n_records, n_partitions=10, n_workers=1,
                            join_depth=3)
    pipe2.extract()
    pipe2.bootstrap_caches()
    pipe2.run_to_completion()
    a = pipe.warehouse.fact_table()
    b = pipe2.warehouse.fact_table()
    order = lambda t: t[np.lexsort((t[:, 1], t[:, 0]))]
    consistent = (len(a) == len(b) and
                  np.allclose(order(a), order(b), rtol=1e-5, atol=1e-5))
    return {
        "rate_before_records_s": round(before),
        "rate_after_records_s": round(after),
        "drop_pct": round(100 * (1 - after / before), 1),
        "paper_drop_pct": 57.0,
        "workers_removed_pct": 40.0,
        "consistency_errors": 0 if consistent else -1,
    }


def table2_production(n_records=5_000) -> Dict[str, float]:
    out = {}
    for label, cmplx, depth in (("simple", False, 1), ("complex", True, 8)):
        cfg, src, pipe = _mk(n_records, complex_model=cmplx,
                             join_depth=depth, n_workers=10)
        pipe.extract()
        pipe.bootstrap_caches()
        t0 = time.perf_counter()
        done = pipe.run_to_completion()
        out[f"{label}_records_s"] = round(done / (time.perf_counter() - t0))
    out["slowdown_x"] = round(out["simple_records_s"] /
                              max(out["complex_records_s"], 1), 1)
    out["paper_slowdown_x"] = round(10_090 / 230, 1)
    return out
