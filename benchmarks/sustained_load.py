"""Sustained-load cluster benchmark — the paper-claim suite (Table 2 +
Fig. 5).

A closed-loop generator replays the steelworks workload against the source
database at a target arrival rate (default: firehose, i.e. as fast as the
feeder can write — which measures saturation capacity, the plateau of the
paper's Fig. 5). The same workload is then driven through three harnesses:

  * ``sequential``  — the single-worker sequential round loop
                      (``extract(); step()`` — the pre-concurrency
                      architecture), the scaling reference,
  * ``dodetl``      — ``ConcurrentCluster`` sweeping worker counts
                      (1/2/4/8): real threads, real hand-off queues, CDC
                      polled by its own extraction thread; per-run
                      p50/p95/p99 end-to-end freshness is reported from
                      the CDC append event-time stamps,
  * ``baseline``    — the §4.1.1 record-at-a-time processor with
                      per-record source look-backs (time-budgeted: its
                      sustained rate is measured over the budget window,
                      since finishing the full workload record-at-a-time
                      would take minutes).

Deep join chains (``--join-depth``, default 8) replay §4.1.4's normalized
ISA-95 schema cost so the numeric core — not Python dispatch — dominates,
which is also what lets worker threads scale: XLA is pinned to ONE intra-op
thread (set before jax import) so worker-level parallelism is the only
parallelism, exactly one core per worker as in the paper's cluster.

    PYTHONPATH=src python -m benchmarks.sustained_load [--smoke] [--rate R]

Writes ``BENCH_sustained.json`` (see docs/BENCHMARKS.md for the metric
definitions and how the speedups map onto the paper's Table 2).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import threading
import time
from typing import Dict, Optional

# pin XLA intra-op parallelism BEFORE jax initializes: each worker thread
# owns one core, matching one-core-per-node cluster accounting
_PIN = "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1"
if "xla_cpu_multi_thread_eigen" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _PIN).strip()

import numpy as np

from repro.configs.dod_etl import ETLConfig, steelworks_config
from repro.core import BaselineStreamProcessor, DODETLPipeline, SourceDatabase
from repro.core.records import RecordBatch
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.runtime.cluster import ConcurrentCluster


@dataclasses.dataclass
class Workload:
    n_base: int = 4_000        # base records/table (masters + backlog)
    waves: int = 119           # streamed production-only waves (480k total
                               # operational records: long enough that
                               # thread startup/drain overheads vanish and
                               # shared-host noise averages into every run)
    chunk: int = 4_000         # records per wave (<= n_base: join keys
                               # must exist in the base master tables)
    n_partitions: int = 20     # paper: 20
    join_depth: int = 32       # §4.1.4 normalized-schema join chain
    late_frac: float = 0.05
    rate: float = 0.0          # target arrival rate (records/s); 0=firehose
    backend: str = "jax"
    dispatch: int = 8192       # target records per transform dispatch: the
                               # per-partition fetch cap is derived per
                               # worker count so every configuration issues
                               # same-sized dispatches (uniform jit buckets,
                               # uniform per-dispatch overhead)

    def cap_for(self, n_workers: int) -> int:
        """Per-partition fetch cap giving ~`dispatch` records per coalesced
        fetch when `n_partitions` is spread over `n_workers` workers."""
        owned = max(1, self.n_partitions // max(1, n_workers))
        return max(1, self.dispatch // owned)

    @property
    def total_ops(self) -> int:
        return self.n_base + self.waves * self.chunk


def make_config(wl: Workload) -> ETLConfig:
    cfg = steelworks_config(n_partitions=wl.n_partitions, backend=wl.backend)
    # pre-size caches (no mid-run grow/recompile) and the late buffer (the
    # replicated store must absorb the whole cold-start backlog)
    slots = 1 << max(12, (4 * wl.n_base).bit_length())
    return dataclasses.replace(cfg, cache_slots=slots,
                               buffer_capacity=2 * wl.total_ops)


def seed_source(wl: Workload):
    cfg = make_config(wl)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=wl.n_base, n_equipment=wl.n_partitions,
        late_master_frac=wl.late_frac))
    sampler.generate(src)               # masters + base backlog + late tail
    return cfg, src, sampler


def feed_waves(sampler: SteelworksSampler, src: SourceDatabase,
               wl: Workload) -> None:
    """Closed-loop feeder: apply `waves` production-only chunks, pacing to
    the target arrival rate (sleeping off any time the apply did not use)."""
    interval = (wl.chunk / wl.rate) if wl.rate > 0 else 0.0
    next_t = time.perf_counter()
    for _ in range(wl.waves):
        sampler.generate(src, n_per_table=wl.chunk, tables=("production",))
        if interval:
            next_t += interval
            pause = next_t - time.perf_counter()
            if pause > 0:
                time.sleep(pause)


def prewarm(pipe: DODETLPipeline, wl: Workload) -> None:
    """Compile every transform bucket a run can hit, outside the window —
    BOTH kernel variants: the plain transform (legacy/pre-PR arms) and the
    fused transform+rollup the device-resident hot path dispatches. The
    micro-batch cap bounds any single dispatch (fetch OR retry sweep) to
    cap * n_partitions records, so the bucket set is small and identical
    for every worker count — no mid-measurement jit compiles."""
    be = pipe.backend
    if not be.device:
        return
    w = pipe.workers[0]
    n_units = pipe.cfg.n_business_keys
    size = 256 if be.name == "pallas" else 128
    top = 1 << (2 * wl.dispatch - 1).bit_length()
    while size <= top:
        dummy = np.full((size, 8), -1.0, np.float32)
        be.transform(dummy, w.equipment, w.quality, join_depth=wl.join_depth)
        be.transform_and_rollup(dummy, w.equipment, w.quality,
                                n_units=n_units,
                                join_depth=wl.join_depth).to_host()
        size *= 2


# ----------------------------------------------------------------- harnesses
def _drive_sequential(wl: Workload, step, fused_rollup: bool = True) -> Dict:
    cfg, src, sampler = seed_source(wl)
    pipe = DODETLPipeline(cfg, src, n_workers=1, join_depth=wl.join_depth)
    if not fused_rollup:
        # faithful seed dispatch: no fused rollup riding the transform
        # kernel (the seed arm must not pay post-PR per-dispatch work)
        for w in pipe.workers:
            w.transformer.n_units = None
    prewarm(pipe, wl)
    feeder = threading.Thread(target=feed_waves, args=(sampler, src, wl))
    total, stalls = 0, 0
    t0 = time.perf_counter()
    feeder.start()
    while total < wl.total_ops and stalls < 200:
        pipe.extract()
        n = step(pipe)
        total += n
        stalls = stalls + 1 if n == 0 else 0
    wall = time.perf_counter() - t0
    feeder.join()
    return {"records": total, "wall_s": round(wall, 4),
            "records_s": round(total / wall) if wall else 0}


def run_seed_sequential(wl: Workload) -> Dict:
    """THE scaling reference of the issue: the seed's single-worker
    sequential round loop — workers executed one after another, one
    dispatch PER PARTITION per topic per round (the execution model
    ``SimulatedCluster`` drove before the concurrent runtime existed;
    same reproduction as ``backend_bench``'s legacy arm)."""
    cap = wl.cap_for(1)

    def step(pipe):
        done = 0
        for w in pipe.workers:
            w.pump_master(pipe.master_topic_map["equipment"], w.equipment)
            w.pump_master(pipe.master_topic_map["quality"], w.quality)
        for w in pipe.workers:
            for topic in pipe.operational_topics:
                for p in w.partitions:
                    batch = pipe.queue.consume(w.group, topic, p, cap)
                    if len(batch):
                        pipe.queue.commit(w.group, topic, p, len(batch))
                    facts, _ = w.transformer.process(batch)
                    w.warehouse.load(p, facts)
                    done += len(facts)
        return done

    return _drive_sequential(wl, step, fused_rollup=False)


def run_sequential(wl: Workload) -> Dict:
    """This repo's OPTIMIZED single-thread pipeline (coalesced
    ``extract(); step()`` round loop) — a strictly stronger reference than
    the seed round loop, reported alongside it for transparency."""
    cap = wl.cap_for(1)
    return _drive_sequential(wl, lambda pipe: pipe.step(cap))


def run_concurrent(wl: Workload, n_workers: int) -> Dict:
    cfg, src, sampler = seed_source(wl)
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers,
                          join_depth=wl.join_depth)
    prewarm(pipe, wl)
    cluster = ConcurrentCluster(
        pipe, max_records_per_partition=wl.cap_for(n_workers))
    feeder = threading.Thread(target=feed_waves, args=(sampler, src, wl))
    t0 = time.perf_counter()
    cluster.start()
    feeder.start()
    feeder.join()
    done = cluster.run_until_idle(timeout=600.0)
    wall = time.perf_counter() - t0
    lat = cluster.freshness()
    cluster.stop_all()
    out = {"records": done, "wall_s": round(wall, 4),
           "records_s": round(done / wall) if wall else 0,
           "complete": done == wl.total_ops}
    out.update(lat)
    return out


def run_baseline(wl: Workload, budget_s: float) -> Dict:
    """§4.1.1 record-at-a-time with per-record source look-backs, measured
    over a time budget (its sustained rate is constant once the master
    tables are fully populated, so the window is representative)."""
    cfg, src, sampler = seed_source(wl)
    for _ in range(wl.waves):           # full workload, applied up front
        sampler.generate(src, n_per_table=wl.chunk, tables=("production",))
    baseline = BaselineStreamProcessor(cfg, src)
    prod_tid = [t.name for t in cfg.tables].index("production")
    # extract through the public log read path (what a Listener does)
    log_all, _ = src.log.read_from(0)
    prod = log_all.filter(log_all.table_id == prod_tid)
    done = 0
    t0 = time.perf_counter()
    # micro-batches of 256 records, like a stream framework's trigger
    for lo in range(0, len(prod), 256):
        sub = prod.take(np.arange(lo, min(lo + 256, len(prod))))
        done += len(baseline.process(sub))
        if time.perf_counter() - t0 > budget_s:
            break
    wall = time.perf_counter() - t0
    return {"records": done, "wall_s": round(wall, 4),
            "records_s": round(done / wall) if wall else 0,
            "budget_s": budget_s, "total_available": wl.total_ops,
            "lookups": src.lookup_count}


def median(runs, key="records_s"):
    runs = sorted(runs, key=lambda r: r[key])
    return runs[len(runs) // 2]


def main() -> None:
    import sys
    # with ~3 threads per worker on a small host, the default 5 ms GIL
    # switch interval forces frequent handoffs mid-hot-loop; a longer
    # quantum lets each stage finish its numpy/XLA call (which releases
    # the GIL anyway) before yielding
    sys.setswitchinterval(0.02)
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload, 2 workers (CI harness check)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="target arrival rate in records/s (0 = firehose)")
    ap.add_argument("--join-depth", type=int, default=None)
    ap.add_argument("--dispatch", type=int, default=8192,
                    help="target records per transform dispatch")
    ap.add_argument("--backend", default="jax")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--baseline-budget-s", type=float, default=None)
    ap.add_argument("--out", default="BENCH_sustained.json")
    args = ap.parse_known_args()[0]

    if args.smoke:
        wl = Workload(n_base=800, waves=2, chunk=800, n_partitions=8,
                      join_depth=args.join_depth or 2, rate=args.rate,
                      backend=args.backend, dispatch=args.dispatch)
        worker_counts = (2,)
        repeats = args.repeats or 1
        budget = args.baseline_budget_s or 3.0
    else:
        wl = Workload(rate=args.rate, backend=args.backend,
                      join_depth=args.join_depth or 32,
                      dispatch=args.dispatch)
        worker_counts = (1, 2, 4, 8)
        repeats = args.repeats or 5
        budget = args.baseline_budget_s or 20.0

    results: Dict[str, dict] = {
        "workload": {
            **dataclasses.asdict(wl), "total_ops": wl.total_ops,
            "host_cores": os.cpu_count(),
            "note": ("firehose arrival (rate=0) measures saturation "
                     "capacity, the Fig. 5 plateau; XLA pinned to one "
                     "intra-op thread so worker threads are the only "
                     "parallelism"),
        },
        "sequential": {}, "dodetl": {},
    }

    # PAIRED cycles (seed-loop, coalesced-loop, w1, w2, ... adjacent in
    # time, repeated): the shared host's speed drifts at the seconds
    # timescale, so each cycle's concurrent runs are ratioed against the
    # sequential runs next to them in time, and the headline ratio is the
    # median over cycles — a paired estimator that cancels drift a plain
    # median of rates cannot
    seed_runs, seq_runs = [], []
    conc_runs: Dict[int, list] = {n: [] for n in worker_counts}
    paired_seed: Dict[int, list] = {n: [] for n in worker_counts}
    paired_coal: Dict[int, list] = {n: [] for n in worker_counts}
    for _ in range(repeats):
        sd = run_seed_sequential(wl)
        seed_runs.append(sd)
        s = run_sequential(wl)
        seq_runs.append(s)
        for n in worker_counts:
            c = run_concurrent(wl, n)
            conc_runs[n].append(c)
            paired_seed[n].append(c["records_s"] / max(sd["records_s"], 1))
            paired_coal[n].append(c["records_s"] / max(s["records_s"], 1))
    seed = median(seed_runs)
    seed["records_s_runs"] = [r["records_s"] for r in seed_runs]
    seed["note"] = ("the issue's reference: seed-era round loop, one "
                    "dispatch per partition per topic per round")
    results["sequential"]["1"] = seed
    seq = median(seq_runs)
    seq["records_s_runs"] = [r["records_s"] for r in seq_runs]
    seq["note"] = "this PR's optimized coalesced single-thread round loop"
    results["sequential"]["1_coalesced"] = seq
    print(f"sequential/1 (seed round loop): {seed}")
    print(f"sequential/1_coalesced: {seq}")
    for n in worker_counts:
        res = median(conc_runs[n])
        res["records_s_runs"] = [r["records_s"] for r in conc_runs[n]]
        results["dodetl"][str(n)] = res
        print(f"dodetl/{n}: {res}")

    base = run_baseline(wl, budget)
    results["baseline"] = base
    print(f"baseline: {base}")

    results["speedup_vs_baseline"] = {
        n: round(r["records_s"] / max(base["records_s"], 1), 2)
        for n, r in results["dodetl"].items()}

    def ratio_block(paired: Dict[int, list]) -> Dict:
        out = {
            str(n): {"median_paired_ratio":
                     round(sorted(rs)[len(rs) // 2], 2),
                     "paired_ratios": [round(r, 2) for r in rs]}
            for n, rs in paired.items()}
        multi = [v["median_paired_ratio"] for n, v in out.items()
                 if int(n) > 1]
        out["best_multi_worker"] = max(multi) if multi else None
        return out

    results["concurrent_vs_sequential"] = ratio_block(paired_seed)
    results["concurrent_vs_sequential"]["reference"] = \
        "sequential.1 (the seed-era single-worker round loop)"
    results["concurrent_vs_coalesced_sequential"] = ratio_block(paired_coal)
    results["concurrent_vs_coalesced_sequential"]["reference"] = \
        "sequential.1_coalesced (this PR's optimized single-thread loop)"
    print(f"speedup vs baseline: {results['speedup_vs_baseline']}")
    print(f"concurrent vs seed round loop: "
          f"{results['concurrent_vs_sequential']}")
    print(f"concurrent vs coalesced sequential: "
          f"{results['concurrent_vs_coalesced_sequential']}")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
