"""Benchmark harness: one function per paper table/figure plus kernel and
roofline reports. Prints ``name,value,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import json
import sys


def emit(name: str, payload):
    if isinstance(payload, dict):
        for k, v in payload.items():
            print(f"{name}.{k},{v},")
    elif isinstance(payload, list):
        for row in payload:
            key = row.get("tables", row.get("workers", ""))
            for k, v in row.items():
                if k not in ("tables", "workers"):
                    print(f"{name}[{key}].{k},{v},")
    sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced record counts (CI-sized)")
    args, _ = ap.parse_known_args()
    n = 4_000 if args.quick else 20_000

    from benchmarks import paper_benchmarks as P

    print("name,value,derived")
    emit("table2_baseline", P.table2_baseline(n))
    emit("fig4_init", P.fig4_init(n_records=n))
    emit("fig5_listener", P.fig5_listener(rows_per_table=max(n // 10, 500)))
    emit("fig6_processor", P.fig6_processor(n_records=n))
    emit("table2_fault", P.table2_fault(n))
    emit("table2_production", P.table2_production(max(n // 4, 1_000)))

    from benchmarks import kernel_bench as K
    emit("kernel.attention", K.bench_attention())
    emit("kernel.gla", K.bench_gla())
    emit("kernel.hash_join", K.bench_hash_join())
    emit("kernel.transform", K.bench_transform())

    # read-side serving layer: incremental-view query speedup, staleness,
    # batched query-plane qps and associative-scan fold speedups (full
    # sweep: python -m benchmarks.report_serving -> BENCH_views.json)
    from benchmarks import report_serving as RS
    emit("serving", RS.summary(quick=args.quick))

    # device-plane dispatch/sync overhead: host<->device round trips per
    # worker step + segment-compacted fold speedup (full sweep:
    # python -m benchmarks.dispatch_overhead -> BENCH_dispatch.json)
    from benchmarks import dispatch_overhead as DO
    emit("dispatch", DO.summary(quick=args.quick))

    # skew-aware adaptive partitioning: worker-load imbalance + surgical
    # cache retention under a Zipf-skewed stream (full sweep:
    # python -m benchmarks.skewed_load -> BENCH_skew.json)
    from benchmarks import skewed_load as SK
    emit("skew", SK.summary(quick=args.quick))

    # crash-consistent durability: recovery cost vs history length +
    # kill -9 exactly-once drill (full sweep:
    # python -m benchmarks.recovery_bench -> BENCH_recovery.json)
    from benchmarks import recovery_bench as RB
    emit("recovery", RB.summary(quick=args.quick))

    # observability plane: NULL_TRACER seam cost + enabled tracer/registry
    # overhead as paired throughput ratios (full sweep:
    # python -m benchmarks.observability_overhead -> BENCH_observability.json)
    from benchmarks import observability_overhead as OO
    emit("observability", OO.summary(quick=args.quick))

    # self-healing control plane: grey-failure detection latency + poison
    # quarantine contracts (full sweep incl. the load-spike drill:
    # python -m benchmarks.control_loop -> BENCH_control.json)
    from benchmarks import control_loop as CL
    emit("control", CL.summary(quick=args.quick))

    # sharded serving plane: modeled fold-throughput scaling across
    # simulated device shards + bitwise parity contracts (full sweep
    # incl. the 4-device mesh drill:
    # python -m benchmarks.shard_scaling -> BENCH_shard.json)
    from benchmarks import shard_scaling as SH
    emit("shard", SH.summary(quick=args.quick))

    # roofline summary (if the dry-run matrix has been produced)
    try:
        from benchmarks.roofline import load_cells, roofline_fraction
        rows = load_cells()
        if rows:
            fracs = [roofline_fraction(r) for r in rows]
            fracs = [f for f in fracs if f]
            emit("roofline", {
                "cells": len(rows),
                "mean_fraction": round(sum(fracs) / len(fracs), 4),
                "min_fraction": round(min(fracs), 4),
                "max_fraction": round(max(fracs), 4),
            })
    except Exception as e:  # pragma: no cover
        print(f"roofline.error,{e},")


if __name__ == "__main__":
    main()
