"""Report-serving benchmark: the read-side claim suite.

Three measurements, written to ``BENCH_views.json``:

* ``query_latency`` — incremental-view report queries
  (``ReportServer.kpi_rollup``, O(n_units) reads of folded state) vs the
  ad-hoc full-rescan path (``Warehouse.kpi_rollup``, O(fact-table)
  concat + segmented reduce) across fact-table sizes. Paired/interleaved:
  each repeat times rescan and view back-to-back and the headline speedup
  is the **median of per-repeat ratios** (the noisy-2-core-host
  methodology of docs/BENCHMARKS.md). Parity is asserted every repeat —
  the view must answer byte-equal counts and ~1e-4-close sums.

* ``concurrency`` — sustained query throughput while a writer keeps
  loading + folding: N reader threads issue snapshot-pinned queries;
  reports qps and per-query p50/p95, and asserts epochs observed by every
  reader are monotone (no torn reads under write pressure).

* ``staleness_e2e`` — end-to-end report staleness (CDC append ->
  visible-in-query) under sustained load on a live ``ConcurrentCluster``
  with the serving stage attached, next to the pipeline's load-freshness
  percentiles. The headline is ``staleness_p95 / freshness_p95`` — how
  much the serving hop adds on top of the write path (acceptance: <= 2x).

    PYTHONPATH=src python -m benchmarks.report_serving [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import threading
import time
from typing import Dict, List, Sequence

import numpy as np

# reuses the sustained-load workload machinery AND its XLA single-thread
# pin (set at that module's import, before jax initializes)
from benchmarks.sustained_load import (Workload, feed_waves, make_config,
                                       prewarm)
from repro.core.cdc import SourceDatabase
from repro.data.sampler import (SamplerConfig, SteelworksSampler,
                                synthetic_facts)
from repro.core import DODETLPipeline, StarSchemaWarehouse, percentiles_ms
from repro.core.backend import get_backend
from repro.runtime.cluster import ConcurrentCluster
from repro.serving import (MaterializedViewEngine, ReportServer,
                           steelworks_views)

N_UNITS = 20


def _median(xs: Sequence[float]) -> float:
    return float(sorted(xs)[len(xs) // 2])


def _loaded_server(n_rows: int, backend: str, chunk: int = 8192):
    rng = np.random.default_rng(n_rows)
    wh = StarSchemaWarehouse(backend=get_backend(backend))
    engine = wh.attach_serving(
        MaterializedViewEngine(steelworks_views(N_UNITS), backend=backend))
    for lo in range(0, n_rows, chunk):
        wh.load_partitioned(
            synthetic_facts(rng, min(chunk, n_rows - lo), N_UNITS), N_UNITS)
    engine.fold_pending()
    return wh, engine, ReportServer(engine)


# ------------------------------------------------------------- query latency
def bench_query_latency(sizes: Sequence[int], reps: int,
                        backend: str = "jax") -> Dict:
    out: Dict[str, object] = {"sizes": list(sizes), "backend": backend,
                              "per_size": {}}
    for n_rows in sizes:
        wh, engine, server = _loaded_server(n_rows, backend)
        wh.kpi_rollup(N_UNITS)          # jit warm-up outside the window
        server.kpi_rollup()
        rescan_ms, view_ms, ratios = [], [], []
        parity_ok = True
        for _ in range(reps):           # interleaved, paired per repeat
            t0 = time.perf_counter()
            scan = wh.kpi_rollup(N_UNITS)
            t1 = time.perf_counter()
            # view queries are microseconds: time a burst of 100
            for _ in range(100):
                view = server.kpi_rollup()
            t2 = time.perf_counter()
            r_ms = (t1 - t0) * 1e3
            v_ms = (t2 - t1) * 1e3 / 100
            rescan_ms.append(round(r_ms, 4))
            view_ms.append(round(v_ms, 5))
            ratios.append(r_ms / max(v_ms, 1e-6))
            parity_ok &= bool(
                np.array_equal(view[:, 4], scan[:, 4])
                and np.allclose(view, scan, rtol=1e-4, atol=1e-4))
        out["per_size"][str(n_rows)] = {
            "rows": n_rows,
            "rescan_ms_runs": rescan_ms,
            "view_query_ms_runs": view_ms,
            "rescan_ms": _median(rescan_ms),
            "view_query_ms": _median(view_ms),
            "paired_speedups": [round(r, 1) for r in ratios],
            "speedup_view_vs_rescan": round(_median(ratios), 1),
            "parity_ok": parity_ok,
        }
    largest = out["per_size"][str(max(sizes))]
    out["speedup_at_largest"] = largest["speedup_view_vs_rescan"]
    out["parity_ok"] = all(v["parity_ok"]
                           for v in out["per_size"].values())
    return out


# --------------------------------------------------------------- concurrency
def bench_concurrency(n_rows: int, thread_counts: Sequence[int],
                      queries_per_thread: int,
                      backend: str = "jax") -> Dict:
    out: Dict[str, object] = {"rows_preloaded": n_rows,
                              "queries_per_thread": queries_per_thread,
                              "per_threads": {}}
    for n_threads in thread_counts:
        wh, engine, server = _loaded_server(n_rows, backend)
        engine.start()                  # maintenance folds while we query
        stop = threading.Event()

        def writer():
            wrng = np.random.default_rng(1)
            while not stop.is_set():
                wh.load_partitioned(synthetic_facts(wrng, 2048, N_UNITS),
                                    N_UNITS)
                time.sleep(0.001)

        lat: List[np.ndarray] = [None] * n_threads
        torn = [False] * n_threads

        def reader(i: int):
            samples = np.zeros(queries_per_thread)
            last_epoch, last_count = -1, -1.0
            for q in range(queries_per_thread):
                t0 = time.perf_counter()
                snap = server.snapshot()
                roll = snap.kpi_rollup()
                samples[q] = time.perf_counter() - t0
                count = float(roll[:, 4].sum())
                if snap.epoch < last_epoch or count < last_count:
                    torn[i] = True
                last_epoch, last_count = snap.epoch, count
            lat[i] = samples

        wthread = threading.Thread(target=writer)
        readers = [threading.Thread(target=reader, args=(i,))
                   for i in range(n_threads)]
        wthread.start()
        t0 = time.perf_counter()
        for r in readers:
            r.start()
        for r in readers:
            r.join()
        wall = time.perf_counter() - t0
        stop.set()
        wthread.join()
        engine.stop()
        total_q = n_threads * queries_per_thread
        res = {"queries": total_q, "wall_s": round(wall, 4),
               "qps": round(total_q / wall) if wall else 0,
               "epochs_final": engine.snapshot().epoch,
               "monotonic": not any(torn)}
        res.update({f"query_{k}": v for k, v in
                    percentiles_ms(np.concatenate(lat)).items()})
        out["per_threads"][str(n_threads)] = res
    return out


# ------------------------------------------------------------- staleness e2e
def bench_staleness(wl: Workload, n_workers: int = 2) -> Dict:
    # unlike the sustained-load harness, seed NOTHING before the cluster
    # starts: every CDC append lands while the pipeline is live, so the
    # freshness/staleness stamps measure the running system, not a
    # pre-start backlog aging through jit warm-up
    cfg = make_config(wl)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=wl.n_base, n_equipment=wl.n_partitions,
        late_master_frac=wl.late_frac))
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers,
                          join_depth=wl.join_depth)
    prewarm(pipe, wl)
    engine = MaterializedViewEngine(
        steelworks_views(wl.n_partitions), backend=wl.backend)
    engine.prewarm()       # fold buckets compile outside the window
    cluster = ConcurrentCluster(
        pipe, max_records_per_partition=wl.cap_for(n_workers),
        serving=engine)

    def feed():
        sampler.generate(src)           # masters + base, cluster already up
        feed_waves(sampler, src, wl)

    feeder = threading.Thread(target=feed)
    t0 = time.perf_counter()
    cluster.start()
    feeder.start()
    feeder.join()
    done = cluster.run_until_idle(timeout=600.0)
    # wait for the maintenance stage to drain the delta backlog so the
    # staleness samples cover every record (stop_all also folds the rest)
    deadline = time.time() + 30.0
    while engine.pending() and time.time() < deadline:
        time.sleep(0.005)
    wall = time.perf_counter() - t0
    freshness = cluster.freshness()
    cluster.stop_all()
    staleness = engine.staleness()
    ratio = (round(staleness["p95_ms"] / freshness["p95_ms"], 2)
             if freshness["p95_ms"] else None)
    return {"records": done, "complete": done == wl.total_ops,
            "wall_s": round(wall, 4),
            "records_s": round(done / wall) if wall else 0,
            "n_workers": n_workers,
            "freshness": freshness, "staleness": staleness,
            "staleness_p95_over_freshness_p95": ratio,
            "epoch": engine.snapshot().epoch,
            "rows_folded": engine.snapshot().rows_folded}


def summary(quick: bool = False) -> Dict[str, float]:
    """Headline numbers for benchmarks/run.py's CSV report."""
    sizes = (4_000, 16_000) if quick else (10_000, 40_000)
    q = bench_query_latency(sizes, reps=3)
    wl = Workload(n_base=800, waves=2, chunk=800, n_partitions=8,
                  join_depth=2)
    s = bench_staleness(wl)
    return {
        "speedup_view_vs_rescan_at_largest": q["speedup_at_largest"],
        "parity_ok": q["parity_ok"],
        "staleness_p95_ms": s["staleness"]["p95_ms"],
        "freshness_p95_ms": s["freshness"]["p95_ms"],
        "staleness_over_freshness_p95":
            s["staleness_p95_over_freshness_p95"],
        "complete": s["complete"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI harness check)")
    ap.add_argument("--backend", default="jax")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--rate", type=float, default=6_000.0,
                    help="staleness-run arrival rate, records/s "
                         "(0 = firehose; full mode only)")
    ap.add_argument("--out", default="BENCH_views.json")
    args = ap.parse_known_args()[0]

    if args.smoke:
        sizes = (5_000, 20_000)
        reps = args.reps or 3
        threads = (1, 4)
        queries = 200
        conc_rows = 20_000
        wl = Workload(n_base=800, waves=2, chunk=800, n_partitions=8,
                      join_depth=2, backend=args.backend)
    else:
        sizes = (50_000, 200_000, 800_000)
        reps = args.reps or 7
        threads = (1, 4, 16)
        queries = 500
        conc_rows = 200_000
        # staleness is a STEADY-STATE metric: pace arrival below the
        # host's saturation capacity (firehose arrival measures backlog
        # drain, where the fold stage is starved along with everything
        # else and staleness just mirrors queue depth — see
        # docs/BENCHMARKS.md)
        wl = Workload(n_base=4_000, waves=30, chunk=4_000, join_depth=8,
                      rate=args.rate, backend=args.backend)

    results = {
        "note": ("read-side serving claims; paired/interleaved medians on "
                 "a noisy shared host (docs/BENCHMARKS.md methodology)"),
        "n_units": N_UNITS,
        "query_latency": bench_query_latency(sizes, reps, args.backend),
    }
    print("query_latency:", json.dumps(results["query_latency"]["per_size"],
                                       indent=2))
    results["concurrency"] = bench_concurrency(conc_rows, threads, queries,
                                               args.backend)
    print("concurrency:", json.dumps(results["concurrency"], indent=2))
    results["staleness_e2e"] = bench_staleness(wl)
    print("staleness_e2e:", json.dumps(results["staleness_e2e"], indent=2))

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
