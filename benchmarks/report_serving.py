"""Report-serving benchmark: the read-side claim suite.

Five measurements, written to ``BENCH_views.json``:

* ``query_latency`` — incremental-view report queries
  (``ReportServer.kpi_rollup``, O(n_units) reads of folded state) vs the
  ad-hoc full-rescan path (``Warehouse.kpi_rollup``, O(fact-table)
  concat + segmented reduce) across fact-table sizes. Paired/interleaved:
  each repeat times rescan and view back-to-back and the headline speedup
  is the **median of per-repeat ratios** (the noisy-2-core-host
  methodology of docs/BENCHMARKS.md). Parity is asserted every repeat —
  the view must answer byte-equal counts and ~1e-4-close sums.

* ``concurrency`` — sustained query throughput while a writer keeps
  loading + folding: N reader threads issue snapshot-pinned queries;
  reports qps and per-query p50/p95, and asserts epochs observed by every
  reader are monotone (no torn reads under write pressure).

* ``staleness_e2e`` — end-to-end report staleness (CDC append ->
  visible-in-query) under sustained load on a live ``ConcurrentCluster``
  with the serving stage attached, next to the pipeline's load-freshness
  percentiles. The headline is ``staleness_p95 / freshness_p95`` — how
  much the serving hop adds on top of the write path (acceptance: <= 2x).

* ``batched`` — the batched query plane vs the per-query dispatch loop:
  one compiled ``QueryPlan`` of B heterogeneous queries (per-unit OEE
  point queries + view reads + top-k + windowed rates + shift/rollup)
  executed in one vectorized dispatch per view, against B sequential
  ``ReportSnapshot`` calls. Paired per repeat with a FRESH epoch before
  each side so neither inherits the other's per-epoch memos; parity is
  byte-asserted on a shared epoch each repeat. Headlines: columnar
  effective qps and the median paired speedup at each batch size
  (acceptance: >= 5x at B >= 1024).

* ``scan_fold`` — the associative-scan windowed fold: read side, ONE
  ``prefix_fold`` scan answering all S cumulative-window prefixes vs the
  bitwise-identical per-window tree recompute (``prefix_fold_reference``)
  — the S >= 128 win; write side, ``fold_segments_scan`` vs the unrolled
  halving tree on one delta — measured honestly (the scan LOSES on CPU
  hosts; documented, tree stays the default). Bitwise equality asserted
  on both sides every repeat.

    PYTHONPATH=src python -m benchmarks.report_serving [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import threading
import time
from typing import Dict, List, Sequence

import numpy as np

# reuses the sustained-load workload machinery AND its XLA single-thread
# pin (set at that module's import, before jax initializes)
from benchmarks.sustained_load import (Workload, feed_waves, make_config,
                                       prewarm)
from repro.core.cdc import SourceDatabase
from repro.data.sampler import (SamplerConfig, SteelworksSampler,
                                synthetic_facts)
from repro.core import DODETLPipeline, StarSchemaWarehouse, percentiles_ms
from repro.core.backend import get_backend, prefix_fold_reference
from repro.runtime.cluster import ConcurrentCluster
from repro.serving import (MaterializedViewEngine, ReportQuery, ReportServer,
                           compile_queries, production_rate_windows,
                           steelworks_views)

N_UNITS = 20


def _median(xs: Sequence[float]) -> float:
    return float(sorted(xs)[len(xs) // 2])


def _loaded_server(n_rows: int, backend: str, chunk: int = 8192):
    rng = np.random.default_rng(n_rows)
    wh = StarSchemaWarehouse(backend=get_backend(backend))
    engine = wh.attach_serving(
        MaterializedViewEngine(steelworks_views(N_UNITS), backend=backend))
    for lo in range(0, n_rows, chunk):
        wh.load_partitioned(
            synthetic_facts(rng, min(chunk, n_rows - lo), N_UNITS), N_UNITS)
    engine.fold_pending()
    return wh, engine, ReportServer(engine)


# ------------------------------------------------------------- query latency
def bench_query_latency(sizes: Sequence[int], reps: int,
                        backend: str = "jax") -> Dict:
    out: Dict[str, object] = {"sizes": list(sizes), "backend": backend,
                              "per_size": {}}
    for n_rows in sizes:
        wh, engine, server = _loaded_server(n_rows, backend)
        wh.kpi_rollup(N_UNITS)          # jit warm-up outside the window
        server.kpi_rollup()
        rescan_ms, view_ms, ratios = [], [], []
        parity_ok = True
        for _ in range(reps):           # interleaved, paired per repeat
            t0 = time.perf_counter()
            scan = wh.kpi_rollup(N_UNITS)
            t1 = time.perf_counter()
            # view queries are microseconds: time a burst of 100
            for _ in range(100):
                view = server.kpi_rollup()
            t2 = time.perf_counter()
            r_ms = (t1 - t0) * 1e3
            v_ms = (t2 - t1) * 1e3 / 100
            rescan_ms.append(round(r_ms, 4))
            view_ms.append(round(v_ms, 5))
            ratios.append(r_ms / max(v_ms, 1e-6))
            parity_ok &= bool(
                np.array_equal(view[:, 4], scan[:, 4])
                and np.allclose(view, scan, rtol=1e-4, atol=1e-4))
        out["per_size"][str(n_rows)] = {
            "rows": n_rows,
            "rescan_ms_runs": rescan_ms,
            "view_query_ms_runs": view_ms,
            "rescan_ms": _median(rescan_ms),
            "view_query_ms": _median(view_ms),
            "paired_speedups": [round(r, 1) for r in ratios],
            "speedup_view_vs_rescan": round(_median(ratios), 1),
            "parity_ok": parity_ok,
        }
    largest = out["per_size"][str(max(sizes))]
    out["speedup_at_largest"] = largest["speedup_view_vs_rescan"]
    out["parity_ok"] = all(v["parity_ok"]
                           for v in out["per_size"].values())
    return out


# --------------------------------------------------------------- concurrency
def bench_concurrency(n_rows: int, thread_counts: Sequence[int],
                      queries_per_thread: int,
                      backend: str = "jax") -> Dict:
    out: Dict[str, object] = {"rows_preloaded": n_rows,
                              "queries_per_thread": queries_per_thread,
                              "per_threads": {}}
    for n_threads in thread_counts:
        wh, engine, server = _loaded_server(n_rows, backend)
        engine.start()                  # maintenance folds while we query
        stop = threading.Event()

        def writer():
            wrng = np.random.default_rng(1)
            while not stop.is_set():
                wh.load_partitioned(synthetic_facts(wrng, 2048, N_UNITS),
                                    N_UNITS)
                time.sleep(0.001)

        lat: List[np.ndarray] = [None] * n_threads
        torn = [False] * n_threads

        def reader(i: int):
            samples = np.zeros(queries_per_thread)
            last_epoch, last_count = -1, -1.0
            for q in range(queries_per_thread):
                t0 = time.perf_counter()
                snap = server.snapshot()
                roll = snap.kpi_rollup()
                samples[q] = time.perf_counter() - t0
                count = float(roll[:, 4].sum())
                if snap.epoch < last_epoch or count < last_count:
                    torn[i] = True
                last_epoch, last_count = snap.epoch, count
            lat[i] = samples

        wthread = threading.Thread(target=writer)
        readers = [threading.Thread(target=reader, args=(i,))
                   for i in range(n_threads)]
        wthread.start()
        t0 = time.perf_counter()
        for r in readers:
            r.start()
        for r in readers:
            r.join()
        wall = time.perf_counter() - t0
        stop.set()
        wthread.join()
        engine.stop()
        total_q = n_threads * queries_per_thread
        res = {"queries": total_q, "wall_s": round(wall, 4),
               "qps": round(total_q / wall) if wall else 0,
               "epochs_final": engine.snapshot().epoch,
               "monotonic": not any(torn)}
        res.update({f"query_{k}": v for k, v in
                    percentiles_ms(np.concatenate(lat)).items()})
        out["per_threads"][str(n_threads)] = res
    return out


# ------------------------------------------------------------- staleness e2e
def bench_staleness(wl: Workload, n_workers: int = 2) -> Dict:
    # unlike the sustained-load harness, seed NOTHING before the cluster
    # starts: every CDC append lands while the pipeline is live, so the
    # freshness/staleness stamps measure the running system, not a
    # pre-start backlog aging through jit warm-up
    cfg = make_config(wl)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=wl.n_base, n_equipment=wl.n_partitions,
        late_master_frac=wl.late_frac))
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers,
                          join_depth=wl.join_depth)
    prewarm(pipe, wl)
    engine = MaterializedViewEngine(
        steelworks_views(wl.n_partitions), backend=wl.backend)
    engine.prewarm()       # fold buckets compile outside the window
    cluster = ConcurrentCluster(
        pipe, max_records_per_partition=wl.cap_for(n_workers),
        serving=engine)

    def feed():
        sampler.generate(src)           # masters + base, cluster already up
        feed_waves(sampler, src, wl)

    feeder = threading.Thread(target=feed)
    t0 = time.perf_counter()
    cluster.start()
    feeder.start()
    feeder.join()
    done = cluster.run_until_idle(timeout=600.0)
    # wait for the maintenance stage to drain the delta backlog so the
    # staleness samples cover every record (stop_all also folds the rest)
    deadline = time.time() + 30.0
    while engine.pending() and time.time() < deadline:
        time.sleep(0.005)
    wall = time.perf_counter() - t0
    freshness = cluster.freshness()
    cluster.stop_all()
    staleness = engine.staleness()
    ratio = (round(staleness["p95_ms"] / freshness["p95_ms"], 2)
             if freshness["p95_ms"] else None)
    return {"records": done, "complete": done == wl.total_ops,
            "wall_s": round(wall, 4),
            "records_s": round(done / wall) if wall else 0,
            "n_workers": n_workers,
            "freshness": freshness, "staleness": staleness,
            "staleness_p95_over_freshness_p95": ratio,
            "epoch": engine.snapshot().epoch,
            "rows_folded": engine.snapshot().rows_folded}


# ------------------------------------------------------------ batched plane
def _batch_mix(batch: int) -> List[ReportQuery]:
    """Deterministic heterogeneous mix: 75% per-unit OEE point queries
    (the dashboard fan-out shape), the rest spread over view reads,
    top-k downtime, windowed rates, shift reports and cumulative curves."""
    qs: List[ReportQuery] = []
    for i in range(batch):
        j = i % 16
        if j < 12:
            qs.append(ReportQuery("oee", unit=i % N_UNITS))
        elif j == 12:
            qs.append(ReportQuery(
                "view", view=("oee_by_equipment" if i % 32 < 16
                              else "production_rate_windows")))
        elif j == 13:
            qs.append(ReportQuery("top_downtime", k=5))
        elif j == 14:
            qs.append(ReportQuery("production_rate"))
        elif i % 32 < 16:
            qs.append(ReportQuery("shift_report"))
        else:
            qs.append(ReportQuery("production_curve"))
    return qs


def _run_loop(rs, queries: Sequence[ReportQuery]) -> list:
    """The status-quo path: one Python-dispatched snapshot read per query."""
    out = []
    for q in queries:
        k = q.kind
        if k == "oee":
            out.append(rs.oee(q.unit))
        elif k == "view":
            out.append(rs.query(q.view))
        elif k == "top_downtime":
            out.append(rs.top_downtime(q.k))
        elif k == "production_rate":
            out.append(rs.production_rate())
        elif k == "shift_report":
            out.append(rs.shift_report())
        elif k == "production_curve":
            out.append(rs.production_curve())
        else:
            out.append(rs.kpi_rollup())
    return out


def _answers_equal(batched, loop_answer) -> bool:
    if isinstance(loop_answer, np.ndarray):          # kpi_rollup payload
        return batched.data["kpi_rollup"].tobytes() == loop_answer.tobytes()
    for key, want in loop_answer.data.items():
        got = batched.data[key]
        if isinstance(want, np.ndarray):
            if np.asarray(got).tobytes() != want.tobytes():
                return False
        elif isinstance(want, float):
            if got != want and not (np.isnan(got) and np.isnan(want)):
                return False
        elif got != want:
            return False
    return True


def bench_batched(n_rows: int, batch_sizes: Sequence[int], reps: int,
                  backend: str = "jax") -> Dict:
    """Compiled-plan batch execution vs the per-query loop. Each repeat
    folds a fresh epoch before EACH side, so both run with cold per-epoch
    memos (neither inherits the other's shared derivations); the headline
    is the median of per-repeat paired ratios. Byte parity between both
    paths is asserted on a shared epoch once per batch size."""
    wh, engine, server = _loaded_server(n_rows, backend)
    engine.prewarm_read()
    rng = np.random.default_rng(99)

    def advance_epoch():
        wh.load_partitioned(synthetic_facts(rng, 256, N_UNITS), N_UNITS)
        engine.fold_pending()

    out: Dict[str, object] = {"rows_preloaded": n_rows, "backend": backend,
                              "mix": "75% point OEE + shared reports",
                              "per_batch": {}}
    for batch in batch_sizes:
        queries = _batch_mix(batch)
        t0 = time.perf_counter()
        plan = compile_queries(queries)
        compile_ms = (time.perf_counter() - t0) * 1e3
        # parity on ONE shared epoch (untimed), then warm both paths
        advance_epoch()
        rs = server.snapshot()
        parity_ok = all(_answers_equal(a, b) for a, b in
                        zip(plan.execute(rs).reports(),
                            _run_loop(rs, queries)))
        exec_ms, rep_ms, loop_ms, ratios = [], [], [], []
        epochs: List[int] = []
        for _ in range(reps):
            advance_epoch()
            rs_b = server.snapshot()
            t0 = time.perf_counter()
            res = plan.execute(rs_b)             # columnar answer
            t1 = time.perf_counter()
            res.reports()                        # per-query materialization
            t2 = time.perf_counter()
            advance_epoch()
            rs_l = server.snapshot()
            t3 = time.perf_counter()
            _run_loop(rs_l, queries)
            t4 = time.perf_counter()
            e, r, l = [(b - a) * 1e3 for a, b in
                       ((t0, t1), (t1, t2), (t3, t4))]
            exec_ms.append(round(e, 4))
            rep_ms.append(round(r, 4))
            loop_ms.append(round(l, 4))
            ratios.append(l / max(e, 1e-9))
            epochs.append(res.epoch)
        e_med, r_med, l_med = (_median(exec_ms), _median(rep_ms),
                               _median(loop_ms))
        out["per_batch"][str(batch)] = {
            "batch": batch,
            "plan_compile_ms": round(compile_ms, 4),
            "exec_ms_runs": exec_ms, "loop_ms_runs": loop_ms,
            "exec_ms": e_med, "reports_ms": r_med, "loop_ms": l_med,
            "qps_columnar": round(batch / (e_med * 1e-3)),
            "qps_materialized": round(batch / ((e_med + r_med) * 1e-3)),
            "qps_loop": round(batch / (l_med * 1e-3)),
            "paired_speedups": [round(x, 2) for x in ratios],
            "speedup_vs_loop": round(_median(ratios), 2),
            "parity_ok": bool(parity_ok),
            "epochs_monotonic": epochs == sorted(epochs)
            and len(set(epochs)) == len(epochs),
        }
    largest = out["per_batch"][str(max(batch_sizes))]
    out["speedup_at_largest"] = largest["speedup_vs_loop"]
    out["qps_at_largest"] = largest["qps_columnar"]
    out["parity_ok"] = all(v["parity_ok"]
                           for v in out["per_batch"].values())
    return out


# ---------------------------------------------------------------- scan folds
def bench_scan_fold(window_sizes: Sequence[int], reps: int,
                    backend: str = "jax", delta_rows: int = 4096) -> Dict:
    """Associative-scan windowed folds, both sides of the story.

    READ side (the win): ONE ``prefix_fold`` scan answers all S
    cumulative-window prefixes vs recomputing each window's prefix with
    the bitwise-identical tree chaining (``prefix_fold_reference``) — the
    O(S log S) vs O(S^2) gap that opens decisively by S >= 128.

    WRITE side (the honest negative): ``fold_segments_scan`` vs the
    unrolled halving tree on the same delta — bitwise-identical results,
    but the scan computes S-1 prefixes it throws away and XLA does not
    dead-code them, so the tree stays the engine default on CPU hosts."""
    b = get_backend(backend)
    rng = np.random.default_rng(5)
    out: Dict[str, object] = {"backend": backend,
                              "delta_rows": delta_rows, "per_windows": {}}
    for S in window_sizes:
        spec = production_rate_windows(n_windows=S)
        facts = synthetic_facts(rng, delta_rows, N_UNITS)
        seg, vals = spec.segments(facts), spec.values(facts)
        table = b.fold_segments(seg, vals, S)
        b.prefix_fold(table)                         # jit warm-up
        b.fold_segments_scan(seg, vals, S)
        read_scan, read_tree, rratios = [], [], []
        write_tree, write_scan, wratios = [], [], []
        bitwise = True
        for _ in range(reps):
            t0 = time.perf_counter()
            cum = b.prefix_fold(table)
            t1 = time.perf_counter()
            ref = prefix_fold_reference(table)
            t2 = time.perf_counter()
            tree = b.fold_segments(seg, vals, S)
            t3 = time.perf_counter()
            scan = b.fold_segments_scan(seg, vals, S)
            t4 = time.perf_counter()
            bitwise &= (cum.tobytes() == ref.tobytes()
                        and tree.tobytes() == scan.tobytes())
            rs_ms, rt_ms = (t1 - t0) * 1e3, (t2 - t1) * 1e3
            wt_ms, ws_ms = (t3 - t2) * 1e3, (t4 - t3) * 1e3
            read_scan.append(round(rs_ms, 4))
            read_tree.append(round(rt_ms, 4))
            rratios.append(rt_ms / max(rs_ms, 1e-9))
            write_tree.append(round(wt_ms, 4))
            write_scan.append(round(ws_ms, 4))
            wratios.append(wt_ms / max(ws_ms, 1e-9))
        out["per_windows"][str(S)] = {
            "windows": S,
            "read_scan_ms": _median(read_scan),
            "read_per_window_tree_ms": _median(read_tree),
            "read_speedup_scan_vs_per_window_tree":
                round(_median(rratios), 2),
            "write_tree_ms": _median(write_tree),
            "write_scan_ms": _median(write_scan),
            "write_tree_over_scan": round(_median(wratios), 3),
            "bitwise_ok": bitwise,
        }
    largest = out["per_windows"][str(max(window_sizes))]
    out["read_speedup_at_largest"] = \
        largest["read_speedup_scan_vs_per_window_tree"]
    out["bitwise_ok"] = all(v["bitwise_ok"]
                            for v in out["per_windows"].values())
    return out


def summary(quick: bool = False) -> Dict[str, float]:
    """Headline numbers for benchmarks/run.py's CSV report."""
    sizes = (4_000, 16_000) if quick else (10_000, 40_000)
    q = bench_query_latency(sizes, reps=3)
    wl = Workload(n_base=800, waves=2, chunk=800, n_partitions=8,
                  join_depth=2)
    s = bench_staleness(wl)
    bt = bench_batched(8_000 if quick else 40_000, (1024,), reps=3)
    sf = bench_scan_fold((128,), reps=2)
    return {
        "speedup_view_vs_rescan_at_largest": q["speedup_at_largest"],
        "parity_ok": q["parity_ok"],
        "staleness_p95_ms": s["staleness"]["p95_ms"],
        "freshness_p95_ms": s["freshness"]["p95_ms"],
        "staleness_over_freshness_p95":
            s["staleness_p95_over_freshness_p95"],
        "complete": s["complete"],
        "batched_speedup_at_1024": bt["speedup_at_largest"],
        "batched_qps_at_1024": bt["qps_at_largest"],
        "batched_parity_ok": bt["parity_ok"],
        "scan_read_speedup_at_128": sf["read_speedup_at_largest"],
        "scan_bitwise_ok": sf["bitwise_ok"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI harness check)")
    ap.add_argument("--backend", default="jax")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--rate", type=float, default=6_000.0,
                    help="staleness-run arrival rate, records/s "
                         "(0 = firehose; full mode only)")
    ap.add_argument("--out", default="BENCH_views.json")
    args = ap.parse_known_args()[0]

    if args.smoke:
        sizes = (5_000, 20_000)
        reps = args.reps or 3
        threads = (1, 4)
        queries = 200
        conc_rows = 20_000
        batch_sizes = (256, 1024)       # gate needs >= 1024
        scan_windows = (32, 128)        # gate needs >= 128
        wl = Workload(n_base=800, waves=2, chunk=800, n_partitions=8,
                      join_depth=2, backend=args.backend)
    else:
        sizes = (50_000, 200_000, 800_000)
        reps = args.reps or 7
        threads = (1, 4, 16)
        queries = 500
        conc_rows = 200_000
        batch_sizes = (64, 256, 1024, 4096)
        scan_windows = (128, 256, 512)
        # staleness is a STEADY-STATE metric: pace arrival below the
        # host's saturation capacity (firehose arrival measures backlog
        # drain, where the fold stage is starved along with everything
        # else and staleness just mirrors queue depth — see
        # docs/BENCHMARKS.md)
        wl = Workload(n_base=4_000, waves=30, chunk=4_000, join_depth=8,
                      rate=args.rate, backend=args.backend)

    results = {
        "note": ("read-side serving claims; paired/interleaved medians on "
                 "a noisy shared host (docs/BENCHMARKS.md methodology)"),
        "n_units": N_UNITS,
        "query_latency": bench_query_latency(sizes, reps, args.backend),
    }
    print("query_latency:", json.dumps(results["query_latency"]["per_size"],
                                       indent=2))
    results["concurrency"] = bench_concurrency(conc_rows, threads, queries,
                                               args.backend)
    print("concurrency:", json.dumps(results["concurrency"], indent=2))
    results["staleness_e2e"] = bench_staleness(wl)
    print("staleness_e2e:", json.dumps(results["staleness_e2e"], indent=2))
    results["batched"] = bench_batched(conc_rows, batch_sizes, reps,
                                       args.backend)
    print("batched:", json.dumps(results["batched"]["per_batch"], indent=2))
    results["scan_fold"] = bench_scan_fold(scan_windows, max(reps - 2, 2),
                                           args.backend)
    print("scan_fold:", json.dumps(results["scan_fold"]["per_windows"],
                                   indent=2))

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
