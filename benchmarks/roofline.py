"""Roofline table builder: reads the dry-run artifacts in experiments/dryrun
and emits the per-(arch x shape) three-term roofline table used by
EXPERIMENTS.md §Roofline, plus the perf-iteration comparator.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def load_cells(out_dir: str = "experiments/dryrun", mesh: str = "pod"
               ) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        d = json.load(open(f))
        if d.get("status") != "ok":
            continue
        r = d.get("roofline", {})
        mem = d.get("memory", {})
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "kind": d["kind"],
            "t_compute_s": r.get("t_compute_s"),
            "t_memory_s": r.get("t_memory_s"),
            "t_collective_s": r.get("t_collective_s"),
            "dominant": r.get("dominant"),
            "bound_s": r.get("bound_s"),
            "model_flops": d.get("model_flops"),
            "model_flops_ratio": d.get("model_flops_ratio"),
            "peak_gb": (mem.get("peak_estimate_bytes", 0) or 0) / 1e9,
            "tokens_per_step": d.get("tokens_per_step"),
            "compile_s": d.get("compile_s"),
        })
    return rows


def roofline_fraction(row: Dict) -> Optional[float]:
    """Useful-model-FLOPs utilization at the roofline bound: model_flops /
    (bound_s * chips * peak). This is the §Perf score: 1.0 would mean the
    step is compute-bound AND does zero non-model work."""
    if not row.get("bound_s") or not row.get("model_flops"):
        return None
    return row["model_flops"] / (row["bound_s"] * 256 * PEAK_FLOPS_BF16)


def format_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | dominant | "
           "6ND/HLO | roofline-frac | peak GB/dev |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        rf = roofline_fraction(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} | "
            f"{r['t_collective_s']:.3g} | {r['dominant']} | "
            f"{(r['model_flops_ratio'] or 0):.2f} | "
            f"{(rf or 0):.4f} | {r['peak_gb']:.1f} |")
    return "\n".join(out)


def main():
    rows = load_cells()
    print(format_table(rows))
    print()
    worst = sorted((r for r in rows if roofline_fraction(r)),
                   key=roofline_fraction)[:3]
    print("worst roofline fractions:",
          [(r["arch"], r["shape"], round(roofline_fraction(r), 4))
           for r in worst])
    coll = sorted(rows, key=lambda r: -(r["t_collective_s"] or 0))[:3]
    print("most collective-bound:",
          [(r["arch"], r["shape"], round(r["t_collective_s"], 2))
           for r in coll])


if __name__ == "__main__":
    main()
