"""Dispatch-granularity + compute-backend benchmark (the tentpole measurement).

Compares, on the steelworks workload:

  * ``legacy``    — the seed hot path: one jitted dispatch PER PARTITION per
                    worker per step, per-pump Python-set rebuild of assigned
                    business keys + ``np.isin`` filtering (reproduced here
                    verbatim from the pre-refactor loop),
  * ``coalesced`` — the refactored path: ``consume_many`` coalesces every
                    assigned partition into one columnar batch, ONE backend
                    dispatch per worker per step, facts split per partition
                    only at ``warehouse.load`` time,

for each registered compute backend, and records everything in
``BENCH_backends.json``.

    PYTHONPATH=src python -m benchmarks.backend_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional

import numpy as np

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.core.partitioning import partition_of
from repro.data.sampler import SamplerConfig, SteelworksSampler


def build(backend: str, n_records: int, n_partitions: int, n_workers: int
          ) -> DODETLPipeline:
    import dataclasses
    cfg = steelworks_config(n_partitions=n_partitions, backend=backend)
    # size caches so no mid-run _grow() rehash changes device-operand shapes
    # (a growth-triggered recompile inside a measured window is pure noise)
    slots = 1 << max(12, (4 * n_records // n_partitions).bit_length())
    cfg = dataclasses.replace(cfg, cache_slots=slots)
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n_records, n_equipment=n_partitions,
        late_master_frac=0.02)).generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers)
    pipe.extract()
    pipe.bootstrap_caches()
    return pipe


# --------------------------------------------------------------- seed loop
def legacy_pump_master(worker, topic: str, cache) -> int:
    """The seed In-memory Table Updater loop: per-partition consume, Python
    set rebuilt per pump, ``np.isin`` membership filtering."""
    n = 0
    bkeys = None
    for p in worker.partitions_for_master(topic):
        batch = worker.queue.consume(worker.group, topic, p)
        if not len(batch):
            continue
        worker.queue.commit(worker.group, topic, p, len(batch))
        if bkeys is None:
            keys = np.arange(worker.cfg.n_business_keys, dtype=np.int64)
            parts = partition_of(keys, worker.cfg.n_partitions)
            own = set(worker.partitions)
            bkeys = {int(k) for k, q in zip(keys, parts) if q in own}
        mask = np.isin(batch.business_key, list(bkeys))
        mine = batch.filter(mask)
        if not len(mine):
            continue
        if cache is worker.quality:
            join_keys = mine.payload[:, 3].astype(np.int64)
        else:
            join_keys = mine.payload[:, 1].astype(np.int64)
        cache.upsert(join_keys, mine.payload, mine.txn_time)
        n += len(mine)
    return n


def legacy_step(pipe: DODETLPipeline, cap: Optional[int]) -> int:
    """The seed Stream Processor step: one transform dispatch per partition
    per worker (the per-partition loop the refactor replaced)."""
    done = 0
    for w in pipe.workers:
        legacy_pump_master(w, pipe.master_topic_map["equipment"], w.equipment)
        legacy_pump_master(w, pipe.master_topic_map["quality"], w.quality)
    for w in pipe.workers:
        for topic in pipe.operational_topics:
            for p in w.partitions:
                batch = pipe.queue.consume(w.group, topic, p, cap)
                if len(batch):
                    pipe.queue.commit(w.group, topic, p, len(batch))
                facts, _ = w.transformer.process(batch)
                w.warehouse.load(p, facts)
                done += len(facts)
    return done


# -------------------------------------------------------------- measurement
def prewarm(pipe: DODETLPipeline, max_bucket: int = 4096) -> None:
    """Compile every power-of-two transform bucket the run can hit so NO jit
    compilation lands inside either measured window (buckets are shared
    process-wide, so measurement order would otherwise bias the comparison)."""
    be = pipe.backend
    if not be.device:
        return
    w = pipe.workers[0]
    size = 256 if be.name == "pallas" else 1
    while size <= max_bucket:
        dummy = np.full((size, 8), -1.0, np.float32)
        be.transform(dummy, w.equipment, w.quality,
                     join_depth=w.transformer.join_depth)
        if w.transformer.n_units:        # the fused rollup variant both
            be.transform_and_rollup(     # measured loops now dispatch
                dummy, w.equipment, w.quality,
                n_units=w.transformer.n_units,
                join_depth=w.transformer.join_depth).to_host()
        size *= 2


def run_stream(pipe: DODETLPipeline, legacy: bool, cap: int,
               warm_steps: int = 2) -> Dict[str, float]:
    if legacy:
        # faithful seed dispatch: the seed loop had no fused rollup riding
        # the transform kernel — without this the reference arm would pay
        # per-dispatch rollup cost it never paid, inflating the speedup
        for w in pipe.workers:
            w.transformer.n_units = None
    step = (lambda: legacy_step(pipe, cap)) if legacy else \
        (lambda: pipe.step(cap))
    prewarm(pipe)
    for _ in range(warm_steps):            # host-path warm-up
        step()
    warm_dispatches = sum(w.transformer.dispatches for w in pipe.workers)
    total, steps = 0, 0
    t0 = time.perf_counter()
    while True:
        n = step()
        if n == 0:
            break
        total += n
        steps += 1
    wall = time.perf_counter() - t0
    dispatches = sum(w.transformer.dispatches
                     for w in pipe.workers) - warm_dispatches
    return {
        "records": total,
        "steps": steps,
        "wall_s": round(wall, 4),
        "records_s": round(total / wall) if wall > 0 else 0,
        "transform_dispatches": dispatches,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_backends.json")
    args = ap.parse_known_args()[0]

    n_records = 4_000 if args.quick else 16_000
    n_partitions, n_workers, cap = 20, 2, 32    # paper: 20 partitions
    pallas_records = 512 if args.quick else 2_048  # interpret mode is slow

    results: Dict[str, dict] = {
        "workload": {
            "n_records": n_records, "n_partitions": n_partitions,
            "n_workers": n_workers, "max_records_per_partition": cap,
            "pallas_n_records": pallas_records,
            "note": ("pallas runs interpret-mode on CPU hosts (correctness "
                     "twin, not a timing proxy) on a reduced workload"),
        },
        "coalesced": {}, "legacy_per_partition": {},
    }

    def median_run(backend: str, legacy: bool, n: int, repeats: int):
        runs = []
        for _ in range(repeats):
            pipe = build(backend, n, n_partitions, n_workers)
            runs.append(run_stream(pipe, legacy, cap))
        runs.sort(key=lambda r: r["records_s"])
        return runs[len(runs) // 2]

    # the headline comparison runs INTERLEAVED (legacy, coalesced, legacy,
    # ...) so slow host phases hit both variants alike; medians of 5 damp
    # the rest of the container noise
    reps = 2 if args.quick else 5
    legacy_runs, coalesced_runs = [], []
    for _ in range(reps):
        legacy_runs.append(
            run_stream(build("jax", n_records, n_partitions, n_workers),
                       True, cap))
        coalesced_runs.append(
            run_stream(build("jax", n_records, n_partitions, n_workers),
                       False, cap))
    for runs, key in ((legacy_runs, "legacy_per_partition"),
                      (coalesced_runs, "coalesced")):
        runs.sort(key=lambda r: r["records_s"])
        results[key]["jax"] = runs[len(runs) // 2]
        results[key]["jax"]["records_s_runs"] = \
            [r["records_s"] for r in runs]
    print(f"legacy/jax: {results['legacy_per_partition']['jax']}")
    print(f"coalesced/jax: {results['coalesced']['jax']}")

    for backend in ("numpy", "pallas"):
        n = pallas_records if backend == "pallas" else n_records
        results["coalesced"][backend] = {
            "n_records": n, **median_run(backend, False, n,
                                         1 if backend == "pallas" else 3)}
        print(f"coalesced/{backend}: {results['coalesced'][backend]}")

    fast = results["coalesced"]["jax"]["records_s"]
    slow = results["legacy_per_partition"]["jax"]["records_s"]
    results["speedup_coalesced_vs_legacy_jax"] = round(fast / max(slow, 1), 2)
    print(f"speedup (jax, coalesced vs seed per-partition loop): "
          f"{results['speedup_coalesced_vs_legacy_jax']}x")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
