"""Batched read-path tests: packed query plans byte-identical to the
per-query loop on every backend, scan-form folds bitwise-equal to the
halving tree, prefix folds bitwise-equal to the block-chained tree
oracle, argpartition top-k == lexsort, per-epoch memoization, and the
admission front's pinned-epoch batching (including batches that span an
epoch swap)."""
import math
import threading

import numpy as np
import pytest

from repro.core.backend import (bitrev_permutation, empty_fold_state,
                                fold_width, gather_width, get_backend,
                                prefix_fold_reference)
from repro.data.sampler import synthetic_facts
from repro.serving import (BatchedReportServer, MaterializedViewEngine,
                           ReportQuery, ReportServer, ReportSnapshot,
                           compile_queries, downtime_by_equipment,
                           downtime_rank_keys, steelworks_views)

N_UNITS = 8
BACKENDS = ["numpy", "jax", "pallas"]


def loaded_server(backend="numpy", n_deltas=4, rows=400, seed=0,
                  scan_fold=False):
    rng = np.random.default_rng(seed)
    eng = MaterializedViewEngine(steelworks_views(N_UNITS), backend=backend,
                                 scan_fold=scan_fold)
    for i in range(n_deltas):
        facts = synthetic_facts(rng, rows, N_UNITS, valid_frac=0.85)
        eng.publish(facts, event_times=np.full(len(facts), float(i)))
        eng.fold_pending()
    return ReportServer(eng)


HETERO_QUERIES = (
    [ReportQuery("oee", unit=u) for u in range(N_UNITS)]
    + [ReportQuery("view", view="oee_by_equipment"),
       ReportQuery("view", view="production_rate_windows"),
       ReportQuery("oee"),                       # fleet-wide
       ReportQuery("top_downtime", k=3),
       ReportQuery("top_downtime", k=N_UNITS + 5),
       ReportQuery("production_rate"),
       ReportQuery("production_curve"),
       ReportQuery("shift_report"),
       ReportQuery("kpi_rollup"),
       ReportQuery("oee", unit=N_UNITS - 1)])    # duplicate point query


def single_query(rs, q):
    """The per-query loop the batch plane must reproduce byte-for-byte."""
    return {"view": lambda: rs.query(q.view),
            "oee": lambda: rs.oee(q.unit),
            "top_downtime": lambda: rs.top_downtime(q.k),
            "production_rate": rs.production_rate,
            "production_curve": rs.production_curve,
            "shift_report": rs.shift_report,
            "kpi_rollup": rs.kpi_rollup}[q.kind]()


def assert_report_equal(batched, oracle, qkind):
    if qkind == "kpi_rollup":
        assert batched.data["kpi_rollup"].tobytes() == oracle.tobytes()
        return
    assert batched.epoch == oracle.epoch
    assert batched.rows == oracle.rows
    assert set(batched.data) == set(oracle.data)
    for key, want in oracle.data.items():
        got = batched.data[key]
        if isinstance(want, np.ndarray):
            assert np.asarray(got).tobytes() == want.tobytes(), key
        elif isinstance(want, float):
            assert got == want or (math.isnan(got) and math.isnan(want)), key
        else:
            assert got == want, key


# ===================================================== batched query parity
@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_parity_every_kind_every_backend(backend):
    """One plan-execute answers a mixed heterogeneous batch byte-identically
    to the per-query loop on the SAME pinned snapshot."""
    srv = loaded_server(backend)
    rs = srv.snapshot()
    res = compile_queries(HETERO_QUERIES).execute(rs)
    reports = res.reports()
    assert len(reports) == len(HETERO_QUERIES)
    for q, rep in zip(HETERO_QUERIES, reports):
        assert_report_equal(rep, single_query(rs, q), q.kind)


def test_batched_point_dispatch_is_one_gather():
    """A thousand per-unit OEE queries cost ONE backend dispatch, not a
    thousand."""
    srv = loaded_server("numpy")
    rs = srv.snapshot()
    plan = compile_queries([ReportQuery("oee", unit=i % N_UNITS)
                            for i in range(1000)])
    b = srv.engine.backend
    before = b.op_dispatches
    res = plan.execute(rs)
    assert b.op_dispatches - before == 1
    assert res.point_stats[0].shape == (1000, gather_width(4))


def test_empty_and_singleton_batches():
    srv = loaded_server("numpy")
    rs = srv.snapshot()
    empty = compile_queries([]).execute(rs)
    assert len(empty) == 0 and empty.reports() == []
    one = compile_queries([ReportQuery("oee", unit=3)]).execute(rs)
    assert_report_equal(one.reports()[0], rs.oee(3), "oee")


def test_plan_reuse_across_epochs_monotonic_stamps():
    """A compiled plan is epoch-agnostic: re-executing it against newer
    snapshots yields strictly monotonic epoch stamps and fresh data."""
    srv = loaded_server("numpy", n_deltas=1)
    plan = compile_queries(HETERO_QUERIES)
    rng = np.random.default_rng(7)
    epochs = []
    for i in range(4):
        res = plan.execute(srv.snapshot())
        epochs.append(res.epoch)
        for rep in res.reports():
            assert rep.epoch == res.epoch
        facts = synthetic_facts(rng, 100, N_UNITS, valid_frac=0.9)
        srv.engine.publish(facts, event_times=np.full(100, float(i)))
        srv.engine.fold_pending()
    assert epochs == sorted(epochs) and len(set(epochs)) == len(epochs)


def test_compile_validation():
    with pytest.raises(ValueError):
        compile_queries([ReportQuery("nonsense")])
    with pytest.raises(ValueError):
        compile_queries([ReportQuery("view")])          # view required
    with pytest.raises(ValueError):
        compile_queries([ReportQuery("top_downtime", k=0)])
    with pytest.raises(ValueError):
        compile_queries([ReportQuery("oee", unit=-2)])
    srv = loaded_server("numpy")
    plan = compile_queries([ReportQuery("oee", unit=N_UNITS + 7)])
    with pytest.raises(ValueError):                     # out of range at exec
        plan.execute(srv.snapshot())


def test_descriptor_roundtrip():
    """The packed wire format reconstructs an equivalent plan."""
    from repro.serving.batch import QueryPlan
    srv = loaded_server("numpy")
    rs = srv.snapshot()
    plan = compile_queries(HETERO_QUERIES)
    clone = QueryPlan(*plan.descriptors(), views=plan.views)
    a = plan.execute(rs).reports()
    b = clone.execute(rs).reports()
    for q, ra, rb in zip(HETERO_QUERIES, a, b):
        if q.kind == "kpi_rollup":
            assert ra.data["kpi_rollup"].tobytes() == \
                rb.data["kpi_rollup"].tobytes()
        else:
            assert_report_equal(ra, rb, q.kind)


# ========================================================== admission front
def test_front_batches_concurrent_submitters():
    srv = loaded_server("numpy")
    rs = srv.snapshot()          # engine idle -> same epoch throughout
    front = BatchedReportServer(srv, max_batch=256, max_wait_ms=20.0)
    front.start()
    results = {}

    def submitter(tid):
        tickets = [(q, front.submit(q)) for q in HETERO_QUERIES]
        results[tid] = [(q, t.result(10.0)) for q, t in tickets]

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    front.stop()
    for tid in results:
        for q, rep in results[tid]:
            assert_report_equal(rep, single_query(rs, q), q.kind)
    st = front.stats()
    assert st["queries"] == 4 * len(HETERO_QUERIES)
    assert st["max_batch"] > 1          # coalescing actually happened


def test_front_batch_spanning_two_epochs():
    """Queries pinned before and after a fold land in ONE coalesced batch
    but carry their OWN epoch/staleness stamps."""
    srv = loaded_server("numpy", n_deltas=2)
    front = BatchedReportServer(srv, max_batch=64, max_wait_ms=50.0)
    # admit with no dispatcher running, fold between admissions, then drain
    t1 = [front.submit(ReportQuery("oee", unit=u)) for u in range(N_UNITS)]
    rng = np.random.default_rng(3)
    facts = synthetic_facts(rng, 200, N_UNITS, valid_frac=0.9)
    srv.engine.publish(facts, event_times=np.full(200, 9.0))
    srv.engine.fold_pending()
    t2 = [front.submit(ReportQuery("oee", unit=u)) for u in range(N_UNITS)]
    e1 = {t.result(10.0).epoch for t in t1}
    e2 = {t.result(10.0).epoch for t in t2}
    assert len(e1) == 1 and len(e2) == 1
    assert e2 != e1                      # each query kept its pinned epoch
    # and each group's answers match a direct read of its own snapshot
    for u, t in enumerate(t1):
        oracle = ReportSnapshot(t.snapshot, srv.engine.backend).oee(u)
        assert_report_equal(t.result(), oracle, "oee")


def test_front_accepts_bare_engine_and_stops_clean():
    srv = loaded_server("numpy")
    front = BatchedReportServer(srv.engine, max_batch=8, max_wait_ms=1.0)
    front.start()
    t = front.submit(ReportQuery("production_rate"))
    rep = t.result(10.0)
    front.stop()
    assert rep.view == "production_rate_windows"


# ================================================= scan fold bitwise parity
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case,make", [
    ("empty", lambda rng, S: np.empty(0, np.int64)),
    ("single_row", lambda rng, S: np.array([S // 2])),
    ("single_segment", lambda rng, S: np.full(300, S - 1)),
    ("all_segments", lambda rng, S: np.arange(3 * S) % S),
    ("out_of_range", lambda rng, S: rng.integers(-3, S + 3, 500)),
    ("sparse", lambda rng, S: rng.choice([1, S - 2], 200)),
    ("multi_block", lambda rng, S: rng.integers(0, S, 5000)),
])
def test_fold_segments_scan_bitwise_equals_tree(backend, case, make):
    """The associative-scan fold is bitwise-identical to the halving tree
    on EVERY backend (bit-reversal aligns the combine orders) — so either
    form satisfies the serving layer's determinism contract."""
    rng = np.random.default_rng(5)
    S, L = 32, 2
    seg = np.asarray(make(rng, S), np.int64)
    vals = rng.normal(scale=4, size=(len(seg), L)).astype(np.float32)
    tree = get_backend("numpy").fold_segments(seg, vals, S)
    scan = get_backend(backend).fold_segments_scan(seg, vals, S)
    assert scan.tobytes() == tree.tobytes()


def test_bitrev_permutation_contract():
    assert list(bitrev_permutation(8)) == [0, 4, 2, 6, 1, 5, 3, 7]
    assert list(bitrev_permutation(1)) == [0]
    with pytest.raises(ValueError):
        bitrev_permutation(6)


def test_engine_scan_fold_byte_identical_state():
    """An engine folding windowed views through the scan op publishes
    byte-identical epochs to the tree engine (and rebuild stays a valid
    oracle for both)."""
    rng = np.random.default_rng(11)
    chunks = [synthetic_facts(rng, 300, N_UNITS, valid_frac=0.8)
              for _ in range(3)]
    tree_snap = MaterializedViewEngine.rebuild(
        steelworks_views(N_UNITS), chunks, backend="numpy")
    scan_snap = MaterializedViewEngine.rebuild(
        steelworks_views(N_UNITS), chunks, backend="numpy", scan_fold=True)
    for name, st in tree_snap.states.items():
        assert st.table.tobytes() == scan_snap.states[name].table.tobytes()


# ================================================ prefix fold (curve) parity
@pytest.mark.parametrize("backend", BACKENDS)
def test_prefix_fold_bitwise_equals_reference(backend):
    rng = np.random.default_rng(6)
    for S, L, n in [(1, 1, 4), (5, 2, 40), (32, 2, 200), (100, 3, 700)]:
        seg = rng.integers(0, max(S - 2, 1), n)    # leave empty windows
        vals = rng.normal(size=(n, L)).astype(np.float32)
        table = get_backend("numpy").fold_segments(seg, vals, S)
        out = get_backend(backend).prefix_fold(table)
        assert out.shape == (S, fold_width(L))
        assert out.tobytes() == prefix_fold_reference(table).tobytes()


def test_prefix_fold_identity_and_empty():
    nb = get_backend("numpy")
    ident = empty_fold_state(16, 2)
    out = nb.prefix_fold(ident)
    assert out.tobytes() == ident.tobytes()     # identity is absorbing
    assert nb.prefix_fold(np.zeros((0, 7), np.float32)).shape == (0, 7)


def test_production_curve_semantics():
    """Curve row w == plain combine of windows [0, w] (values, not just
    bit-association): cross-check counts and sums against a direct
    recompute."""
    srv = loaded_server("numpy")
    rs = srv.snapshot()
    st = rs.snap.view("production_rate_windows")
    rep = rs.production_curve()
    want = np.cumsum(st.count)
    np.testing.assert_array_equal(rep.data["count"], want)
    np.testing.assert_allclose(rep.data["sum"], np.cumsum(st.sums, axis=0),
                               rtol=1e-5, atol=1e-5)
    # min/max are running extrema over non-empty windows
    run_min = np.minimum.accumulate(st.mins, axis=0)
    np.testing.assert_array_equal(rep.data["min"], run_min)
    with pytest.raises(ValueError):
        rs.production_curve("oee_by_equipment")   # not windowed


# ==================================================== top-k downtime parity
def test_topk_matches_lexsort_including_ties():
    down = np.array([5.0, 5.0, 1.0, 9.0, 5.0, 0.0, -0.0, 9.0], np.float32)
    up = 100.0 - down
    eng = MaterializedViewEngine([downtime_by_equipment(len(down))],
                                 backend="numpy")
    facts = np.zeros((len(down), 10), np.float32)
    facts[:, 0] = np.arange(len(down))
    facts[:, 8] = down
    facts[:, 7] = up
    facts[:, 9] = 1.0
    eng.publish(facts)
    eng.fold_pending()
    rs = ReportServer(eng).snapshot()
    lane = rs.snap.view("downtime_by_equipment").sums[:, 0]
    oracle = np.lexsort((np.arange(len(lane)), -lane))
    for k in (1, 2, 3, len(down), len(down) + 10):
        rep = rs.top_downtime(k)
        np.testing.assert_array_equal(rep.data["unit"],
                                      oracle[:min(k, len(down))])
    # -0.0 and +0.0 rank as equal (tie broken by unit id)
    keys = downtime_rank_keys(np.array([0.0, -0.0], np.float32))
    assert (keys >> np.uint64(32))[0] == (keys >> np.uint64(32))[1]


def test_rank_keys_reproduce_lexsort_on_random_lanes():
    rng = np.random.default_rng(9)
    for _ in range(20):
        down = rng.choice([0.0, 1.5, 1.5, 7.25, -3.0, 7.25],
                          size=rng.integers(1, 40)).astype(np.float32)
        oracle = np.lexsort((np.arange(len(down)), -down))
        got = np.argsort(downtime_rank_keys(down))
        np.testing.assert_array_equal(got, oracle)


# ================================================ memoization + read-only
def test_epoch_memo_shared_across_readers():
    srv = loaded_server("numpy")
    rs1, rs2 = srv.snapshot(), srv.snapshot()
    assert rs1.snap is rs2.snap
    a = rs1.query("oee_by_equipment").data["mean"]
    b = rs2.query("oee_by_equipment").data["mean"]
    assert a is b                        # computed once per epoch
    assert rs1.kpi_rollup() is rs2.kpi_rollup()
    assert rs1.production_curve().data["count"].base is \
        rs2.production_curve().data["count"].base
    # a new epoch gets a fresh memo
    srv.engine.publish(synthetic_facts(np.random.default_rng(2), 50,
                                       N_UNITS, valid_frac=1.0))
    srv.engine.fold_pending()
    assert srv.snapshot().query("oee_by_equipment").data["mean"] is not a


def test_memo_concurrent_readers_compute_once():
    srv = loaded_server("numpy")
    snap = srv.engine.snapshot()
    calls = []
    barrier = threading.Barrier(8)

    def compute():
        calls.append(1)
        return object()

    got = []

    def reader():
        barrier.wait()
        got.append(snap.shared("k", compute))

    threads = [threading.Thread(target=reader) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(calls) == 1 and all(g is got[0] for g in got)


def test_report_payloads_are_read_only_views():
    srv = loaded_server("numpy")
    rs = srv.snapshot()
    for rep in (rs.query("oee_by_equipment"), rs.production_rate(),
                rs.shift_report(), rs.production_curve(),
                rs.top_downtime(3)):
        for v in rep.data.values():
            if isinstance(v, np.ndarray) and v.size:
                writeable = v.flags.writeable
                owns = v.base is None and v.flags.owndata
                # views of epoch state must be frozen; small per-query
                # materializations (top-k gathers) may own their memory
                assert owns or not writeable
    assert not rs.kpi_rollup().flags.writeable
    with pytest.raises(ValueError):
        rs.query("oee_by_equipment").data["count"][0] = 99.0
