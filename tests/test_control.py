"""Self-healing control plane drills (ROADMAP item 4: autonomous
failure detection, supervised restart, credit-based backpressure and the
elastic scaling loop).

The drills follow the recovery-suite pattern: run the REAL concurrent
runtime over a pre-extracted stream (so a byte-identity oracle exists),
inject a fault at a control seam — a hang (grey failure), a stage-thread
crash, a poison record, a failing restart — and assert the control plane
heals the cluster with exactly-once results: the final warehouse is
byte-identical to an uninterrupted sequential run, nothing is lost,
nothing duplicated, and no human call was needed.
"""
import dataclasses
import threading
import time
import warnings

import numpy as np
import pytest

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.core.message_queue import MessageQueue, TopicConfig
from repro.core.records import make_batch
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.durability.faults import (HEARTBEAT_MISS, INGEST_FETCH,
                                     RESTART_PRE_HYDRATE, TRANSFORM_DONE,
                                     FaultInjector)
from repro.runtime.cluster import ConcurrentCluster
from repro.runtime.control import (ControlConfig, CreditLedger,
                                   QuiesceTimeout, QuiesceTimeoutWarning)

# fast supervision for the numpy backend: sub-second detection without
# flapping on a loaded CI box
FAST = dict(tick_s=0.02, heartbeat_deadline_s=0.4, ping_grace_s=0.2,
            warmup_s=0.2, restart_backoff_s=0.05, restart_backoff_max_s=0.5,
            restart_jitter_s=0.02, policy_interval_s=0.1,
            evict_lock_timeout_s=0.5, evict_join_timeout_s=0.5,
            scaling=False)


def build(n_workers, n_records=2500, n_partitions=8, late_frac=0.05,
          fault=None, seed=0):
    cfg = steelworks_config(n_partitions=n_partitions, backend="numpy")
    cfg = dataclasses.replace(cfg, buffer_capacity=4096)
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n_records, n_equipment=n_partitions,
        late_master_frac=late_frac, seed=seed)).generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers, fault=fault)
    return cfg, src, pipe


_ORACLES = {}


def oracle_facts(n_records, n_partitions=8, late_frac=0.05, seed=0):
    """Byte-level fact table of an uninterrupted single-worker run over
    the same pre-extracted stream (memoized per workload)."""
    key = (n_records, n_partitions, late_frac, seed)
    if key not in _ORACLES:
        _, _, pipe = build(1, n_records, n_partitions, late_frac, seed=seed)
        pipe.extract()
        pipe.bootstrap_caches()
        pipe.run_to_completion()
        _ORACLES[key] = pipe.warehouse.canonical_fact_table().tobytes()
    return _ORACLES[key]


def wait_for(predicate, timeout=15.0, interval=0.01):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout:
        if predicate():
            return True
        time.sleep(interval)
    return predicate()


# ================================================================ credit ledger
def test_credit_ledger_conservation():
    led = CreditLedger(100)
    assert led.take(30) == 30
    assert led.available == 70 and led.outstanding == 30
    assert led.take(200) == 70          # clamps to available, never blocks
    assert led.take(10) == 0            # exhausted: zero grant, no deadlock
    assert led.exhausted()
    led.refund(30)
    assert led.available == 30 and led.outstanding == 70
    led.refund(70)
    assert led.available == led.capacity and led.outstanding == 0
    assert led.spent == 100 and led.refunded == 100
    led.refund(50)                      # over-refund capped at capacity
    assert led.available == led.capacity
    assert led.take(0) == 0 and led.take(-5) == 0


def test_credit_ledger_concurrent_hammer():
    """Many threads take/refund concurrently: conservation holds at every
    end state and the ledger never grants more than its capacity."""
    led = CreditLedger(256)
    errors = []

    def hammer(seed):
        rng = np.random.default_rng(seed)
        held = 0
        for _ in range(2000):
            if rng.random() < 0.5:
                got = led.take(int(rng.integers(1, 32)))
                if got < 0 or led.available < 0:
                    errors.append("negative grant or balance")
                held += got
            elif held:
                back = int(rng.integers(1, held + 1))
                led.refund(back)
                held -= back
        led.refund(held)

    threads = [threading.Thread(target=hammer, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert led.available == led.capacity
    assert led.outstanding == 0
    assert led.spent == led.refunded


def test_credits_conserved_across_full_run():
    """End-to-end: a full stream through the concurrent runtime spends
    and refunds every credit — at idle each live ledger is whole again
    (a leak here would eventually wedge ingest for good)."""
    n = 2000
    cfg, _, pipe = build(2, n)
    cfg = dataclasses.replace(cfg, credit_capacity=256)  # far below stream
    pipe.cfg = cfg
    pipe.extract()
    cluster = ConcurrentCluster(pipe, poll_cdc=False)
    cluster.start()
    done = cluster.run_until_idle(timeout=60)
    cluster.stop_all()
    assert done == n
    for rt in cluster.runtimes.values():
        assert rt.credits.available == rt.credits.capacity
        assert rt.credits.spent == rt.credits.refunded
        assert rt.credits.spent >= n // len(cluster.runtimes) // 2


def test_credits_exhausted_throttles_extraction():
    cfg, _, pipe = build(2, 100)
    cluster = ConcurrentCluster(pipe, poll_cdc=False)
    assert not cluster._credits_exhausted()
    for rt in cluster.runtimes.values():
        rt.credits.take(rt.credits.capacity)
    assert cluster._credits_exhausted()          # extractor backs off
    next(iter(cluster.runtimes.values())).credits.refund(1)
    assert not cluster._credits_exhausted()      # any headroom resumes


# ================================================================ group fencing
def test_fenced_group_cannot_commit_or_fetch():
    """The zombie-worker fence: after eviction the victim's consumer
    group is dead at the broker — its commits are dropped and its fetches
    return nothing, so a thread that wakes from a hang cannot move
    offsets that now belong to a survivor."""
    q = MessageQueue()
    q.create_topic(TopicConfig("t", 0, 2, "business_key"))
    n = 50
    q.publish("t", make_batch(0, 0, np.arange(n), np.arange(n),
                              np.arange(n), np.zeros((n, 8), np.float32)))
    _, counts = q.fetch_many("g", "t", [0, 1])
    assert sum(counts.values()) == n
    q.commit("g", "t", 0, counts[0])
    committed = q.committed("g", "t", 0)

    q.fence_group("g")
    assert q.is_fenced("g")
    q.commit("g", "t", 1, counts[1])             # zombie commit: dropped
    assert q.committed("g", "t", 1) == 0
    assert q.committed("g", "t", 0) == committed
    q.rewind("g", "t", 0), q.rewind("g", "t", 1)
    batch, c2 = q.fetch_many("g", "t", [0, 1])   # zombie fetch: empty
    assert not c2 and len(batch) == 0
    assert q.fenced_commits == 1 and q.fenced_fetches == 1
    # a different (successor) group is unaffected
    _, c3 = q.fetch_many("g2", "t", [0, 1])
    assert sum(c3.values()) == n


# ============================================================== S1: typed joins
def test_quiesce_timeout_is_typed_runtime_error():
    assert issubclass(QuiesceTimeout, RuntimeError)   # API compat: callers
    assert issubclass(QuiesceTimeoutWarning, UserWarning)


def test_join_surfaces_wedged_threads():
    """A stop that strands a stage thread must not read as success:
    ``WorkerRuntime.join`` returns the wedged names, warns, and counts
    them in ``worker.join_timeouts``. The hang sits at the first ingest
    fetch, so the sibling stages drain cleanly and exactly one thread
    wedges."""
    fault = FaultInjector({INGEST_FETCH: 1}, actions={INGEST_FETCH: "hang"})
    cfg, _, pipe = build(1, 200, fault=fault)
    pipe.extract()
    cluster = ConcurrentCluster(pipe, poll_cdc=False)
    cluster.start()
    assert fault.hung.wait(10.0), "hang seam never reached"
    rt = next(iter(cluster.runtimes.values()))
    rt.stop.set()
    with pytest.warns(QuiesceTimeoutWarning):
        wedged = rt.join(timeout=0.3)
    assert len(wedged) == 1                       # exactly the frozen stage
    assert cluster.health()["counters"]["worker.join_timeouts"] == 1
    fault.release_hangs()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", QuiesceTimeoutWarning)
        cluster.stop_all()


# =========================================================== hang (grey) drill
def test_hang_drill_detect_evict_restart_byte_identical():
    """The tentpole grey-failure drill: one stage thread freezes
    mid-stream (a wedged worker that never crashes — ``fail_workers``
    alone cannot see it). The supervisor detects the silent heartbeat,
    confirms via ping, force-evicts (fencing the zombie's group) and
    restarts a re-hydrated replacement — and the stream still finishes
    byte-identical to the uninterrupted sequential oracle."""
    n = 2500
    fault = FaultInjector({TRANSFORM_DONE: 3},
                          actions={TRANSFORM_DONE: "hang"})
    cfg, _, pipe = build(3, n, fault=fault)
    pipe.extract()
    cluster = ConcurrentCluster(pipe, poll_cdc=False,
                                control=ControlConfig(**FAST))
    cluster.start()
    assert fault.hung.wait(10.0), "hang seam never reached"
    assert wait_for(lambda: cluster.control.last_eviction is not None), \
        "supervisor never confirmed the hung worker"
    ev = cluster.control.last_eviction
    assert ev["restarted"] is True
    # detection latency: hang instant -> eviction, bounded by the
    # configured deadline + grace + a few supervision ticks
    latency = ev["at_s"] - fault.hung_at_s
    bound = (FAST["heartbeat_deadline_s"] + FAST["ping_grace_s"]
             + 10 * FAST["tick_s"]
             + 2 * FAST["evict_join_timeout_s"] + 1.5)  # join + CI slack
    assert 0 < latency < bound, (latency, bound)

    done = cluster.run_until_idle(timeout=60)
    with warnings.catch_warnings():               # the wedged daemon thread
        warnings.simplefilter("ignore", QuiesceTimeoutWarning)
        cluster.stop_all()
    fault.release_hangs()
    assert done == n
    assert pipe.warehouse.rows_loaded == n        # zero lost, zero duplicated
    assert pipe.warehouse.canonical_fact_table().tobytes() == oracle_facts(n)

    h = cluster.health()
    assert h["control"]["enabled"] and h["control"]["restarts"] == 1
    assert h["control"]["evictions"] == 1
    assert h["counters"]["control.pings"] >= 1
    assert h["counters"]["worker.join_timeouts"] >= 1  # the frozen thread
    # the replacement took over real ownership
    assert len(cluster.alive_workers()) == 3
    assert ev["worker"] not in cluster.alive_workers()


# ================================================================= crash drill
def test_crash_drill_detect_evict_restart_byte_identical():
    """A stage thread dies outright (fetched-uncommitted window). The
    dead stage stops heartbeating, the supervisor confirms (the ping is
    never acked — the ingest loop is gone) and replaces the worker; the
    fenced group's uncommitted records are re-served to the replacement.
    Exactly-once end to end."""
    n = 2500
    fault = FaultInjector({INGEST_FETCH: 4})
    cfg, _, pipe = build(3, n, fault=fault)
    pipe.extract()
    # cap per-partition fetches so the pre-extracted backlog takes many
    # hand-offs (one giant coalesced fetch would skip the crash ordinal)
    cluster = ConcurrentCluster(pipe, poll_cdc=False,
                                max_records_per_partition=25,
                                control=ControlConfig(**FAST))
    cluster.start()
    assert fault.tripped.wait(10.0), "crash seam never reached"
    assert wait_for(lambda: cluster.control.restarts >= 1), \
        "supervisor never restarted the crashed worker"
    done = cluster.run_until_idle(timeout=60)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", QuiesceTimeoutWarning)
        cluster.stop_all()
    assert done == n
    assert pipe.warehouse.rows_loaded == n
    assert pipe.warehouse.canonical_fact_table().tobytes() == oracle_facts(n)
    snap = cluster.control.snapshot()
    assert snap["restarts"] >= 1 and snap["restart_failures"] == 0
    assert not snap["breaker_open"]
    ev = cluster.control.last_eviction
    assert ev is not None and "ingest" in ev["stale_stages"]


# ================================================================ poison drill
class _PoisonError(Exception):
    pass


def _poison_transform(worker, key):
    """Wrap a worker's transform so any batch containing ``key`` raises a
    plain Exception — a deterministic poison record, not a drill kill."""
    orig = worker.transformer.transform_block

    def wrapped(batch, eq, qu):
        if np.any(batch.business_key == key):
            raise _PoisonError(f"poison key {key}")
        return orig(batch, eq, qu)

    worker.transformer.transform_block = wrapped


def test_poison_records_quarantined_not_crash_looped():
    """Records whose transform deterministically raises are bisected out,
    parked in the dead-letter buffer and their offsets COMMITTED — the
    worker keeps processing everything else, the supervisor never evicts
    (the stages still heartbeat), and nothing crash-loops."""
    n, bad_key = 2500, 3
    cfg, _, pipe = build(2, n, late_frac=0.0)
    for w in pipe.workers:
        _poison_transform(w, bad_key)
    pipe.extract()
    cluster = ConcurrentCluster(pipe, poll_cdc=False,
                                control=ControlConfig(**FAST))
    cluster.start()
    cluster.run_until_idle(timeout=60)
    cluster.stop_all()

    quarantined = sum(len(rt.worker.dead_letter)
                      for rt in cluster.runtimes.values())
    assert quarantined > 0
    assert pipe.warehouse.rows_loaded == n - quarantined  # good ones loaded
    # exactly the poisoned records (and only them) are in the DLQ
    for rt in cluster.runtimes.values():
        held = rt.worker.dead_letter.peek()
        if len(held):
            assert (held.business_key == bad_key).all()
            assert all(r["reason"] == "transform-poison"
                       for r in rt.worker.dead_letter.reasons)
    # offsets committed: no lag left behind, nothing replays forever
    assert cluster._operational_lag() == 0
    # no crash-loop: zero evictions/restarts, breaker closed
    snap = cluster.control.snapshot()
    assert snap["restarts"] == 0 and snap["evictions"] == 0
    assert not snap["breaker_open"]
    assert snap["dead_lettered"] == quarantined
    h = cluster.health()
    assert h["control"]["dead_lettered"] == quarantined
    assert h["counters"]["worker.dead_lettered"] == quarantined
    per_worker = sum(w["dead_lettered"] for w in h["workers"].values())
    assert per_worker == quarantined


def test_dead_letter_export_restore_roundtrip():
    from repro.core.buffer import DeadLetterBuffer
    dl = DeadLetterBuffer()
    dl.push(make_batch(0, 0, np.arange(3), np.full(3, 7), np.arange(3),
                       np.zeros((3, 8), np.float32)), reason="transform-poison")
    state = dl.export_state()
    dl2 = DeadLetterBuffer.restore(state)
    assert len(dl2) == 3 and dl2.total_quarantined == 3
    assert dl2.reasons == [{"reason": "transform-poison", "records": 3}]
    assert DeadLetterBuffer.restore(None).total_quarantined == 0  # pre-DLQ
    drained = dl2.drain()
    assert len(drained) == 3 and len(dl2) == 0


# ======================================================= breaker / backoff drill
def test_restart_failures_back_off_then_open_breaker():
    """Every restart attempt fails at the pre-hydration seam: the
    supervisor retries with exponentially growing backoff, opens the
    circuit breaker after the configured consecutive failures, and the
    control thread itself survives (degraded mode, not a dead loop)."""
    fault = FaultInjector(
        {HEARTBEAT_MISS: 2, RESTART_PRE_HYDRATE: set(range(1, 10))},
        actions={HEARTBEAT_MISS: "hang"}, sticky=False)
    cfg, _, pipe = build(3, 2000, fault=fault)
    pipe.extract()
    ctl = ControlConfig(**{**FAST, "max_consecutive_restarts": 3})
    cluster = ConcurrentCluster(pipe, poll_cdc=False, control=ctl)
    cluster.start()
    assert fault.hung.wait(10.0)
    assert wait_for(lambda: cluster.control.breaker_open, timeout=20.0), \
        "breaker never opened"
    ctrl = cluster.control
    assert ctrl.restart_attempts == 3
    assert ctrl.consecutive_restart_failures == 3
    assert ctrl.restarts == 0 and ctrl.restart_failures == 3
    assert not ctrl.crashed                       # loop survived the drill
    backoffs = [d["backoff_s"] for d in ctrl.decisions
                if d["action"] == "restart_backoff"]
    assert len(backoffs) == 3
    assert backoffs[0] < backoffs[1] < backoffs[2]  # exponential + jitter
    assert any(d["action"] == "breaker_open" for d in ctrl.decisions)
    h = cluster.health()
    assert h["control"]["breaker_open"] and h["control"]["degraded"]
    # with the breaker open the victim is plainly evicted (no restart) so
    # survivors keep the stream alive in degraded mode
    assert wait_for(lambda: ctrl.evictions >= 1, timeout=20.0)
    assert ctrl.last_eviction["restarted"] is False
    ctrl.reset_breaker()                          # operator action
    assert not ctrl.breaker_open
    fault.release_hangs()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", QuiesceTimeoutWarning)
        cluster.stop_all()


# ================================================================ policy drills
def test_policy_scales_up_on_sustained_backlog():
    """The autonomous loop: a pre-published backlog far above the
    per-worker threshold makes the controller scale up — no human call —
    and the stream still completes exactly-once."""
    n = 4000
    cfg, _, pipe = build(1, n)
    pipe.extract()                                # instant deep backlog
    ctl = ControlConfig(**{**FAST, "scaling": True,
                           "policy_interval_s": 0.05,
                           "hysteresis_samples": 2, "cooldown_s": 0.3,
                           "backlog_high_per_worker": 200,
                           "backlog_low_per_worker": 0,
                           "scale_down": False, "repartition": False,
                           "max_workers": 3})
    cluster = ConcurrentCluster(pipe, poll_cdc=False,
                                max_records_per_partition=20, control=ctl)
    cluster.start()
    assert wait_for(lambda: cluster.control.scale_ups >= 1, timeout=20.0), \
        "controller never scaled up"
    done = cluster.run_until_idle(timeout=90)
    cluster.stop_all()
    assert done == n and pipe.warehouse.rows_loaded == n
    assert len(cluster.alive_workers()) >= 2      # it really grew
    snap = cluster.control.snapshot()
    assert snap["scale_ups"] >= 1
    assert snap["last_decision"] is not None
    acted = [d for d in cluster.control.decisions
             if d["action"] == "scale_up"]
    assert acted and acted[0]["per_worker"] > 200


def test_policy_quiet_stream_makes_no_decisions():
    """Hysteresis + cooldown: a healthy in-band stream triggers nothing —
    the controller observes and stays silent."""
    n = 1500
    cfg, _, pipe = build(2, n)
    pipe.extract()
    ctl = ControlConfig(**{**FAST, "scaling": True, "scale_down": False,
                           "repartition": False, "policy_interval_s": 0.05})
    cluster = ConcurrentCluster(pipe, poll_cdc=False, control=ctl)
    cluster.start()
    done = cluster.run_until_idle(timeout=60)
    time.sleep(0.3)                               # a few idle policy samples
    cluster.stop_all()
    assert done == n
    snap = cluster.control.snapshot()
    assert snap["scale_ups"] == 0 and snap["scale_downs"] == 0
    assert snap["repartitions"] == 0 and snap["evictions"] == 0
    assert not snap["degraded"]


def test_health_control_stub_without_control_plane():
    cfg, _, pipe = build(1, 200)
    pipe.extract()
    cluster = ConcurrentCluster(pipe, poll_cdc=False)
    h = cluster.health()
    assert h["control"]["enabled"] is False
    assert h["control"]["suspects"] == []
    assert h["control"]["dead_lettered"] == 0
    for w in h["workers"].values():
        assert w["credits_available"] == cfg.credit_capacity
        assert "heartbeat_max_age_s" in w and "dead_lettered" in w


# ============================================================== chaos schedules
def _chaos_schedule(seed):
    """One seeded random fault: seam, action and ordinal drawn from the
    ranges the drills above cover individually."""
    rng = np.random.default_rng(seed)
    point = [INGEST_FETCH, TRANSFORM_DONE, HEARTBEAT_MISS][
        int(rng.integers(0, 3))]
    action = ["raise", "hang"][int(rng.integers(0, 2))]
    ordinal = int(rng.integers(1, 30))
    return point, action, ordinal


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_schedule_exactly_once(seed):
    """Seeded randomized kill/hang schedules under sustained load: for
    EVERY schedule the self-healing cluster must finish the stream
    byte-identical to the uninterrupted oracle with whole credit ledgers
    — whether or not the fault's ordinal was even reached."""
    n = 2500
    point, action, ordinal = _chaos_schedule(seed)
    fault = FaultInjector({point: ordinal}, actions={point: action},
                          sticky=(action == "raise"))
    cfg, _, pipe = build(3, n, fault=fault)
    pipe.extract()
    cluster = ConcurrentCluster(pipe, poll_cdc=False,
                                max_records_per_partition=25,
                                control=ControlConfig(**FAST))
    cluster.start()
    done = cluster.run_until_idle(timeout=90)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", QuiesceTimeoutWarning)
        cluster.stop_all()
    fault.release_hangs()

    fired = fault.tripped.is_set() or fault.hung.is_set()
    assert done == n, (point, action, ordinal, fired)
    assert pipe.warehouse.rows_loaded == n
    assert pipe.warehouse.canonical_fact_table().tobytes() == oracle_facts(n)
    if fault.tripped.is_set() and point in (INGEST_FETCH, TRANSFORM_DONE):
        # the killed thread died HOLDING an uncommitted batch — those
        # records can only have been re-served past the fence, so a
        # completed stream proves the supervisor evicted + restarted
        assert cluster.control.evictions >= 1
    # no credit leaked anywhere that still matters (live workers only:
    # a wedged zombie keeps its grant forever, but it is dead + fenced)
    for rt in cluster.runtimes.values():
        if not rt.dead:
            assert rt.credits.available == rt.credits.capacity
    assert not cluster.control.crashed
