"""Observability plane tests: lock-sharded registry vs a serial oracle,
tracer golden Chrome-trace output, NULL_TRACER zero-allocation pin,
bounded-reservoir determinism, and ClusterHealth consistency while
rebalance / repartition / checkpoint run concurrently."""
import dataclasses
import json
import threading
import time
import tracemalloc

import numpy as np
import pytest

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, MessageQueue, SourceDatabase, \
    TopicConfig, make_batch
from repro.core.backend import NumpyBackend
from repro.core.metrics import LatencyRecorder, percentiles_ms
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.observability import (NULL_TRACER, MetricsRegistry, StageTracer,
                                 global_registry)
from repro.observability.tracer import _NULL_SPAN
from repro.runtime.cluster import ConcurrentCluster


# ------------------------------------------------------------- registry
def test_registry_hammer_matches_serial_oracle():
    """8 writer threads, each on its own shard, hammering shared-name
    counters + histograms: the merged read equals a serial recount."""
    reg = MetricsRegistry()
    n_threads, n_iter = 8, 20_000

    def writer(i):
        shard = reg.shard(f"t{i}")
        c_shared = shard.counter("hits")       # same name on every shard
        c_own = shard.counter(f"own.{i}")
        h = shard.histogram("lat")
        for k in range(n_iter):
            c_shared.inc()
            c_own.inc(2)
            if k % 1000 == 0:
                h.add(np.full(10, float(i)))

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    counters = reg.counters()
    assert counters["hits"] == n_threads * n_iter     # summed across shards
    for i in range(n_threads):
        assert counters[f"own.{i}"] == 2 * n_iter
    # histogram union: every thread contributed (n_iter/1000)*10 samples
    p = reg.histogram_percentiles("lat")
    assert p["n"] == n_threads * (n_iter // 1000) * 10
    snap = reg.snapshot()
    assert snap["counters"] == counters
    assert "lat" in snap["histograms"]


def test_shard_handles_are_memoized_and_gauges_pull():
    reg = MetricsRegistry()
    s = reg.shard("w0")
    assert s is reg.shard("w0")
    assert s.counter("c") is s.counter("c")
    depth = [3]
    s.gauge_fn("queue_depth", lambda: depth[0])
    assert reg.gauges()["w0"]["queue_depth"] == 3.0
    depth[0] = 7
    assert reg.gauges()["w0"]["queue_depth"] == 7.0   # read-time evaluation
    s.gauge_fn("broken", lambda: 1 / 0)
    assert np.isnan(reg.gauges()["w0"]["broken"])     # never raises


def test_registered_histogram_is_adopted_not_copied():
    reg = MetricsRegistry()
    rec = LatencyRecorder()
    reg.shard("w0").register_histogram("freshness", rec)
    rec.add(np.array([0.1, 0.2, 0.3]))
    assert reg.histogram_percentiles("freshness")["n"] == 3
    rec.add(np.array([0.4]))
    assert reg.histogram_percentiles("freshness")["n"] == 4


def test_backend_counters_per_instance_shards_sum_globally():
    """Dispatch counters live on per-instance global-registry shards:
    per-instance reset stays isolated, merged reads sum the process."""
    a, b = NumpyBackend(), NumpyBackend()
    base = global_registry().counters().get("backend.numpy.op_dispatches", 0)
    a.op_dispatches += 3
    b.op_dispatches += 2
    assert a.op_dispatches == 3 and b.op_dispatches == 2
    merged = global_registry().counters()["backend.numpy.op_dispatches"]
    assert merged == base + 5
    a.reset_stats()
    assert a.op_dispatches == 0 and b.op_dispatches == 2


def test_broker_counters_and_commit_lags():
    q = MessageQueue()
    q.create_topic(TopicConfig("t", 0, 4, "business_key"))
    ids = np.arange(100, dtype=np.int64)
    q.publish("t", make_batch(0, 0, ids, ids % 7, ids + 100,
                              np.zeros((100, 8), np.float32)))
    counters = q.metrics.counters()
    assert counters["broker.t.published"] == 100
    assert counters["broker.t.key_loads"] == 100
    assert q.metrics.gauges()["broker.t"]["broker.t.high_watermark"] == 100
    lags = q.commit_lags("g")
    assert sum(lags["t"].values()) == 100        # nothing committed yet
    b = q.consume("g", "t", 0)
    q.commit("g", "t", 0, len(b))
    lags = q.commit_lags("g")
    assert lags["t"][0] == 0
    assert sum(lags["t"].values()) == 100 - len(b)


# --------------------------------------------------------------- tracer
def _tick_clock(step_s=0.5e-3):
    t = [0.0]

    def clock():
        v = t[0]
        t[0] += step_s
        return v
    return clock


def test_tracer_golden_chrome_trace_with_nesting():
    """Deterministic clock -> byte-stable Chrome-trace JSON: nested spans
    close inner-first, lanes become labeled tids, args ride along."""
    tracer = StageTracer(clock=_tick_clock())        # _t0 = 0.0
    with tracer.span("query.batch", lane="serving") as outer:   # t=0.5ms
        with tracer.span("serving.fold", lane="serving"):       # t=1.0ms
            pass                                                # t=1.5ms
        outer.put("queries", 2)
    # outer exit t=2.0ms
    tracer.instant("epoch.swap", lane="serving")                # t=2.5ms

    golden = {
        "traceEvents": [
            {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "dod-etl"}},
            {"name": "thread_name", "ph": "M", "pid": 1, "tid": 1,
             "args": {"name": "serving"}},
            {"name": "serving.fold", "cat": "serving", "ph": "X",
             "ts": 1000.0, "pid": 1, "tid": 1, "dur": 500.0},
            {"name": "query.batch", "cat": "query", "ph": "X",
             "ts": 500.0, "pid": 1, "tid": 1, "dur": 1500.0,
             "args": {"queries": 2}},
            {"name": "epoch.swap", "cat": "epoch", "ph": "i",
             "ts": 2500.0, "pid": 1, "tid": 1, "s": "t"},
        ],
        "displayTimeUnit": "ms",
        "otherData": {"dropped_events": 0},
    }
    assert tracer.to_chrome() == golden
    # nesting containment: inner [ts, ts+dur] inside outer's interval
    ev = {e["name"]: e for e in golden["traceEvents"] if e["ph"] == "X"}
    inner, outer = ev["serving.fold"], ev["query.batch"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    json.dumps(tracer.to_chrome())                   # JSON-serializable


def test_tracer_drop_and_event_cap():
    tracer = StageTracer(max_events=2)
    with tracer.span("a") as sp:
        sp.drop()
    assert tracer.events() == []                     # dropped = not recorded
    for _ in range(4):
        with tracer.span("b"):
            pass
    assert len(tracer.events()) == 2                 # capped
    assert tracer.dropped_events == 2
    tracer.clear()
    assert tracer.events() == [] and tracer.dropped_events == 0


def test_tracer_export_file(tmp_path):
    tracer = StageTracer()
    with tracer.span("ingest.fetch", lane="w0.ingest") as sp:
        sp.put("records", 17)
    path = tracer.export_chrome_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    names = [e["name"] for e in doc["traceEvents"]]
    assert "ingest.fetch" in names and "thread_name" in names


def test_null_tracer_zero_allocation():
    """The disabled seam allocates NOTHING per span: every call site gets
    the one shared _NullSpan. Pinned with tracemalloc."""
    tr = NULL_TRACER
    assert tr.span("warmup") is _NULL_SPAN           # shared singleton
    for _ in range(100):                             # warm any caches
        with tr.span("x") as sp:
            sp.put("k", 1)
            sp.drop()
        tr.instant("y")
    import repro.observability.tracer as tracer_mod
    tracemalloc.start()
    snap1 = tracemalloc.take_snapshot()
    for _ in range(10_000):
        with tr.span("x") as sp:
            sp.put("k", 1)
            sp.drop()
        tr.instant("y")
    snap2 = tracemalloc.take_snapshot()
    tracemalloc.stop()
    grown = sum(s.size_diff for s in snap2.compare_to(snap1, "filename")
                if s.traceback[0].filename == tracer_mod.__file__
                and s.size_diff > 0)
    # zero PER-SPAN allocation: 10k spans may leave at most a constant
    # few transient blocks (bound methods caught mid-flight by the
    # snapshot), never anything proportional to the span count. One
    # real span object per iteration would show >= 560 KB here.
    assert grown < 256
    assert tr.enabled is False


# ---------------------------------------------------- bounded reservoir
def test_reservoir_non_overflow_path_is_exact():
    """At or under capacity the recorder is byte-identical to the legacy
    keep-everything behavior."""
    rec = LatencyRecorder()
    rng = np.random.default_rng(0)
    a, b = rng.normal(size=300) ** 2, rng.normal(size=500) ** 2
    rec.add(a)
    rec.add(b)
    full = np.concatenate([a, b])
    assert rec.merged(drain=False).tobytes() == full.tobytes()
    assert rec.percentiles() == percentiles_ms(full)
    assert rec.total_seen == 800 and rec.stored == 800


def test_reservoir_overflow_keeps_deterministic_stride_subset():
    """Past capacity: kept samples are EXACTLY the arrivals whose global
    index is divisible by the (power-of-two) stride — independent of how
    arrivals were chunked."""
    samples = np.arange(1000, dtype=np.float64)
    chunkings = [[1000], [37, 463, 500], [1] * 1000, [999, 1]]
    merged_views = []
    for chunks in chunkings:
        rec = LatencyRecorder(capacity=64)
        off = 0
        for n in chunks:
            rec.add(samples[off:off + n])
            off += n
        stride = rec._stride
        assert stride & (stride - 1) == 0 and stride > 1
        expect = samples[::stride]
        got = rec.merged(drain=False)
        assert got.tobytes() == expect.tobytes()
        assert rec.stored <= rec.capacity
        assert rec.total_seen == 1000
        merged_views.append(got.tobytes())
    assert len(set(merged_views)) == 1               # chunking-invariant


def test_reservoir_drain_resets_stride():
    rec = LatencyRecorder(capacity=16)
    rec.add(np.arange(100, dtype=np.float64))
    assert rec._stride > 1
    drained = rec.merged(drain=True)
    assert len(drained) <= 16
    assert rec.stored == 0 and rec._stride == 1
    rec.add(np.arange(5, dtype=np.float64))
    assert rec.merged().tobytes() == \
        np.arange(5, dtype=np.float64).tobytes()


# --------------------------------------------- live cluster integration
def _build(n_workers, n_records=3000, n_partitions=8, late_frac=0.05,
           buffer_capacity=8192):
    cfg = steelworks_config(n_partitions=n_partitions, backend="numpy")
    cfg = dataclasses.replace(cfg, buffer_capacity=buffer_capacity)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n_records, n_equipment=n_partitions,
        late_master_frac=late_frac))
    return cfg, src, sampler


def test_cluster_trace_covers_all_six_stage_seams(tmp_path):
    """Sustained load with serving + checkpointing: the exported trace
    holds spans for every stage seam, in worker-thread lanes, and loads
    as valid Chrome-trace JSON."""
    from repro.durability.journal import DurabilityJournal
    from repro.durability.recovery import RecoveryCoordinator
    from repro.serving.batch import BatchedReportServer, ReportQuery
    from repro.serving.engine import MaterializedViewEngine
    from repro.serving.server import ReportServer
    from repro.serving.views import steelworks_views

    cfg, src, sampler = _build(2)
    tracer = StageTracer()
    pipe = DODETLPipeline(cfg, src, n_workers=2, tracer=tracer)
    engine = MaterializedViewEngine(steelworks_views(20), backend="numpy")
    front = BatchedReportServer(ReportServer(engine))
    rec = RecoveryCoordinator(DurabilityJournal(str(tmp_path / "j")))
    cluster = ConcurrentCluster(pipe, serving=front, recovery=rec)
    sampler.generate(src)
    cluster.start()
    done = cluster.run_until_idle(timeout=60)
    cluster.checkpoint()
    front.submit(ReportQuery(kind="oee")).result(5.0)
    cluster.stop_all()
    assert done == 3000

    names = set(tracer.span_names())
    assert {"ingest.fetch", "transform.dispatch", "load.commit",
            "serving.fold", "query.batch", "checkpoint.step"} <= names
    doc = tracer.to_chrome()
    lanes = {e["args"]["name"] for e in doc["traceEvents"]
             if e["name"] == "thread_name"}
    assert any(l.endswith(".ingest") for l in lanes)
    assert any(l.endswith(".transform") for l in lanes)
    assert any(l.endswith(".load") for l in lanes)
    for e in doc["traceEvents"]:
        if e["ph"] == "X":
            assert e["dur"] >= 0.0 and e["ts"] >= 0.0
    json.loads(json.dumps(doc))                      # round-trips


def test_health_consistent_during_rebalance_and_checkpoint(tmp_path):
    """Poll health() as fast as possible while a feeder streams, workers
    fail over, the cluster repartitions and checkpoints run: every
    snapshot is internally consistent (partition ownership forms a
    partition of the partition set, counters monotone, lags
    non-negative) and no poll ever raises."""
    from repro.durability.journal import DurabilityJournal
    from repro.durability.recovery import RecoveryCoordinator

    n = 6000
    cfg, src, sampler = _build(4, n_records=n, n_partitions=8)
    pipe = DODETLPipeline(cfg, src, n_workers=4)
    rec = RecoveryCoordinator(DurabilityJournal(str(tmp_path / "j")))
    cluster = ConcurrentCluster(pipe, recovery=rec)

    snaps, errors = [], []
    stop = threading.Event()

    def poller():
        while not stop.is_set():
            try:
                snaps.append(cluster.health())
            except Exception as exc:      # pragma: no cover - must not fire
                errors.append(exc)
                return

    feeder = threading.Thread(target=lambda: sampler.generate(src))
    poll_t = threading.Thread(target=poller)
    cluster.start()
    feeder.start()
    poll_t.start()
    time.sleep(0.1)                       # mid-stream, under load
    cluster.checkpoint()
    cluster.fail_workers(["w1"])          # rebalance while polling
    cluster.repartition()
    cluster.checkpoint()
    cluster.scale_to(4)
    feeder.join()
    done = cluster.run_until_idle(timeout=120)
    stop.set()
    poll_t.join(5.0)
    final = cluster.health()
    cluster.stop_all()

    assert not errors
    assert done == n
    assert len(snaps) > 5
    all_parts = set(range(8))
    prev_done = -1
    for h in snaps + [final]:
        owned = [p for w in h["workers"].values() for p in w["partitions"]]
        assert len(owned) == len(set(owned))         # disjoint ownership
        assert set(owned) <= all_parts
        for lags in h["commit_lag"].values():
            assert all(v >= 0 for v in lags.values())
        assert h["backlog"]["operational_lag"] >= 0
        total_done = sum(w["records_done"] for w in h["workers"].values())
        assert total_done >= 0
        prev_done = max(prev_done, total_done)
    # the final post-idle snapshot reflects the drained stream
    assert set(p for w in final["workers"].values()
               for p in w["partitions"]) == all_parts
    assert final["backlog"]["operational_lag"] == 0
    assert final["checkpoint"]["steps"] == 2
    assert final["checkpoint"]["age_s"] is not None
    assert final["counters"]["pipeline.checkpoints"] == 2
    assert final["counters"]["pipeline.repartitions"] == 1
    assert final["freshness"]["n"] > 0
    sum_hits = final["counters"]["worker.cache_hits"]
    assert sum_hits >= n                 # every record joined at least once


def test_pipeline_health_sequential():
    cfg, src, sampler = _build(2, n_records=1500)
    pipe = DODETLPipeline(cfg, src, n_workers=2)
    sampler.generate(src)
    pipe.extract()
    pipe.bootstrap_caches()
    pipe.run_to_completion()
    h = pipe.health()
    assert sum(w["records_done"] for w in h["workers"].values()) == 1500
    assert h["backlog"]["operational_lag"] == 0
    assert h["counters"]["worker.cache_hits"] == 1500
    owned = [p for w in h["workers"].values() for p in w["partitions"]]
    assert sorted(owned) == list(range(8))


def test_default_tracer_is_null_and_emits_nothing():
    cfg, src, sampler = _build(1, n_records=500)
    pipe = DODETLPipeline(cfg, src, n_workers=1)
    assert pipe.tracer is NULL_TRACER
    sampler.generate(src)
    pipe.extract()
    pipe.bootstrap_caches()
    pipe.run_to_completion()
    assert pipe.warehouse.rows_loaded == 500         # seam is transparent
