"""Shared hypothesis import shim: when hypothesis is absent, only the
property tests skip (via a skip marker) — plain unit tests in the same
module still run. Import from test modules as
``from _hypothesis_compat import given, settings, st``."""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f

    class _NullStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NullStrategies()
