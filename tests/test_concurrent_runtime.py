"""Concurrent runtime tests (paper §3.1 "distributed, parallel" + §4.1.3).

The numpy backend keeps these fast and jit-free; the runtime under test is
identical for every backend (the backend only changes the numeric core).
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.core.message_queue import MessageQueue, TopicConfig
from repro.core.records import make_batch
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.runtime.cluster import ConcurrentCluster


def build(n_workers, n_records=3000, n_partitions=8, late_frac=0.05,
          buffer_capacity=1024):
    cfg = steelworks_config(n_partitions=n_partitions, backend="numpy")
    cfg = dataclasses.replace(cfg, buffer_capacity=buffer_capacity)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n_records, n_equipment=n_partitions,
        late_master_frac=late_frac))
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers)
    return cfg, src, sampler, pipe


def sequential_oracle(n_records, n_partitions=8, late_frac=0.05):
    _, src, sampler, pipe = build(1, n_records, n_partitions, late_frac)
    sampler.generate(src)
    pipe.extract()
    pipe.bootstrap_caches()
    pipe.run_to_completion()
    return pipe


def test_concurrent_byte_identical_to_sequential():
    """N concurrent workers produce a warehouse byte-identical to the
    single-worker sequential pipeline (pre-extracted stream, so both runs
    join every record against the same master versions)."""
    n = 3000
    _, src, sampler, pipe = build(4, n)
    sampler.generate(src)
    pipe.extract()                      # everything queued before start
    cluster = ConcurrentCluster(pipe, poll_cdc=False)
    cluster.start()
    done = cluster.run_until_idle(timeout=60)
    cluster.stop_all()
    assert done == n
    assert pipe.warehouse.rows_loaded == n

    oracle = sequential_oracle(n)
    a = pipe.warehouse.canonical_fact_table()
    b = oracle.warehouse.canonical_fact_table()
    assert a.shape == b.shape
    assert a.tobytes() == b.tobytes()   # literally byte-identical


def test_failover_under_load_loses_no_records():
    """§4.1.3 drill, for real: kill 2 of 5 workers while the feeder is
    still writing and the cluster is mid-stream; then scale back up. Zero
    records lost, zero duplicated, zero buffer drops."""
    n = 6000
    _, src, sampler, pipe = build(5, n, n_partitions=10,
                                  buffer_capacity=8192)
    feeder = threading.Thread(target=lambda: sampler.generate(src))
    cluster = ConcurrentCluster(pipe)
    cluster.start()
    feeder.start()
    time.sleep(0.15)                     # mid-run, under load
    redump = cluster.fail_workers(["w1", "w3"])
    assert redump >= 0.0
    assert sorted(cluster.alive_workers()) == ["w0", "w2", "w4"]
    time.sleep(0.1)
    cluster.scale_to(4)                  # elastic recovery, still streaming
    feeder.join()
    done = cluster.run_until_idle(timeout=90)
    cluster.stop_all()

    assert done == n
    assert pipe.warehouse.rows_loaded == n         # no loss, no duplicates
    drops = sum(rt.worker.buffer.dropped for rt in cluster.runtimes.values())
    assert drops == 0

    # same record set as the oracle: identity columns (equipment, window)
    # must match exactly; KPI columns may differ where a record was joined
    # against an earlier (still-correct) master version mid-stream
    oracle = sequential_oracle(n, n_partitions=10)
    a = pipe.warehouse.canonical_fact_table()
    b = oracle.warehouse.canonical_fact_table()
    assert a.shape == b.shape
    order = lambda t: t[np.lexsort((t[:, 2], t[:, 1], t[:, 0]))]
    np.testing.assert_array_equal(order(a)[:, :3], order(b)[:, :3])
    assert (a[:, -1] > 0.5).all()                  # every fact valid


def test_concurrent_scale_up_mid_stream():
    """Start with 1 worker, scale to 3 mid-run; the stream completes and
    newly added workers actually take over partitions."""
    n = 4000
    _, src, sampler, pipe = build(1, n, buffer_capacity=8192)
    feeder = threading.Thread(target=lambda: sampler.generate(src))
    cluster = ConcurrentCluster(pipe)
    cluster.start()
    feeder.start()
    time.sleep(0.1)
    cluster.scale_to(3)
    feeder.join()
    done = cluster.run_until_idle(timeout=60)
    cluster.stop_all()
    assert done == n
    assert len(cluster.alive_workers()) == 3
    owners = set(cluster.assignment.assignment.values())
    assert len(owners) == 3              # every worker owns partitions


def test_freshness_percentiles_recorded():
    """Every loaded record contributes one end-to-end freshness sample;
    percentiles are ordered and positive."""
    n = 2000
    _, src, sampler, pipe = build(2, n)
    sampler.generate(src)
    cluster = ConcurrentCluster(pipe)
    cluster.start()
    done = cluster.run_until_idle(timeout=60)
    cluster.stop_all()
    assert done == n
    lat = cluster.freshness()
    assert lat["n"] == n
    assert 0.0 < lat["p50_ms"] <= lat["p95_ms"] <= lat["p99_ms"]


def test_fetch_many_positions_vs_commits():
    """The broker's read-position / committed-offset split: fetch advances
    the position (no re-reads), commit is durable progress, and an
    abandoned read-ahead rewinds to the committed offset."""
    q = MessageQueue()
    q.create_topic(TopicConfig("t", 0, 2, "business_key"))
    n = 100
    q.publish("t", make_batch(0, 0, np.arange(n), np.arange(n),
                              np.arange(n), np.zeros((n, 8), np.float32)))
    batch1, counts1 = q.fetch_many("g", "t", [0, 1])
    assert sum(counts1.values()) == n
    # position advanced: nothing new to read, though nothing is committed
    batch2, counts2 = q.fetch_many("g", "t", [0, 1])
    assert not counts2
    assert all(q.committed("g", "t", p) == 0 for p in (0, 1))
    # a crash abandons the read-ahead: rewind, resume from committed
    for p in (0, 1):
        q.rewind("g", "t", p)
    batch3, counts3 = q.fetch_many("g", "t", [0, 1])
    assert sum(counts3.values()) == n
    np.testing.assert_array_equal(np.sort(batch3.row_key),
                                  np.sort(batch1.row_key))
    # commit makes it durable: fetch after rewind returns nothing
    for p, c in counts3.items():
        q.commit("g", "t", p, c)
        q.rewind("g", "t", p)
    _, counts4 = q.fetch_many("g", "t", [0, 1])
    assert not counts4


def test_concurrent_commits_are_exact():
    """Offset commits from many threads never lose an increment."""
    q = MessageQueue()
    q.create_topic(TopicConfig("t", 0, 1, "business_key"))
    per_thread, n_threads = 500, 8

    def worker():
        for _ in range(per_thread):
            q.commit("g", "t", 0, 1)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert q.committed("g", "t", 0) == per_thread * n_threads


def test_cdc_event_times_monotonic():
    """Event-time stamps are assigned at CDC append and are non-decreasing
    in LSN order — the foundation of the freshness metric."""
    src = SourceDatabase()
    for i in range(5):
        src.apply(make_batch(0, 0, np.arange(3) + 3 * i, np.zeros(3),
                             np.zeros(3), np.zeros((3, 8), np.float32)))
    lsns = np.arange(src.log.next_lsn)
    stamps = src.log.event_times(lsns)
    assert len(stamps) == 15
    assert (np.diff(stamps) >= 0).all()
    assert (stamps <= src.log.clock()).all()
