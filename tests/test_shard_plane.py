"""Sharded multi-device serving-plane drills (ROADMAP item 1).

The contract under test: sharding is INVISIBLE to the numbers. On any
shard count — host-simulated or a real ≥4-device mesh — the warehouse
stays byte-identical and every materialized-view aggregate bitwise-
identical to the single-device path, including across a live mid-run
``repartition()`` (surgical shard-ownership remap) and across a
checkpoint/crash/recovery drill (per-shard fold state captured and
restored). The mechanism making that possible is segment-column
ownership: every shard folds the full delta with foreign segments
masked to the -1 identity, so a segment's combine order never changes
(see ``repro.runtime.shard_plane``).

The real-mesh drill runs in a SUBPROCESS: jax backends bind device
count at first initialization, so ``--xla_force_host_platform_device_
count`` must be set before jax imports — the pytest process is already
initialized (same pattern as the kill -9 drill in recovery_bench).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.core.backend import available_backends
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.durability import (DurabilityJournal, FaultInjector,
                              InjectedCrash, RecoveryCoordinator,
                              recover_pipeline)
from repro.durability.faults import COMMIT_POST, REPARTITION_MID
from repro.runtime.cluster import ConcurrentCluster
from repro.runtime.shard_plane import ShardedViewEngine, owner_gather
from repro.serving.engine import MaterializedViewEngine
from repro.serving.views import steelworks_views

BACKENDS = [b for b in ("numpy", "jax") if b in available_backends()]
SHARD_COUNTS = (1, 2, 4)


# --------------------------------------------------------------------- harness
def _workload(backend="numpy", n=400, n_partitions=4, zipf_s=0.0,
              strategy="static", seed=0):
    cfg = steelworks_config(n_partitions=n_partitions, backend=backend,
                            partition_strategy=strategy)
    cfg = dataclasses.replace(cfg, buffer_capacity=4096)
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n, n_equipment=n_partitions,
        late_master_frac=0.15, zipf_s=zipf_s, seed=seed)).generate(src)
    return cfg, src


def _sharded_engine(cfg, n_shards, backend="numpy"):
    return ShardedViewEngine(steelworks_views(cfg.n_business_keys),
                             n_shards=n_shards, backend=backend)


def _extraction_lag(pipe):
    log = pipe.source.log
    return sum(max(0, log.next_lsn - l.offset)
               for l in pipe.tracker.listeners)


def _drill_loop(pipe, engine, coord=None, ckpt_every=2, extract_per=60,
                repartition_at=None, cap=40, max_steps=300):
    """test_recovery's deterministic state-driven loop: bounded extract,
    state-derived repartition trigger, micro-batch step, fold, maybe
    checkpoint."""
    steps = stalls = 0
    while steps < max_steps:
        steps += 1
        pipe.extract(extract_per)
        if repartition_at is not None \
                and pipe.current_routing().epoch == 0 \
                and pipe.warehouse.commit_seq >= repartition_at:
            pipe.repartition()
        n = pipe.step(cap)
        engine.fold_pending()
        if coord is not None and steps % ckpt_every == 0:
            coord.checkpoint(pipe, engine=engine)
        if _extraction_lag(pipe) > 0:
            stalls = 0
            continue
        if n == 0 and sum(len(w.buffer) for w in pipe.workers) == 0:
            break
        stalls = stalls + 1 if n == 0 else 0
        if stalls >= 3:
            break
    return steps


def _final_state(pipe, engine):
    snap = engine.snapshot()
    return {
        "facts": pipe.warehouse.canonical_fact_table().tobytes(),
        "rows": pipe.warehouse.rows_loaded,
        "seq": pipe.warehouse.commit_seq,
        "views": {n: st.table.tobytes() for n, st in snap.states.items()},
        "rows_folded": snap.rows_folded,
        "deltas_folded": snap.deltas_folded,
    }


def _assert_identical(got, want):
    assert got["rows"] == want["rows"]
    assert got["seq"] == want["seq"]
    assert got["facts"] == want["facts"]
    assert got["rows_folded"] == want["rows_folded"]
    assert got["deltas_folded"] == want["deltas_folded"]
    for name, table in want["views"].items():
        assert got["views"][name] == table, name


def _run_pair(n_shards, backend="numpy", repartition_at=None, **wl):
    """One workload through the SHARDED engine and the plain single-
    device engine, identically driven. Returns (sharded final state,
    plain final state, sharded pipe, sharded engine)."""
    cfg, src = _workload(backend=backend, **wl)
    pipe = DODETLPipeline(cfg, src, n_workers=2)
    eng = _sharded_engine(cfg, n_shards, backend)
    eng.reown(pipe.current_routing())
    pipe.warehouse.attach_serving(eng)
    pipe.warehouse.attach_shards(eng.ownership)
    _drill_loop(pipe, eng, repartition_at=repartition_at)

    cfg2, src2 = _workload(backend=backend, **wl)
    pipe2 = DODETLPipeline(cfg2, src2, n_workers=2)
    ref = MaterializedViewEngine(steelworks_views(cfg2.n_business_keys),
                                 backend=backend)
    pipe2.warehouse.attach_serving(ref)
    _drill_loop(pipe2, ref, repartition_at=repartition_at)
    return _final_state(pipe, eng), _final_state(pipe2, ref), pipe, eng


def _assert_warehouse_shards_partition(pipe, eng):
    """The per-shard sub-logs are a partition of the chunk log: their
    union, canonically sorted, is byte-identical to the warehouse's own
    canonical fact table, and each shard holds ONLY its owned keys."""
    wh = pipe.warehouse
    parts = [wh.shard_fact_table(k) for k in range(eng.n_shards)]
    union = np.concatenate([p for p in parts if len(p)]) \
        if any(len(p) for p in parts) \
        else np.zeros((0, 10), np.float32)
    canon = union[np.lexsort(union.T[::-1])] if len(union) else union
    assert canon.tobytes() == wh.canonical_fact_table().tobytes()
    for k, p in enumerate(parts):
        if len(p):
            owners = eng.ownership.shard_of_keys(p[:, 0].astype(np.int64))
            assert (owners == k).all()


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_sharded_parity_bitwise(n_shards, backend):
    """1/2/4 shards: byte-identical warehouse facts, bitwise-identical
    view fold state vs the single-device engine, per-shard warehouse
    sub-logs partition the chunk log exactly."""
    got, want, pipe, eng = _run_pair(n_shards, backend=backend)
    _assert_identical(got, want)
    _assert_warehouse_shards_partition(pipe, eng)


@pytest.mark.parametrize("n_shards", (2, 4))
def test_sharded_parity_across_repartition(n_shards):
    """Mid-run repartition() under a zipf-skewed workload with the
    skew-aware strategy: the routing-epoch switch remaps shard ownership
    surgically and the final state stays bitwise-identical to the
    single-device run (which repartitions identically)."""
    wl = dict(n=500, zipf_s=1.2, strategy="skew")
    got, want, pipe, eng = _run_pair(n_shards, repartition_at=3, **wl)
    assert pipe.current_routing().epoch >= 1      # it really switched
    _assert_identical(got, want)
    _assert_warehouse_shards_partition(pipe, eng)
    rep = eng.mesh_report()
    assert rep["reowns"] >= 1                     # ownership remapped
    assert rep["routing_epoch"] == pipe.current_routing().epoch


def test_tree_reduce_merge_equals_owner_gather():
    """The explicit pairwise-halving tree reduce over shard-local tables
    is bitwise-equal to the authoritative owner-gather merge (and hence
    to the single-device table) on the KPI domain."""
    got, want, pipe, eng = _run_pair(4)
    snap = eng.snapshot()
    for spec in eng.specs:
        reduced = eng.tree_reduced_table(spec.name)
        gathered = owner_gather(snap.shard_states[spec.name],
                                snap.seg_owners[spec.name])
        assert reduced.tobytes() == gathered.tobytes(), spec.name
        assert reduced.tobytes() == want["views"][spec.name], spec.name


def test_shard_routed_batch_gather_bitwise():
    """The batched read path routes each point query to its owning shard
    (one gather dispatch per shard) and the answers are bitwise the
    unsharded single-dispatch answers."""
    from repro.serving.batch import ReportQuery, compile_queries
    from repro.serving.server import ReportServer

    got, want, pipe, eng = _run_pair(4)
    cfg2, src2 = _workload()
    pipe2 = DODETLPipeline(cfg2, src2, n_workers=2)
    ref = MaterializedViewEngine(steelworks_views(cfg2.n_business_keys))
    pipe2.warehouse.attach_serving(ref)
    _drill_loop(pipe2, ref)

    queries = [ReportQuery("oee", unit=int(u))
               for u in range(cfg2.n_business_keys)] \
        + [ReportQuery("oee"), ReportQuery("top_downtime", k=3),
           ReportQuery("kpi_rollup"), ReportQuery("production_rate"),
           ReportQuery("shift_report")]
    plan = compile_queries(queries)
    res_sharded = plan.execute(ReportServer(eng).snapshot())
    res_plain = plan.execute(ReportServer(ref).snapshot())
    reps_s, reps_p = res_sharded.reports(), res_plain.reports()
    assert len(reps_s) == len(reps_p) == len(queries)
    for a, b in zip(reps_s, reps_p):
        assert a.view == b.view
        assert set(a.data) == set(b.data), a.view
        for key, va in a.data.items():
            vb = b.data[key]
            if isinstance(va, np.ndarray):
                assert va.tobytes() == vb.tobytes(), (a.view, key)
            else:
                assert va == vb, (a.view, key)


# -------------------------------------------------------- checkpoint/recovery
@pytest.mark.parametrize("point,ordinal", [(COMMIT_POST, 5),
                                           (REPARTITION_MID, 1)])
def test_sharded_checkpoint_recovery_drill(tmp_path, point, ordinal):
    """Crash mid-stream (and mid-repartition) with a SHARDED engine on
    both sides: checkpoints capture per-shard fold state, recovery
    restores it onto a sharded engine, and the finished run is
    byte-identical to the uninterrupted sharded run — which is itself
    bitwise-identical to the single-device oracle (test above)."""
    wl = dict(n=500, zipf_s=1.2, strategy="skew")
    repartition_at = 3
    want, _, _, _ = _run_pair(2, repartition_at=repartition_at, **wl)

    cfg, src = _workload(**wl)
    fault = FaultInjector({point: ordinal})
    pipe = DODETLPipeline(cfg, src, n_workers=2, fault=fault)
    eng = _sharded_engine(cfg, 2)
    eng.reown(pipe.current_routing())
    pipe.warehouse.attach_serving(eng)
    pipe.warehouse.attach_shards(eng.ownership)
    journal = DurabilityJournal(str(tmp_path))
    coord = RecoveryCoordinator(journal)
    with pytest.raises(InjectedCrash):
        _drill_loop(pipe, eng, coord=coord, repartition_at=repartition_at)

    eng2 = _sharded_engine(cfg, 2)
    pipe2, coord2, info = recover_pipeline(
        cfg, src, DurabilityJournal(str(tmp_path)), engine=eng2,
        n_workers=2)
    assert info is not None
    eng2.reown(pipe2.current_routing())
    pipe2.warehouse.attach_shards(eng2.ownership)
    _drill_loop(pipe2, eng2, coord=coord2, repartition_at=repartition_at)
    _assert_identical(_final_state(pipe2, eng2), want)
    _assert_warehouse_shards_partition(pipe2, eng2)


def test_export_captures_per_shard_state_and_restores_cross_shape():
    """export_fold_state carries the per-shard tables + ownership; a
    restore onto a matching engine adopts them directly, and a restore
    onto a DIFFERENT shard count re-derives exact shard placement from
    the merged tables (owned columns merged, foreign identity)."""
    got, want, pipe, eng = _run_pair(4)
    state = eng.export_fold_state()
    assert state["shard"]["n_shards"] == 4
    for spec in eng.specs:
        stacked = state["shard"]["tables"][spec.name]
        assert stacked.shape[0] == 4
        owners = state["shard"]["seg_owners"][spec.name]
        merged = owner_gather(list(stacked), owners)
        assert merged.tobytes() == state["tables"][spec.name].tobytes()

    for k2 in (2, 4):                       # same and different shape
        eng2 = ShardedViewEngine(eng.specs, n_shards=k2,
                                 router=eng.ownership.router)
        eng2.restore_fold_state(state)
        snap = eng2.snapshot()
        for spec in eng.specs:
            assert snap.view(spec.name).table.tobytes() \
                == want["views"][spec.name], (k2, spec.name)
            gathered = owner_gather(snap.shard_states[spec.name],
                                    snap.seg_owners[spec.name])
            assert gathered.tobytes() == want["views"][spec.name]


# ----------------------------------------------------------------- cluster
def test_cluster_wires_sharded_plane_and_health_mesh_block():
    """ConcurrentCluster with a ShardedViewEngine: ownership aligns to
    the live routing epoch, the warehouse gets shard sub-logs, and
    health() exposes the mesh block (shard imbalance observation)."""
    cfg, src = _workload(n=600, n_partitions=8)
    pipe = DODETLPipeline(cfg, src, n_workers=2)
    eng = _sharded_engine(cfg, 2)
    pipe.extract()
    cluster = ConcurrentCluster(pipe, poll_cdc=False, serving=eng)
    cluster.start()
    cluster.run_until_idle(timeout=60)
    cluster.stop_all()
    eng.fold_pending()
    h = cluster.health()
    assert h["mesh"]["n_shards"] == 2
    assert sum(h["mesh"]["fold_rows"]) > 0
    assert h["mesh"]["merge"]["dispatches"] > 0
    assert any(k.startswith("shard.fold_rows") for k in h["counters"])
    _assert_warehouse_shards_partition(pipe, eng)

    # unsharded engines get the same-shape stub
    cfg2, src2 = _workload(n=100)
    pipe2 = DODETLPipeline(cfg2, src2, n_workers=1)
    cluster2 = ConcurrentCluster(
        pipe2, poll_cdc=False,
        serving=MaterializedViewEngine(
            steelworks_views(cfg2.n_business_keys)))
    h2 = cluster2.health()
    assert h2["mesh"]["n_shards"] == 1 and not h2["mesh"]["device_mesh"]


# ------------------------------------------------------------- real mesh
_MESH_DRILL = textwrap.dedent("""
    import numpy as np
    from repro.launch.mesh import virtual_devices, make_shard_mesh
    virtual_devices(4)                      # before any jax device state
    import jax
    assert jax.device_count() >= 4, jax.device_count()

    from repro.core.backend import get_backend
    from repro.runtime.shard_plane import ShardedViewEngine
    from repro.serving.engine import MaterializedViewEngine
    from repro.serving.views import steelworks_views

    rng = np.random.default_rng(3)
    n_units = 16
    specs = steelworks_views(n_units)

    def mkdelta(n):
        f = np.zeros((n, 10), np.float32)
        f[:, 0] = rng.integers(0, n_units, n)
        f[:, 1] = rng.uniform(0, 10000, n)
        f[:, 2] = f[:, 1] + rng.uniform(1, 50, n)
        f[:, 3:7] = rng.uniform(0, 1, (n, 4))
        f[:, 7] = rng.uniform(0, 40, n)
        f[:, 8] = rng.uniform(0, 10, n)
        f[:, 9] = (rng.uniform(0, 1, n) > 0.1).astype(np.float32)
        return f

    be = get_backend("jax")
    eng = ShardedViewEngine(specs, n_shards=4, backend="jax")
    ref = MaterializedViewEngine(specs, backend="jax")
    be.set_mesh(make_shard_mesh(4))         # folds now run shard_map
    try:
        for _ in range(6):
            d = mkdelta(int(rng.integers(100, 2500)))
            eng.publish(d); ref.publish(d)
            eng.fold_pending(); ref.fold_pending()
    finally:
        be.set_mesh(None)
    s, r = eng.snapshot(), ref.snapshot()
    rep = eng.mesh_report()
    for spec in specs:
        assert s.view(spec.name).table.tobytes() \\
            == r.view(spec.name).table.tobytes(), spec.name
    print("MESH_PARITY_OK", jax.device_count())
""")


@pytest.mark.skipif("jax" not in BACKENDS, reason="jax not available")
def test_real_mesh_4device_bitwise_parity():
    """On a REAL simulated 4-device mesh (forced host devices, shard_map
    dispatch per fold block) the sharded engine's published state is
    bitwise-identical to the single-device jax engine."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)              # the drill sets its own
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _MESH_DRILL], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MESH_PARITY_OK" in out.stdout


def test_virtual_devices_refuses_when_jax_initialized():
    """virtual_devices must refuse (clear error, not a silent no-op) in
    a process whose jax runtime is already initialized — the forcing
    flag would be ignored."""
    import jax

    from repro.launch.mesh import virtual_devices

    jax.devices()                           # ensure initialized
    with pytest.raises(RuntimeError, match="already initialized"):
        virtual_devices(4)


# -------------------------------------------------- sharding ctx satellites
def test_sharding_ctx_axis_sizes_computed_once():
    """The {axis: size} map is built once per ctx, not per _axis_size
    call (the satellite fix), and spec_for_shape still drops mesh axes
    for too-small dims."""
    from repro.models.sharding import ShardingCtx

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.zeros((4, 2))

    ctx = ShardingCtx(mesh=FakeMesh())
    first = ctx._axis_sizes
    assert first == {"data": 4, "model": 2}
    assert ctx._axis_sizes is first          # cached, same object
    assert ctx._axis_size("data") == 4
    assert ctx._axis_size(("data", "model")) == 8
    assert ctx._axis_sizes is first


def test_spec_for_shape_still_drops_too_small_dims():
    from repro.models.sharding import ShardingCtx, default_rules

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.zeros((4, 2))

    ctx = ShardingCtx(mesh=FakeMesh(), rules=default_rules())
    # batch -> "data" (size 4): a dim of 2 is too small, 8 is fine
    assert ctx.spec_for_shape(("batch", None), (2, 16))[0] is None
    assert ctx.spec_for_shape(("batch", None), (8, 16))[0] == "data"
    # heads -> "model" (size 2): 1 too small, 2 kept
    assert ctx.spec_for_shape(("heads",), (1,))[0] is None
    assert ctx.spec_for_shape(("heads",), (2,))[0] == "model"
