"""Skew-aware adaptive partitioning tests: pluggable strategies, routing
epochs, surgical cache migration (ISSUE 5).

Covers the acceptance contract: consistent-hash minimal movement on scale
events, byte-identical warehouse + serving state across strategies and
across a mid-run repartition, surgical migration == reset-then-rewarm
oracle on all three backends, zero-loss live repartition retaining ≥ 50%
of survivors' cache entries.
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase
from repro.core.cache import InMemoryTable
from repro.core.message_queue import MessageQueue, TopicConfig
from repro.core.partitioning import (PartitionAssignment, RoutingTable,
                                     get_strategy, partition_of)
from repro.core.records import make_batch
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.runtime.cluster import ConcurrentCluster

BACKENDS = ("numpy", "jax", "pallas")


# ------------------------------------------------------------- routing tables
def test_static_table_is_byte_identical_to_legacy_hash():
    keys = np.random.default_rng(0).integers(0, 10**12, 4000)
    t = RoutingTable.static(20)
    np.testing.assert_array_equal(t.partition_of(keys),
                                  partition_of(keys, 20))


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=2, max_value=24))
def test_consistent_hash_minimal_movement_on_scale_up(n_parts):
    """Adding one partition to the ring moves ≤ ~1/(n+1) + ε of keys, and
    every moved key moves TO the new partition (nothing reshuffles
    between survivors)."""
    keys = np.random.default_rng(1).integers(0, 10**12, 8000)
    cs = get_strategy("consistent")
    a = cs.initial_table(n_parts)
    b = cs.scaled_table(a, n_parts + 1)
    pa, pb = a.partition_of(keys), b.partition_of(keys)
    moved = pa != pb
    assert moved.mean() <= 1.0 / (n_parts + 1) + 0.1
    assert set(pb[moved].tolist()) <= {n_parts}
    # static modulus reshuffles nearly everything — the contrast the ring
    # exists for
    sa = RoutingTable.static(n_parts)
    sb = RoutingTable.static(n_parts + 1, epoch=1)
    assert (sa.partition_of(keys) != sb.partition_of(keys)).mean() > 0.5


def test_worker_scale_up_moves_about_one_over_w_of_keys():
    """Sticky load-aware assignment + any routing: adding a worker moves
    ≈ 1/(W+1) of the KEY SPACE between workers (the old round-robin
    reshuffle moved most of it)."""
    keys = np.random.default_rng(2).integers(0, 10**12, 20000)
    table = RoutingTable.static(20)
    pa = PartitionAssignment(20, ["w0", "w1", "w2"])
    before = np.array([hash(pa.worker_of(p)) for p in table.partition_of(keys)])
    pa.rebalance(["w0", "w1", "w2", "w3"])
    after = np.array([hash(pa.worker_of(p)) for p in table.partition_of(keys)])
    moved = (before != after).mean()
    assert moved <= 1.0 / 4 + 0.08, moved


def test_rebalance_changed_dict_is_consistent():
    """Satellite: EVERY worker passed to rebalance appears in the result —
    unchanged survivors with an empty list, late-added workers with their
    gains — and the gain lists are sorted."""
    pa = PartitionAssignment(12, ["a", "b", "c"])
    changed = pa.rebalance(["a", "c", "d"])          # b died, d joined
    assert set(changed) == {"a", "c", "d"}
    for w, parts in changed.items():
        assert parts == sorted(parts)
    assert changed["d"]                               # newcomer gained
    # coverage is preserved
    assert sorted(sum((pa.partitions_of(w) for w in "acd"), [])) == \
        list(range(12))
    # no-op rebalance: everyone present, nothing moved
    changed2 = pa.rebalance(["a", "c", "d"])
    assert set(changed2) == {"a", "c", "d"}
    assert all(v == [] for v in changed2.values())


def test_skew_strategy_balances_to_atomic_floor_and_is_idempotent():
    sk = get_strategy("skew")
    bk = np.arange(50, dtype=np.int64)
    load = (1e5 / np.arange(1, 51) ** 1.2).astype(np.int64)
    t0 = sk.initial_table(4)
    t1 = sk.rebalanced_table(t0, None, (bk, load))

    def imbalance(tab):
        per = np.zeros(4)
        np.add.at(per, tab.partition_of(bk), load)
        return per.max() / per.mean()

    floor = load.max() / (load.sum() / 4)
    assert t1.epoch == t0.epoch + 1
    assert imbalance(t1) < imbalance(t0)
    assert imbalance(t1) <= max(floor, 1.0) + 0.15
    # idempotent: a balanced table does not churn epochs
    t2 = sk.rebalanced_table(t1, None, (bk, load))
    assert t2.epoch == t1.epoch


# -------------------------------------------------------------- routing epochs
def test_routing_epoch_residuals_stay_readable_and_retire():
    """Records published under epoch E stay in E's partitions and remain
    consumable after the switch to E+1; E retires only once committed
    past its horizons."""
    q = MessageQueue()
    topic = q.create_topic(TopicConfig("t", 0, 4, "business_key"))
    n = 80
    q.publish("t", make_batch(0, 0, np.arange(n), np.arange(n) % 8,
                              np.arange(n), np.zeros((n, 8), np.float32)))
    e0 = topic.routing
    new = get_strategy("skew").initial_table(4)
    new = dataclasses.replace(new, epoch=1)
    topic.set_routing(new)
    assert topic.routing.epoch == 1
    assert [t.epoch for t in topic.live_tables()] == [0, 1]
    # publish under E1: may land elsewhere, E0 residuals untouched
    q.publish("t", make_batch(0, 0, np.arange(n), np.arange(n) % 8,
                              np.arange(n), np.zeros((n, 8), np.float32),
                              lsn_start=n))
    got = 0
    for p in range(4):
        b = q.consume("g", "t", p)
        q.commit("g", "t", p, len(b))
        got += len(b)
    assert got == 2 * n                  # nothing lost across the epochs
    committed = {p: q.committed("g", "t", p) for p in range(4)}
    assert topic.retire_epochs(committed)
    assert [t.epoch for t in topic.live_tables()] == [1]


# --------------------------------------------------- surgical cache migration
@pytest.mark.parametrize("backend", BACKENDS)
def test_surgical_migration_equals_reset_then_rewarm_oracle(backend):
    """retain_only + gained-keys upsert must land in EXACTLY the state a
    full reset-then-rewarm with the new key set produces: same rows, same
    probe results, on every compute backend."""
    rng = np.random.default_rng(3)
    n_units, rows = 24, 200
    units = (np.arange(rows) % n_units).astype(np.int64)
    payload = rng.normal(size=(rows, 8)).astype(np.float32)
    payload[:, 1] = units                # column 1 carries the business key
    join_keys = np.arange(rows, dtype=np.int64) + 1000
    txn = np.arange(rows, dtype=np.int64)

    keys_a = np.arange(0, 16, dtype=np.int64)          # owned before
    keys_b = np.arange(8, 24, dtype=np.int64)          # owned after

    def rows_for(units_sel):
        m = np.isin(units, units_sel)
        return join_keys[m], payload[m], txn[m]

    surg = InMemoryTable(1024, backend=backend)
    surg.upsert(*rows_for(keys_a))
    kept, dropped = surg.retain_only(keys_b)           # drop 0..7
    gained = np.setdiff1d(keys_b, keys_a)
    surg.upsert(*rows_for(gained))                     # rewarm 16..23 only

    oracle = InMemoryTable(1024, backend=backend)
    oracle.reset_from_snapshot(*rows_for(keys_b))

    assert kept + dropped == (np.isin(units, keys_a)).sum()
    assert surg.n_rows == oracle.n_rows
    probe = np.concatenate([join_keys, join_keys[:5] + 10**6])
    sa = surg.snapshot_view(surg.device_state is not None and
                            __import__("repro.core.backend",
                                       fromlist=["get_backend"]
                                       ).get_backend(backend).device)
    so = oracle.snapshot_view(sa._device is not None)
    va, fa, ta = sa.lookup(probe)
    vo, fo, to = so.lookup(probe)
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fo))
    np.testing.assert_allclose(np.asarray(va), np.asarray(vo), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ta), np.asarray(to))
    # the watermark tracks the master stream, not the owned slice
    assert surg.watermark >= oracle.watermark


def test_worker_migrate_caches_matches_reset_oracle():
    """Pipeline-level oracle: after a surgical migration the worker's
    caches answer every probe exactly like the paper's full reset."""
    cfg = steelworks_config(n_partitions=8, backend="numpy",
                            partition_strategy="skew")
    cfg = dataclasses.replace(cfg, n_business_keys=64)
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=1200, n_equipment=64)).generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=2)
    pipe.extract()
    pipe.bootstrap_caches()
    w = pipe.workers[0]
    prev = w.assigned_business_keys(cfg.n_business_keys)
    # force a different key set: steal a peer partition that holds keys
    other = pipe.workers[1]
    other_keys = other.assigned_business_keys(cfg.n_business_keys)
    moved = int(pipe.current_routing().partition_of(other_keys)[0])
    other.partitions = [p for p in other.partitions if p != moved]
    w.partitions = sorted(set(w.partitions) | {moved})
    stats = w.migrate_caches(pipe.master_topic_map, cfg.n_business_keys, prev)
    assert stats.retained_rows > 0 and stats.gained_rows > 0
    assert stats.retention == 1.0        # pure gain: nothing dropped
    # oracle: full reset with the same final key set
    redump = w.reset_caches(pipe.master_topic_map, cfg.n_business_keys)
    assert redump >= 0
    # counts must agree (reset is the rewarm oracle)
    assert w.equipment.n_rows > 0


# ----------------------------------- cross-strategy equivalence (sequential)
def _run_strategy(strategy: str, repartition: bool):
    from repro.serving import MaterializedViewEngine, steelworks_views
    cfg = steelworks_config(n_partitions=8, backend="numpy",
                            partition_strategy=strategy)
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=1500, n_equipment=8, zipf_s=0.8)).generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=3)
    engine = MaterializedViewEngine(steelworks_views(8), backend="numpy")
    pipe.warehouse.attach_serving(engine)
    pipe.extract()
    pipe.bootstrap_caches()
    pipe.step(120)
    if repartition:
        pipe.repartition()
    pipe.run_to_completion()
    engine.fold_pending()
    return pipe, engine


def test_warehouse_and_views_identical_across_strategies():
    """Byte-identical canonical warehouse across all 3 strategies and a
    mid-run repartition; serving state equivalent (replaying either
    canonical table is byte-identical, and every live view agrees with
    its own rebuild oracle byte-for-byte)."""
    from repro.serving import MaterializedViewEngine, steelworks_views
    runs = {s: _run_strategy(s, repartition=(s != "static"))
            for s in ("static", "consistent", "skew")}
    ref_pipe, _ = runs["static"]
    ref = ref_pipe.warehouse.canonical_fact_table()
    assert len(ref) == 1500
    for s, (pipe, engine) in runs.items():
        t = pipe.warehouse.canonical_fact_table()
        assert t.shape == ref.shape
        assert t.tobytes() == ref.tobytes(), f"{s}: warehouse diverged"
        # live incremental state == its own recompute oracle, bitwise
        snap = engine.snapshot()
        oracle = MaterializedViewEngine.rebuild(
            steelworks_views(8), pipe.warehouse.read_view().chunks,
            backend="numpy")
        for name, st_ in snap.states.items():
            assert st_.table.tobytes() == \
                oracle.states[name].table.tobytes(), (s, name)
    # canonical replay: the same fact SET folds to the same state no
    # matter which strategy produced it
    a = MaterializedViewEngine.rebuild(steelworks_views(8), [ref],
                                       backend="numpy").states
    for s, (pipe, _) in runs.items():
        b = MaterializedViewEngine.rebuild(
            steelworks_views(8), [pipe.warehouse.canonical_fact_table()],
            backend="numpy").states
        for name in a:
            assert a[name].table.tobytes() == b[name].table.tobytes()


# --------------------------------------------- live cluster: zero-loss + 50%
def test_live_repartition_zero_loss_and_cache_retention():
    """Acceptance pin: a mid-run skew repartition on the concurrent
    cluster completes with zero record loss (exactly-once preserved) and
    retains ≥ 50% of surviving workers' cache entries."""
    n = 5000
    cfg = steelworks_config(n_partitions=12, backend="numpy",
                            partition_strategy="skew")
    cfg = dataclasses.replace(cfg, buffer_capacity=16384,
                              n_business_keys=60)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n, n_equipment=60, zipf_s=1.2))
    pipe = DODETLPipeline(cfg, src, n_workers=4)
    feeder = threading.Thread(target=lambda: sampler.generate(src))
    cluster = ConcurrentCluster(pipe)
    cluster.start()
    feeder.start()
    deadline = time.time() + 30
    while cluster.records_done() < n // 5 and time.time() < deadline:
        time.sleep(0.005)
    stats = cluster.repartition()
    assert stats["cache_retention"] >= 0.5, stats
    feeder.join()
    done = cluster.run_until_idle(timeout=90)
    # epochs retire once the old epoch's records are committed
    cluster.retire_epochs()
    cluster.stop_all()
    assert done == n == pipe.warehouse.rows_loaded
    assert sum(rt.worker.buffer.dropped
               for rt in cluster.runtimes.values()) == 0
    t0 = pipe.queue.topics[pipe.operational_topics[0]]
    assert len(t0.live_tables()) == 1
    assert (pipe.warehouse.canonical_fact_table()[:, -1] > 0.5).all()


def test_scale_partitions_with_consistent_ring_mid_stream():
    """Elastic partition scale event under the consistent-hash ring: the
    topic grows, only ~1/n of the key space moves, the stream completes
    with zero loss."""
    n = 3000
    cfg = steelworks_config(n_partitions=8, backend="numpy",
                            partition_strategy="consistent")
    cfg = dataclasses.replace(cfg, buffer_capacity=16384)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n, n_equipment=8))
    pipe = DODETLPipeline(cfg, src, n_workers=3)
    feeder = threading.Thread(target=lambda: sampler.generate(src))
    cluster = ConcurrentCluster(pipe)
    cluster.start()
    feeder.start()
    time.sleep(0.1)
    stats = cluster.scale_partitions(10)
    assert stats["epoch"] >= 1
    assert stats["moved_key_fraction"] <= 0.55   # ring, not a reshuffle
    feeder.join()
    done = cluster.run_until_idle(timeout=90)
    cluster.stop_all()
    assert done == n == pipe.warehouse.rows_loaded
    assert pipe.queue.topics[pipe.operational_topics[0]].cfg.n_partitions \
        == 10
