"""Crash-consistent durability drills (ROADMAP: exactly-once recovery).

Every drill follows the same shape: run a deterministic state-driven loop
with periodic journal checkpoints, kill the process at a named stage seam
(``repro.durability.faults``), recover a FRESH pipeline from the journal,
finish the loop — and assert the final warehouse fact table and every
materialized-view aggregate are **byte-identical** to an uninterrupted
run of the same loop. Byte identity subsumes exactly-once: a lost record
changes the canonical table, a duplicated one changes it too.

Determinism notes the drills rely on:

* the loop extracts incrementally (``extract(limit)`` per iteration), so
  late master rows genuinely arrive late and the §3.2 buffer path is
  exercised; listener offsets are journaled, so a recovered run resumes
  extraction exactly where the checkpoint left it;
* triggers are STATE-derived (warehouse commit seq, routing epoch), never
  iteration counters — a recovered run re-derives them from restored
  state and re-attempts the same actions (e.g. the mid-crash
  repartition);
* view comparison uses aggregate-table bytes + rows/deltas folded, not
  epoch numbers (fold cadence differs across a restart; state must not).
"""
import dataclasses
import os
import threading
import time

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, MessageQueue, SourceDatabase, \
    TopicConfig
from repro.core.backend import available_backends
from repro.core.records import make_batch
from repro.data.sampler import SamplerConfig, SteelworksSampler
from repro.durability import (CRASH_POINTS, DurabilityJournal, FaultInjector,
                              InjectedCrash, RecoveryCoordinator,
                              recover_pipeline)
from repro.durability.faults import (CHECKPOINT_MID_WRITE, COMMIT_POST,
                                     INGEST_FETCH, LOAD_PRE_COMMIT,
                                     REPARTITION_MID, TRANSFORM_DONE)
from repro.runtime.cluster import ConcurrentCluster
from repro.serving.engine import MaterializedViewEngine
from repro.serving.views import steelworks_views
from repro.train import checkpoint as ckpt

BACKENDS = [b for b in ("numpy", "jax", "pallas")
            if b in available_backends()]

# crash points wired through the SEQUENTIAL worker's process_operational
SEQ_POINTS = (INGEST_FETCH, TRANSFORM_DONE, LOAD_PRE_COMMIT, COMMIT_POST)


# --------------------------------------------------------------------- harness
def _workload(backend="numpy", n=400, n_partitions=4, zipf_s=0.0,
              strategy="static", seed=0):
    cfg = steelworks_config(n_partitions=n_partitions, backend=backend,
                            partition_strategy=strategy)
    cfg = dataclasses.replace(cfg, buffer_capacity=4096)
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n, n_equipment=n_partitions,
        late_master_frac=0.15, zipf_s=zipf_s, seed=seed)).generate(src)
    return cfg, src


def _engine(cfg, backend="numpy"):
    return MaterializedViewEngine(steelworks_views(cfg.n_business_keys),
                                  backend=backend)


def _extraction_lag(pipe):
    log = pipe.source.log
    return sum(max(0, log.next_lsn - l.offset)
               for l in pipe.tracker.listeners)


def _drill_loop(pipe, engine, coord=None, ckpt_every=2, extract_per=60,
                repartition_at=None, cap=40, max_steps=300):
    """The deterministic state-driven loop every drill (oracle,
    interrupted, recovered) executes. One iteration: extract a bounded
    slice of the CDC log, maybe repartition (state-derived trigger), one
    micro-batch step, fold views, maybe checkpoint."""
    steps = stalls = 0
    while steps < max_steps:
        steps += 1
        pipe.extract(extract_per)
        if repartition_at is not None \
                and pipe.current_routing().epoch == 0 \
                and pipe.warehouse.commit_seq >= repartition_at:
            pipe.repartition()
        n = pipe.step(cap)
        engine.fold_pending()
        if coord is not None and steps % ckpt_every == 0:
            coord.checkpoint(pipe, engine=engine)
        if _extraction_lag(pipe) > 0:
            stalls = 0
            continue
        if n == 0 and sum(len(w.buffer) for w in pipe.workers) == 0:
            break
        stalls = stalls + 1 if n == 0 else 0
        if stalls >= 3:
            break
    return steps


def _final_state(pipe, engine):
    snap = engine.snapshot()
    return {
        "facts": pipe.warehouse.canonical_fact_table().tobytes(),
        "rows": pipe.warehouse.rows_loaded,
        "seq": pipe.warehouse.commit_seq,
        "views": {n: st.table.tobytes() for n, st in snap.states.items()},
        "rows_folded": snap.rows_folded,
        "deltas_folded": snap.deltas_folded,
    }


_ORACLES = {}


def _oracle(backend="numpy", repartition_at=None, **wl):
    """Uninterrupted run of the drill loop (memoized per scenario)."""
    key = (backend, repartition_at, tuple(sorted(wl.items())))
    if key not in _ORACLES:
        cfg, src = _workload(backend=backend, **wl)
        pipe = DODETLPipeline(cfg, src, n_workers=2)
        eng = _engine(cfg, backend)
        pipe.warehouse.attach_serving(eng)
        _drill_loop(pipe, eng, repartition_at=repartition_at)
        _ORACLES[key] = _final_state(pipe, eng)
    return _ORACLES[key]


def _crash_and_recover(tmp_path, point, ordinal, backend="numpy",
                       repartition_at=None, journal_fault=False, **wl):
    """Run the drill loop with a scheduled crash, recover from the
    journal into fresh objects, finish the loop. Returns (final state,
    injector, recovery info, commit seq at crash)."""
    cfg, src = _workload(backend=backend, **wl)
    fault = FaultInjector({point: ordinal})
    pipe = DODETLPipeline(cfg, src, n_workers=2, fault=fault)
    eng = _engine(cfg, backend)
    pipe.warehouse.attach_serving(eng)
    journal = DurabilityJournal(str(tmp_path)) if not journal_fault \
        else DurabilityJournal(str(tmp_path), fault=fault)
    coord = RecoveryCoordinator(journal)
    try:
        _drill_loop(pipe, eng, coord=coord, repartition_at=repartition_at)
        crashed = False
    except InjectedCrash:
        crashed = True
    seq_at_crash = pipe.warehouse.commit_seq
    # the dead process's objects are abandoned; recovery builds new ones
    eng2 = _engine(cfg, backend)
    pipe2, coord2, info = recover_pipeline(
        cfg, src, DurabilityJournal(str(tmp_path)), engine=eng2,
        backend=backend, n_workers=2)
    if info is None:                 # crash before the first checkpoint
        pipe2.warehouse.attach_serving(eng2)
    _drill_loop(pipe2, eng2, coord=coord2, repartition_at=repartition_at)
    return _final_state(pipe2, eng2), fault, info, seq_at_crash, crashed


def _assert_identical(got, want):
    assert got["rows"] == want["rows"]           # zero lost, zero duplicated
    assert got["seq"] == want["seq"]
    assert got["facts"] == want["facts"]         # byte-identical warehouse
    assert got["rows_folded"] == want["rows_folded"]
    assert got["deltas_folded"] == want["deltas_folded"]
    for name, table in want["views"].items():
        assert got["views"][name] == table, name  # byte-identical views


# ------------------------------------------------------- sequential drill matrix
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("point", SEQ_POINTS)
def test_crash_drill_byte_identical(tmp_path, point, backend):
    """Kill at each stage seam (fetched-uncommitted, transformed-unloaded,
    loaded-uncommitted, committed) -> restart -> the final warehouse and
    every view aggregate are byte-identical to the uninterrupted run, on
    every backend."""
    want = _oracle(backend=backend)
    got, fault, info, seq_at_crash, crashed = _crash_and_recover(
        tmp_path, point, ordinal=5, backend=backend)
    assert crashed and fault.tripped_at == point   # the drill really died
    assert info is not None                        # ...after checkpoints
    _assert_identical(got, want)
    # incremental recovery: the serving layer replayed only the chunk-log
    # suffix past its checkpointed fold state, never the whole history
    assert 0 <= info["replayed_chunks"] <= info["commit_seq"]
    if info["commit_seq"] > 2:
        assert info["replayed_chunks"] < info["commit_seq"]


def test_crash_before_first_checkpoint_recovers_cold(tmp_path):
    """A crash before any checkpoint leaves an empty journal; recovery is
    a clean cold start (offsets at zero, empty warehouse) and the rerun
    still matches the oracle exactly."""
    want = _oracle()
    got, fault, info, _, crashed = _crash_and_recover(
        tmp_path, INGEST_FETCH, ordinal=1)
    assert crashed and info is None
    _assert_identical(got, want)


def test_mid_checkpoint_write_crash(tmp_path):
    """Die after the checkpoint tmp dir is fully written but before the
    atomic rename: the torn step is invisible (swept on load), recovery
    falls back to the previous good step, and the rerun is exact."""
    want = _oracle()
    got, fault, info, _, crashed = _crash_and_recover(
        tmp_path, CHECKPOINT_MID_WRITE, ordinal=2, journal_fault=True)
    assert crashed and fault.tripped_at == CHECKPOINT_MID_WRITE
    assert info is not None and info["step"] == 0    # fell back to step_0
    _assert_identical(got, want)


def test_mid_repartition_crash(tmp_path):
    """Die between the routing-epoch switch and the ownership rebalance —
    the half-applied migration window — under a zipf-skewed workload with
    the skew-aware strategy. The recovered run re-derives the repartition
    trigger from restored state, re-runs the full migration, and ends
    byte-identical to the uninterrupted run (which also repartitions)."""
    wl = dict(n=500, zipf_s=1.2, strategy="skew")
    want = _oracle(repartition_at=3, **wl)
    got, fault, info, _, crashed = _crash_and_recover(
        tmp_path, REPARTITION_MID, ordinal=1, repartition_at=3, **wl)
    assert crashed and fault.tripped_at == REPARTITION_MID
    _assert_identical(got, want)


# --------------------------------------------------------- concurrent kill drill
@pytest.mark.parametrize("point", (INGEST_FETCH, LOAD_PRE_COMMIT,
                                   COMMIT_POST))
def test_concurrent_kill_drill_exactly_once(tmp_path, point):
    """The real runtime: stage threads + periodic checkpointer, killed
    mid-stream at a stage seam (the whole cluster is then abandoned
    without drains or commits — what a kill -9 leaves). Recovery resumes
    and the result is byte-identical to the sequential single-worker
    oracle: zero records lost, zero duplicated."""
    n = 3000
    cfg, src = _workload(n=n, n_partitions=8)
    fault = FaultInjector({point: 6})
    pipe = DODETLPipeline(cfg, src, n_workers=3, fault=fault)
    eng = _engine(cfg)
    journal = DurabilityJournal(str(tmp_path))
    coord = RecoveryCoordinator(journal)
    pipe.extract()                       # stream fully queued, like the
    cluster = ConcurrentCluster(         # byte-identity concurrency test
        pipe, max_records_per_partition=25, poll_cdc=False, serving=eng,
        recovery=coord, checkpoint_every_s=0.02)
    cluster.checkpoint()                 # initial step, before the threads
    cluster.start()
    assert fault.tripped.wait(30.0), "crash point never reached"
    cluster.abandon()                    # kill: no drain, no fold, no commit

    eng2 = _engine(cfg)
    pipe2, coord2, info = recover_pipeline(
        cfg, src, DurabilityJournal(str(tmp_path)), engine=eng2)
    assert info is not None
    cluster2 = ConcurrentCluster(pipe2, max_records_per_partition=25,
                                 poll_cdc=False, serving=eng2,
                                 recovery=coord2, checkpoint_every_s=0.02)
    cluster2.start()
    done = cluster2.run_until_idle(timeout=90)
    cluster2.stop_all()
    assert done + info["commit_seq"] >= 0          # progressed
    assert pipe2.warehouse.rows_loaded == n        # exactly-once

    # byte-identical to the sequential oracle (pre-extracted stream)
    cfg_o, src_o = _workload(n=n, n_partitions=8)
    oracle = DODETLPipeline(cfg_o, src_o, n_workers=1)
    oracle.extract()
    oracle.bootstrap_caches()
    oracle.run_to_completion()
    assert pipe2.warehouse.canonical_fact_table().tobytes() == \
        oracle.warehouse.canonical_fact_table().tobytes()
    # views match their own rebuild oracle over the recovered chunk log
    rebuilt = MaterializedViewEngine.rebuild(
        steelworks_views(cfg.n_business_keys),
        pipe2.warehouse.read_view().chunks, backend="numpy")
    snap = eng2.snapshot()
    assert snap.rows_folded == rebuilt.rows_folded
    for name in rebuilt.states:
        assert snap.states[name].table.tobytes() == \
            rebuilt.states[name].table.tobytes(), name


# ----------------------------------------------------- property-based schedules
def _random_drill(tmp_path, seed):
    """One randomized crash drill: random seam, ordinal, skew and
    checkpoint cadence. Exactly-once must hold for every schedule."""
    rng = np.random.default_rng(seed)
    point = str(rng.choice(list(SEQ_POINTS) + [CHECKPOINT_MID_WRITE]))
    ordinal = int(rng.integers(1, 9))
    zipf = float(rng.choice([0.0, 1.1]))
    ckpt_every = int(rng.integers(1, 4))
    wl = dict(n=350, zipf_s=zipf)
    want = _oracle(**wl)

    cfg, src = _workload(**wl)
    fault = FaultInjector({point: ordinal})
    pipe = DODETLPipeline(cfg, src, n_workers=2, fault=fault)
    eng = _engine(cfg)
    pipe.warehouse.attach_serving(eng)
    root = os.path.join(str(tmp_path), f"j{seed}")
    journal = DurabilityJournal(root, fault=fault)
    coord = RecoveryCoordinator(journal)
    try:
        _drill_loop(pipe, eng, coord=coord, ckpt_every=ckpt_every)
    except InjectedCrash:
        pass                 # ordinal may or may not be reached: both fine
    eng2 = _engine(cfg)
    pipe2, coord2, info = recover_pipeline(
        cfg, src, DurabilityJournal(root), engine=eng2, n_workers=2)
    if info is None:
        pipe2.warehouse.attach_serving(eng2)
    _drill_loop(pipe2, eng2, coord=coord2, ckpt_every=ckpt_every)
    _assert_identical(_final_state(pipe2, eng2), want)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=15, deadline=None)
def test_random_crash_schedule_property(tmp_path, seed):
    _random_drill(tmp_path, seed)


@pytest.mark.parametrize("seed", [3, 11, 42, 1234, 99991])
def test_random_crash_schedule_seeded(tmp_path, seed):
    """Deterministic fallback for the property test above (hypothesis is
    optional): a fixed sample of random schedules."""
    _random_drill(tmp_path, seed)


# ------------------------------------------------------- torn-checkpoint repair
def _journal_with_steps(tmp_path, n_steps=3):
    cfg, src = _workload(n=300)
    pipe = DODETLPipeline(cfg, src, n_workers=2)
    eng = _engine(cfg)
    pipe.warehouse.attach_serving(eng)
    journal = DurabilityJournal(str(tmp_path))
    coord = RecoveryCoordinator(journal)
    pipe.extract()
    pipe.bootstrap_caches()
    for _ in range(n_steps):
        pipe.step(40)
        eng.fold_pending()
        coord.checkpoint(pipe, engine=eng)
    return cfg, src, journal


def test_truncated_tail_step_pruned(tmp_path):
    """A torn tail step (truncated leaves.npz — the crash window) is
    pruned on load; recovery proceeds from the previous good step."""
    cfg, src, journal = _journal_with_steps(tmp_path)
    steps = journal.steps()
    leaves = os.path.join(journal._dir_for(steps[-1]), "leaves.npz")
    with open(leaves, "r+b") as f:
        f.truncate(os.path.getsize(leaves) // 2)
    state = DurabilityJournal(str(tmp_path)).load()
    assert state is not None and state["_step"] == steps[-2]
    assert journal.steps() == steps[:-1]           # torn step removed


def test_checksum_mismatch_tail_pruned(tmp_path):
    """A bit-flipped leaf fails its sha256 check; the step is rejected
    exactly like a truncation."""
    cfg, src, journal = _journal_with_steps(tmp_path)
    steps = journal.steps()
    leaves = os.path.join(journal._dir_for(steps[-1]), "leaves.npz")
    data = bytearray(open(leaves, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(leaves, "wb").write(bytes(data))
    state = DurabilityJournal(str(tmp_path)).load()
    assert state is not None and state["_step"] == steps[-2]


def test_mid_chain_corruption_raises(tmp_path):
    """Corruption in the MIDDLE of the chain (a lost step with later
    steps present) is not a crash window — silently skipping it would
    replay over a gap and violate exactly-once, so load refuses."""
    cfg, src, journal = _journal_with_steps(tmp_path)
    steps = journal.steps()
    leaves = os.path.join(journal._dir_for(steps[0]), "leaves.npz")
    with open(leaves, "r+b") as f:
        f.truncate(10)
    with pytest.raises(IOError):
        DurabilityJournal(str(tmp_path)).load()


def test_tmp_leftovers_ignored_and_swept(tmp_path):
    """Crash leftovers (`step_N.tmp-*` dirs) are never valid steps: they
    don't appear in step listings, don't break ``latest_step``, and are
    swept by load."""
    cfg, src, journal = _journal_with_steps(tmp_path, n_steps=2)
    steps_before = journal.steps()
    stray = os.path.join(str(tmp_path), "step_9.tmp-123-456")
    os.makedirs(stray)
    open(os.path.join(stray, "leaves.npz"), "wb").write(b"torn")
    assert journal.steps() == steps_before
    assert ckpt.latest_step(str(tmp_path)) == steps_before[-1]
    assert DurabilityJournal(str(tmp_path)).load() is not None
    assert not os.path.exists(stray)               # swept


def test_checkpoint_manager_falls_back_past_corruption(tmp_path):
    """The train-side CheckpointManager shares the same discipline:
    restore_latest walks past a corrupted newest step to the newest one
    that verifies."""
    mgr = ckpt.CheckpointManager(str(tmp_path), keep_last=5)
    tree = {"w": np.arange(10, dtype=np.float32)}
    for s in range(3):
        mgr.save_sync(s, {"w": tree["w"] + s}, extra={"s": s})
    bad = os.path.join(mgr.dir_for(2), "leaves.npz")
    with open(bad, "r+b") as f:
        f.truncate(8)
    step, restored, extra = mgr.restore_latest(tree)
    assert step == 1 and extra["s"] == 1
    np.testing.assert_array_equal(restored["w"], tree["w"] + 1)


# ------------------------------------------------------ broker offset durability
def _toy_queue():
    q = MessageQueue()
    q.create_topic(TopicConfig("ops", 0, 4, "business_key"))
    q.create_topic(TopicConfig("master", 1, 4, "row_key", compacted=True))
    n = 200
    q.publish("ops", make_batch(0, 0, np.arange(n), np.arange(n) % 16,
                                np.arange(n), np.zeros((n, 8), np.float32)))
    # master with key collisions: compaction must pick latest txn_time
    q.publish("master", make_batch(1, 0, np.arange(60) % 20, np.arange(60),
                                   np.arange(60),
                                   np.arange(480, dtype=np.float32)
                                   .reshape(60, 8)))
    return q


def _clone_topics(q):
    q2 = MessageQueue()
    for name, t in q.topics.items():
        q2.create_topic(dataclasses.replace(t.cfg))
    return q2


def test_offsets_survive_broker_restart():
    """fetch_many / commit / rewind state survives an export -> fresh
    broker -> restore cycle: committed offsets land exactly, read-ahead
    positions are abandoned, and consumption resumes from the commits."""
    q = _toy_queue()
    batch, counts = q.fetch_many("g", "ops", range(4), 30)
    for p in (0, 1):
        q.commit("g", "ops", p, counts[p])
    exported = q.export_state()

    q2 = _clone_topics(q)
    q2.restore_broker_state(exported)
    for p in range(4):
        assert q2.committed("g", "ops", p) == q.committed("g", "ops", p)
    assert not q2.positions                        # read-ahead not durable
    # the restored broker re-serves exactly the uncommitted records
    b2, c2 = q2.fetch_many("g", "ops", range(4))
    q.rewind("g", "ops", 2), q.rewind("g", "ops", 3)
    b1, c1 = q.fetch_many("g", "ops", range(4))
    assert c1 == c2
    np.testing.assert_array_equal(np.sort(b1.row_key), np.sort(b2.row_key))
    # compacted snapshot identical after replaying journal segments
    rks1, pls1, tts1 = q.topics["master"].snapshot()
    rks2, pls2, tts2 = q2.topics["master"].snapshot()
    order1, order2 = np.argsort(rks1), np.argsort(rks2)
    np.testing.assert_array_equal(rks1[order1], rks2[order2])
    np.testing.assert_array_equal(tts1[order1], tts2[order2])
    np.testing.assert_array_equal(pls1[order1], pls2[order2])


def test_incremental_export_only_ships_suffix():
    """export_state(since=marks) carries only records past the marks —
    the incremental-checkpoint contract (journal steps stay O(delta))."""
    q = _toy_queue()
    full = q.export_state()
    lengths = {t: m["lengths"] for t, m in full["meta"].items()}
    inc = q.export_state(since=lengths)
    assert all(not segs for segs in inc["segments"].values())
    n = 40
    q.publish("ops", make_batch(0, 0, np.arange(n) + 500, np.arange(n) % 16,
                                np.arange(n) + 500,
                                np.zeros((n, 8), np.float32)))
    inc2 = q.export_state(since=lengths)
    shipped = sum(len(cols["row_key"])
                  for segs in inc2["segments"].values()
                  for cols in segs.values())
    assert shipped == n                            # the suffix, nothing more


def test_retire_epochs_replayed_identically_after_restore():
    """Routing epochs + drain horizons survive restore: the same
    committed-offset map retires the same epochs on the restored broker
    as on the original."""
    from repro.core.partitioning import RoutingTable
    q = _toy_queue()
    t = q.topics["ops"]
    new = RoutingTable.static(4, epoch=1)
    t.set_routing(new)                             # horizons recorded
    assert len(t.live_tables()) == 2
    exported = q.export_state()

    q2 = _clone_topics(q)
    q2.restore_broker_state(exported)
    t2 = q2.topics["ops"]
    assert [tab.epoch for tab in t2.live_tables()] == \
        [tab.epoch for tab in t.live_tables()]
    # partial commits: neither broker retires the draining epoch
    partial = {p: 10 for p in range(4)}
    assert t.retire_epochs(dict(partial)) == t2.retire_epochs(dict(partial))
    assert len(t2.live_tables()) == 2
    # full commits: both retire it
    full = {p: t.high_watermark(p) for p in range(4)}
    assert t.retire_epochs(dict(full)) is True
    assert t2.retire_epochs(dict(full)) is True
    assert [tab.epoch for tab in t2.live_tables()] == [1]


def test_journal_roundtrip_delta_encoding():
    """Monotone int64 columns (lsn, txn_time) round-trip exactly through
    the journal's delta encoding, including the non-monotone and
    short-array fallbacks."""
    from repro.durability.journal import _delta_decode, _delta_encode
    for a in (np.arange(100, dtype=np.int64) * 7 + 3,
              np.array([5, 4, 3, 9, 2, 8, 1, 7, 0], np.int64),   # non-mono
              np.arange(3, dtype=np.int64),                      # short
              np.zeros(0, np.int64),
              np.array([2**40, 2**40 + 1] * 8, np.int64)):
        enc, meta = _delta_encode(a)
        np.testing.assert_array_equal(_delta_decode(enc, meta), a)
        if meta.get("enc") == "d32":
            assert enc.dtype == np.int32           # halved on disk
