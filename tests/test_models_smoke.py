"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, output shapes + finiteness; prefill/decode agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_model
from repro.optim import AdamWConfig, init_state
from repro.train.train_step import make_train_step

ARCHS = C.list_archs()


def _batch(m, key, b=2, s=64):
    cfg = m.cfg
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    m = build_model(arch, smoke=True)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(m, jax.random.PRNGKey(1))
    logits, cache, aux = m.forward(params, batch, mode="train")
    assert logits.shape == (2, 64, m.cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert cache is None                      # train mode carries no cache


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_runs_and_is_finite(arch):
    m = build_model(arch, smoke=True)
    params = m.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    step = make_train_step(m, AdamWConfig(warmup_steps=1, total_steps=10))
    batch = _batch(m, jax.random.PRNGKey(1))
    batch["targets"] = jnp.roll(batch["tokens"], -1, axis=1)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "rwkv6-7b",
                                  "zamba2-1.2b", "whisper-small"])
def test_prefill_decode_matches_full_forward(arch):
    m = build_model(arch, smoke=True)
    cfg = m.cfg
    params = m.init(jax.random.PRNGKey(2))
    b, s, tail = 2, 64, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(4), (b, cfg.enc_seq, cfg.d_model),
            jnp.bfloat16)
    full, _, _ = m.forward(params, batch, mode="train")

    p = s - tail
    pre = dict(batch, tokens=toks[:, :p])
    _, cache, _ = m.forward(params, pre, mode="prefill")

    def pad_kv(c):
        out = {}
        for k, v in c.items():
            if isinstance(v, dict):
                out[k] = pad_kv(v)
            elif k in ("k", "v") and v.ndim >= 3 and v.shape[-3] == p:
                padw = [(0, 0)] * v.ndim
                padw[-3] = (0, tail)
                out[k] = jnp.pad(v, padw)
            else:
                out[k] = v
        return out

    cache = pad_kv(cache)
    errs = []
    for t in range(p, s):
        dl, cache, _ = m.forward(params, {"tokens": toks[:, t:t + 1]},
                                 mode="decode", cache=cache, cache_index=t)
        errs.append(float(jnp.abs(dl[:, 0] - full[:, t]).max()))
    scale = float(jnp.abs(full).max())
    assert max(errs) < 0.02 * max(scale, 1.0), (max(errs), scale)


def test_mrope_reduces_to_rope_for_text():
    from repro.models.layers import apply_mrope, apply_rope
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 16, 4, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    pos3 = jnp.stack([pos] * 3, axis=-1)
    np.testing.assert_allclose(
        np.asarray(apply_rope(x, pos, 1e4)),
        np.asarray(apply_mrope(x, pos3, 1e4)), rtol=2e-5, atol=2e-5)


def test_param_counts_in_expected_range():
    """Full configs hit the published parameter-count ballpark."""
    expect = {
        "internlm2-1.8b": (1.5e9, 2.3e9),
        "qwen2-vl-7b": (6.5e9, 9e9),
        "rwkv6-7b": (6e9, 9e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
        "whisper-small": (0.2e9, 0.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = C.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_aux_loss_nonzero_and_balancedish():
    m = build_model("qwen2-moe-a2.7b", smoke=True)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(m, jax.random.PRNGKey(1))
    _, _, aux = m.forward(params, batch, mode="train")
    assert float(aux) > 0.0
