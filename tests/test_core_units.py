"""Unit + property tests (hypothesis) for the DOD-ETL substrate."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (InMemoryTable, MessageQueue, OperationalMessageBuffer,
                        PartitionAssignment, RecordBatch, TopicConfig,
                        make_batch, partition_of)
from repro.core.cache import lookup_ref
import jax.numpy as jnp


# ---------------------------------------------------------------- queue
def _batch(n, table_id=0, bk_mod=7, start=0):
    ids = np.arange(start, start + n, dtype=np.int64)
    return make_batch(table_id, 0, ids, ids % bk_mod, ids + 100,
                      np.random.default_rng(0).normal(size=(n, 8)),
                      lsn_start=start)


def test_topic_partition_ordering_per_key():
    q = MessageQueue()
    q.create_topic(TopicConfig("t", 0, 4, "business_key"))
    q.publish("t", _batch(100))
    q.publish("t", _batch(100, start=100))
    seen = {}
    for p in range(4):
        b = q.consume("g", "t", p)
        q.commit("g", "t", p, len(b))
        for i in range(len(b)):
            key = int(b.business_key[i])
            lsn = int(b.lsn[i])
            assert seen.get((p, key), -1) < lsn  # per-partition key order
            seen[(p, key)] = lsn
    assert sum(1 for _ in seen) > 0
    assert q.lag("g", "t", 0) == 0


def test_compaction_snapshot_is_latest_per_key():
    q = MessageQueue()
    q.create_topic(TopicConfig("m", 0, 2, "row_key", compacted=True))
    ids = np.array([1, 2, 3, 1, 2], dtype=np.int64)
    payload = np.arange(5 * 8, dtype=np.float32).reshape(5, 8)
    q.publish("m", make_batch(0, 0, ids, ids, np.array([1, 1, 1, 9, 9]),
                              payload))
    rks, pls, tts = q.topics["m"].snapshot()
    by_key = dict(zip(rks.tolist(), tts.tolist()))
    assert by_key == {1: 9, 2: 9, 3: 1}       # latest txn wins


def test_consumer_group_offsets_independent():
    q = MessageQueue()
    q.create_topic(TopicConfig("t", 0, 1, "business_key"))
    q.publish("t", _batch(10))
    a = q.consume("a", "t", 0)
    q.commit("a", "t", 0, len(a))
    b = q.consume("b", "t", 0)
    assert len(a) == len(b) == 10             # group b unaffected by a


# ---------------------------------------------------------------- cache
@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=2**31 - 2),
                min_size=1, max_size=200, unique=True))
def test_cache_lookup_property(keys):
    """Property: every inserted key is found with its exact payload; absent
    keys are not found."""
    keys = np.array(keys, dtype=np.int64)
    tbl = InMemoryTable(max(512, 4 * len(keys)))
    payload = np.arange(len(keys) * 8, dtype=np.float32).reshape(-1, 8)
    tbl.upsert(keys, payload, np.arange(len(keys), dtype=np.int64))
    kt, vt, tt = tbl.device_state()
    vals, found, _ = lookup_ref(jnp.asarray(keys, jnp.int32), kt, vt, tt)
    assert bool(found.all())
    np.testing.assert_allclose(np.asarray(vals), payload)
    missing = jnp.asarray((keys[:5] + 1) % (2**31 - 1), jnp.int32)
    present = set((keys & 0xFFFFFFFF).tolist())
    _, found_m, _ = lookup_ref(missing, kt, vt, tt)
    for k, f in zip(np.asarray(missing), np.asarray(found_m)):
        if int(k) not in present:
            assert not f


def test_cache_upsert_overwrites():
    tbl = InMemoryTable(64)
    tbl.upsert(np.array([5]), np.ones((1, 8), np.float32),
               np.array([1], np.int64))
    tbl.upsert(np.array([5]), 2 * np.ones((1, 8), np.float32),
               np.array([2], np.int64))
    kt, vt, tt = tbl.device_state()
    vals, found, txn = lookup_ref(jnp.asarray([5], jnp.int32), kt, vt, tt)
    assert bool(found[0]) and float(vals[0, 0]) == 2.0
    assert tbl.n_rows == 1 and tbl.watermark == 2


def test_cache_reset_from_snapshot_and_dump_time():
    tbl = InMemoryTable(256)
    keys = np.arange(50, dtype=np.int64)
    tbl.upsert(keys, np.zeros((50, 8), np.float32),
               np.arange(50, dtype=np.int64))
    dump = tbl.reset_from_snapshot(keys[:10], np.ones((10, 8), np.float32),
                                   np.arange(10, dtype=np.int64))
    assert dump > 0 and tbl.n_rows == 10      # Fig. 4 overhead measured


# ---------------------------------------------------------------- buffer
def test_buffer_watermark_gating():
    buf = OperationalMessageBuffer(100)
    late = make_batch(0, 0, np.arange(10), np.arange(10),
                      np.arange(10) * 10, np.zeros((10, 8), np.float32))
    buf.push(late)
    ready = buf.pop_ready(45)                 # txn_times 0..90
    assert len(ready) == 5 and len(buf) == 5
    ready2 = buf.pop_ready(1000)
    assert len(ready2) == 5 and len(buf) == 0


def test_buffer_capacity_drop_accounting():
    buf = OperationalMessageBuffer(8)
    buf.push(make_batch(0, 0, np.arange(20), np.arange(20),
                        np.arange(20), np.zeros((20, 8), np.float32)))
    assert len(buf) == 8 and buf.dropped == 12


def test_buffer_export_restore_roundtrip():
    buf = OperationalMessageBuffer(50)
    buf.push(make_batch(0, 0, np.arange(7), np.arange(7),
                        np.arange(7), np.zeros((7, 8), np.float32)))
    st_ = buf.export_state()
    buf2 = OperationalMessageBuffer.restore(st_, 50)
    assert len(buf2) == 7


# ----------------------------------------------------------- partitioning
@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=64),
       st.lists(st.integers(min_value=0, max_value=10**9), min_size=1,
                max_size=500))
def test_partitioning_deterministic_and_in_range(n_parts, keys):
    keys = np.array(keys, dtype=np.int64)
    p1 = partition_of(keys, n_parts)
    p2 = partition_of(keys, n_parts)
    assert (p1 == p2).all()
    assert (p1 >= 0).all() and (p1 < n_parts).all()


def test_rebalance_covers_all_partitions():
    pa = PartitionAssignment(12, ["a", "b", "c"])
    assert sorted(sum((pa.partitions_of(w) for w in "abc"), [])) == \
        list(range(12))
    changed = pa.rebalance(["a", "c"])        # b died
    assert sorted(pa.partitions_of("a") + pa.partitions_of("c")) == \
        list(range(12))
    assert len(changed.get("a", [])) + len(changed.get("c", [])) > 0


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2, max_value=16))
def test_moe_position_assignment_capacity(n_experts):
    """The MoE slot assigner (shared discipline with the queue partitioner)
    never exceeds capacity and never double-books a slot."""
    import jax
    from repro.models.moe import assign_positions
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, n_experts, 128), jnp.int32)
    pos, keep = assign_positions(idx, n_experts, capacity=8)
    pos, keep, idx = map(np.asarray, (pos, keep, idx))
    assert (pos[keep] < 8).all()
    taken = set()
    for e, p, k in zip(idx, pos, keep):
        if k:
            assert (int(e), int(p)) not in taken
            taken.add((int(e), int(p)))
