"""BI serving layer tests: fused fold op parity, byte-identical
incremental-vs-recompute equivalence, snapshot isolation under concurrent
writers, epoch monotonicity across failover, and the warehouse
committed-view regression (readers never observe a partition mid-load)."""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.configs.dod_etl import steelworks_config
from repro.core import DODETLPipeline, SourceDatabase, StarSchemaWarehouse
from repro.core.backend import empty_fold_state, fold_width, get_backend
from repro.data.sampler import (SamplerConfig, SteelworksSampler,
                                synthetic_facts)
from repro.runtime.cluster import ConcurrentCluster
from repro.serving import (MaterializedViewEngine, ReportServer,
                           downtime_by_equipment, oee_by_equipment,
                           production_rate_windows, steelworks_views)

N_UNITS = 8


def rand_facts(rng, n, n_units=N_UNITS):
    return synthetic_facts(rng, n, n_units, valid_frac=0.85)


def build_cluster(n_workers, n_records, n_partitions=N_UNITS, serving=None):
    cfg = steelworks_config(n_partitions=n_partitions, backend="numpy")
    cfg = dataclasses.replace(cfg, buffer_capacity=4096)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n_records, n_equipment=n_partitions))
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers)
    cluster = ConcurrentCluster(pipe, serving=serving)
    return src, sampler, pipe, cluster


# ------------------------------------------------------------ fold op contract
def test_fold_segments_matches_per_segment_oracle():
    rng = np.random.default_rng(0)
    n, S, L = 777, 11, 3
    seg = rng.integers(-2, S + 2, n)         # includes out-of-range ids
    vals = rng.normal(scale=5, size=(n, L)).astype(np.float32)
    packed = get_backend("numpy").fold_segments(seg, vals, S)
    assert packed.shape == (S, fold_width(L))
    for s in range(S):
        m = (seg == s)
        assert packed[s, 0] == m.sum()                    # count exact
        if m.any():
            np.testing.assert_allclose(packed[s, 1:1 + L], vals[m].sum(0),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_array_equal(packed[s, 1 + L:1 + 2 * L],
                                          vals[m].min(0))   # min/max exact
            np.testing.assert_array_equal(packed[s, 1 + 2 * L:],
                                          vals[m].max(0))
        else:   # empty segment carries the fold identity
            assert (packed[s, 1 + L:1 + 2 * L] == np.inf).all()
            assert (packed[s, 1 + 2 * L:] == -np.inf).all()


def test_fold_segments_backend_parity():
    """numpy and jax fold the SAME halving tree -> bitwise identical;
    pallas uses the MXU one-hot matmul -> allclose (same contract as the
    other kernel ops)."""
    rng = np.random.default_rng(1)
    for n in (1, 9, 256, 5000):
        S, L = 13, 4
        seg = rng.integers(-1, S + 1, n)
        vals = rng.normal(scale=3, size=(n, L)).astype(np.float32)
        ref = get_backend("numpy").fold_segments(seg, vals, S)
        jx = get_backend("jax").fold_segments(seg, vals, S)
        assert ref.tobytes() == jx.tobytes()
        pl = get_backend("pallas").fold_segments(seg, vals, S)
        finite = np.isfinite(ref)
        np.testing.assert_array_equal(finite, np.isfinite(pl))
        np.testing.assert_allclose(pl[finite], ref[finite],
                                   rtol=1e-5, atol=1e-4)


def _uncompacted_fold(seg, vals, n_segments):
    """The pre-compaction reference driver: the halving tree over the FULL
    [block, n_segments, lanes] range, block-chained — reproduced here
    verbatim so compaction is checked against the exact old op order."""
    from repro.core.backend import (FOLD_BLOCK, _fold_tree_np, combine_fold,
                                    empty_fold_state)
    seg = np.asarray(seg, np.int64)
    vals = np.asarray(vals, np.float32)
    if vals.ndim == 1:
        vals = vals[:, None]
    n, L = vals.shape
    out = empty_fold_state(n_segments, L)
    for lo in range(0, n, FOLD_BLOCK):
        s = seg[lo:lo + FOLD_BLOCK]
        v = vals[lo:lo + FOLD_BLOCK]
        m = len(s)
        bucket = max(8, 1 << (m - 1).bit_length())
        if bucket != m:
            s = np.concatenate([s, np.full(bucket - m, -1, np.int64)])
            v = np.concatenate([v, np.zeros((bucket - m, L), np.float32)])
        out = combine_fold(out, _fold_tree_np(s, v, n_segments))
    return out


@pytest.mark.parametrize("case,make_seg", [
    ("empty", lambda rng, S: np.zeros(0, np.int64)),
    ("single_segment", lambda rng, S: np.full(700, S // 2, np.int64)),
    ("all_segments", lambda rng, S: np.arange(3 * S * 97) % S),
    ("out_of_range", lambda rng, S: np.array(
        [-7, -1, S, S + 3, 2 * S, 1, 1, S - 1], np.int64)),
    ("sparse", lambda rng, S: rng.choice(
        np.array([0, 3, S - 1], np.int64), 5000)),
    ("multi_block", lambda rng, S: rng.integers(-2, S + 2, 6000)),
])
def test_fold_compaction_bitwise_vs_uncompacted(case, make_seg):
    """Segment compaction must be INVISIBLE: on adversarial deltas the
    compacted fold (numpy AND jax) is byte-identical to the uncompacted
    halving tree it replaced."""
    rng = np.random.default_rng(17)
    S, L = 20, 3
    seg = make_seg(rng, S)
    vals = rng.normal(scale=5, size=(len(seg), L)).astype(np.float32)
    ref = _uncompacted_fold(seg, vals, S)
    for backend in ("numpy", "jax"):
        got = get_backend(backend).fold_segments(seg, vals, S)
        assert got.tobytes() == ref.tobytes(), (case, backend)


def test_empty_fold_state_is_identity():
    from repro.core.backend import combine_fold
    rng = np.random.default_rng(2)
    seg = rng.integers(0, 5, 100)
    vals = rng.normal(size=(100, 2)).astype(np.float32)
    agg = get_backend("numpy").fold_segments(seg, vals, 5)
    out = combine_fold(empty_fold_state(5, 2), agg)
    assert out.tobytes() == agg.tobytes()


# -------------------------------------------------- incremental == recompute
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_incremental_equals_rebuild_byte_identical(backend):
    """The equivalence property: view state after N random delta folds ==
    replaying the same chunk log from scratch, BYTE-identical — and the
    numpy and jax engines agree bitwise too."""
    rng = np.random.default_rng(3)
    specs = steelworks_views(N_UNITS)
    eng = MaterializedViewEngine(specs, backend=backend)
    deltas = [rand_facts(rng, int(n))
              for n in rng.integers(1, 900, 25)] + [rand_facts(rng, 1)]
    for d in deltas:
        eng.publish(d)
        if rng.random() < 0.5:         # fold in random batch sizes
            eng.fold_pending()
    eng.fold_pending()
    snap = eng.snapshot()
    assert snap.rows_folded == sum(len(d) for d in deltas)

    rebuilt = MaterializedViewEngine.rebuild(specs, deltas, backend=backend)
    for name, st in snap.states.items():
        assert st.table.tobytes() == rebuilt.states[name].table.tobytes()

    ref = MaterializedViewEngine.rebuild(specs, deltas, backend="numpy")
    for name, st in snap.states.items():
        assert st.table.tobytes() == ref.states[name].table.tobytes()


def test_view_queries_match_full_rescan():
    """Acceptance parity: incremental kpi_rollup / query_oee answers are
    numerically identical to the warehouse's full-rescan path (counts
    exact, means to float tolerance)."""
    cfg = steelworks_config(n_partitions=N_UNITS, backend="numpy")
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=2000, n_equipment=N_UNITS)).generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=2)
    engine = pipe.warehouse.attach_serving(
        MaterializedViewEngine(steelworks_views(N_UNITS), backend="numpy"))
    pipe.extract()
    pipe.bootstrap_caches()
    pipe.run_to_completion()
    engine.fold_pending()
    server = ReportServer(engine)

    view_roll = server.kpi_rollup()
    scan_roll = pipe.warehouse.kpi_rollup(N_UNITS, backend="numpy")
    np.testing.assert_array_equal(view_roll[:, 4], scan_roll[:, 4])
    np.testing.assert_allclose(view_roll, scan_roll, rtol=1e-4, atol=1e-4)

    for unit in list(range(N_UNITS)) + [None]:
        r = server.oee(unit)
        q = pipe.warehouse.query_oee(unit)
        assert r.data["rows"] == q["rows"]
        for k in ("availability", "performance", "quality", "oee"):
            np.testing.assert_allclose(r.data[k], q[k], rtol=1e-4)


def test_attach_serving_replays_history():
    """Views attached AFTER loads cover the committed history too."""
    rng = np.random.default_rng(4)
    wh = StarSchemaWarehouse()
    for _ in range(5):
        wh.load_partitioned(rand_facts(rng, 200), N_UNITS)
    engine = wh.attach_serving(
        MaterializedViewEngine([oee_by_equipment(N_UNITS)],
                               backend="numpy"))
    wh.load_partitioned(rand_facts(rng, 100), N_UNITS)
    engine.fold_pending()
    snap = engine.snapshot()
    t = wh.fact_table()
    valid = t[:, 9] > 0.5
    assert snap.view("oee_by_equipment").count.sum() == valid.sum()


# ------------------------------------------------------------ isolation/epochs
def test_snapshot_isolation_under_concurrent_writer():
    """Readers pin epochs while a writer thread keeps folding: pinned
    state never changes (isolation), epochs only grow (monotonicity),
    published tables are frozen."""
    rng = np.random.default_rng(5)
    engine = MaterializedViewEngine(steelworks_views(N_UNITS),
                                    backend="numpy")
    engine.start()
    stop = threading.Event()

    def writer():
        wrng = np.random.default_rng(6)
        while not stop.is_set():
            engine.publish(rand_facts(wrng, 64))
            time.sleep(0.001)

    t = threading.Thread(target=writer)
    t.start()
    try:
        pinned, last_epoch = [], -1
        deadline = time.time() + 5.0
        while len(pinned) < 8 and time.time() < deadline:
            snap = engine.snapshot()
            assert snap.epoch >= last_epoch
            last_epoch = snap.epoch
            if snap.epoch > (pinned[-1][0].epoch if pinned else -1):
                pinned.append((snap, {n: s.table.tobytes()
                                      for n, s in snap.states.items()}))
            time.sleep(0.01)
        assert len(pinned) >= 3          # the writer made progress
    finally:
        stop.set()
        t.join()
        engine.stop()
    for snap, frozen in pinned:          # pinned epochs never moved
        for name, st in snap.states.items():
            assert not st.table.flags.writeable
            assert st.table.tobytes() == frozen[name]
    counts = [s.view("oee_by_equipment").count.sum() for s, _ in pinned]
    assert all(b >= a for a, b in zip(counts, counts[1:]))


def test_live_cluster_queries_consistent_epochs():
    """Queries issued while ConcurrentCluster workers load: every pinned
    snapshot is internally consistent (all views cover the same delta
    prefix — equal valid-row counts), epochs are monotonic, and the final
    state byte-matches the recompute oracle."""
    n = 3000
    engine = MaterializedViewEngine(steelworks_views(N_UNITS),
                                    backend="numpy")
    server = ReportServer(engine)
    src, sampler, pipe, cluster = build_cluster(3, n, serving=engine)
    cluster.start()
    feeder = threading.Thread(target=lambda: sampler.generate(src))
    feeder.start()
    last_epoch = -1
    for _ in range(40):
        snap = server.snapshot()
        assert snap.epoch >= last_epoch
        last_epoch = snap.epoch
        per_view = {name: st.count.sum()
                    for name, st in snap.snap.states.items()}
        assert len(set(per_view.values())) == 1, f"torn epoch: {per_view}"
        time.sleep(0.005)
    feeder.join()
    done = cluster.run_until_idle(timeout=90)
    rep = cluster.report()
    cluster.stop_all()
    assert done == n

    snap = engine.snapshot()
    assert snap.rows_folded == n
    rebuilt = MaterializedViewEngine.rebuild(
        steelworks_views(N_UNITS), pipe.warehouse.read_view().chunks,
        backend="numpy")
    for name, st in snap.states.items():
        assert st.table.tobytes() == rebuilt.states[name].table.tobytes()
    # staleness recorded per record, on the same clock as load freshness
    assert rep["serving"]["staleness_n"] == n
    assert rep["serving"]["staleness_p50_ms"] > 0
    assert (rep["serving"]["staleness_p50_ms"]
            <= rep["serving"]["staleness_p95_ms"])
    # visibility always lags the load that produced it
    assert rep["serving"]["staleness_p95_ms"] >= rep["p50_ms"]


def test_epoch_monotonic_across_failover():
    """§4.1.3 drill with the serving stage attached: killing workers
    mid-run never regresses the epoch, and the post-failover state still
    byte-matches the recompute oracle (no lost or doubled deltas)."""
    n = 4000
    engine = MaterializedViewEngine(steelworks_views(N_UNITS),
                                    backend="numpy")
    src, sampler, pipe, cluster = build_cluster(4, n, n_partitions=8,
                                                serving=engine)
    cluster.start()
    feeder = threading.Thread(target=lambda: sampler.generate(src))
    feeder.start()
    epochs = [engine.snapshot().epoch]
    time.sleep(0.15)
    cluster.fail_workers(["w1", "w2"])
    epochs.append(engine.snapshot().epoch)
    feeder.join()
    done = cluster.run_until_idle(timeout=90)
    epochs.append(engine.snapshot().epoch)
    cluster.stop_all()
    epochs.append(engine.snapshot().epoch)
    assert done == n
    assert epochs == sorted(epochs)
    snap = engine.snapshot()
    assert snap.rows_folded == n
    rebuilt = MaterializedViewEngine.rebuild(
        steelworks_views(N_UNITS), pipe.warehouse.read_view().chunks,
        backend="numpy")
    for name, st in snap.states.items():
        assert st.table.tobytes() == rebuilt.states[name].table.tobytes()


# ------------------------------------------------------------- view semantics
def test_topn_downtime_and_window_reports():
    facts = np.zeros((6, 10), np.float32)
    facts[:, 0] = [0, 0, 1, 2, 2, 2]
    facts[:, 1] = [0, 100, 2100, 4100, 4200, 100]
    facts[:, 6] = [.5, .7, .2, .9, .4, .6]      # oee
    facts[:, 7] = [1, 1, 2, 3, 3, 3]            # uptime
    facts[:, 8] = [5, 5, 30, 1, 1, 1]           # downtime
    facts[:, 9] = 1.0
    facts[5, 9] = 0.0                           # invalid: must be ignored
    engine = MaterializedViewEngine(
        [downtime_by_equipment(3), production_rate_windows(
            n_windows=4, window_len=2000.0)], backend="numpy")
    engine.publish(facts)
    engine.fold_pending()
    server = ReportServer(engine)

    top = server.top_downtime(2)
    np.testing.assert_array_equal(top.data["unit"], [1, 0])
    np.testing.assert_allclose(top.data["downtime_s"], [30.0, 10.0])
    assert top.epoch == 1

    rate = server.production_rate()
    np.testing.assert_array_equal(rate.data["facts"], [2, 1, 2, 0])
    np.testing.assert_allclose(rate.data["oee_min"][0], 0.5)
    np.testing.assert_allclose(rate.data["oee_max"][0], 0.7)
    np.testing.assert_allclose(rate.data["oee_min"][2], 0.4)
    assert np.isinf(rate.data["oee_min"][3])    # empty window: identity


# ----------------------------------------- warehouse committed-view regression
def test_warehouse_read_view_consistent_under_concurrent_loads():
    """Regression for the ad-hoc read/write race: a pinned ``read_view``
    is immune to concurrent ``load_partitioned`` calls — every aggregate
    computed from one view is stable and mutually consistent, and
    successive views only grow."""
    wh = StarSchemaWarehouse()
    stop = threading.Event()

    def writer():
        wrng = np.random.default_rng(7)
        while not stop.is_set():
            wh.load_partitioned(rand_facts(wrng, 128), N_UNITS)
            time.sleep(0.001)            # keep the fact table test-sized

    t = threading.Thread(target=writer)
    t.start()
    try:
        prev_rows = -1
        checked = 0
        deadline = time.time() + 10.0
        while checked < 10 and time.time() < deadline:
            view = wh.read_view()
            assert view.rows >= prev_rows
            prev_rows = view.rows
            if not view.rows:
                continue
            t1 = wh.fact_table(view)
            roll1 = wh.kpi_rollup(N_UNITS, backend="numpy", view=view)
            time.sleep(0.002)            # let loads land in between
            t2 = wh.fact_table(view)
            roll2 = wh.kpi_rollup(N_UNITS, backend="numpy", view=view)
            assert len(t1) == view.rows
            assert t1.tobytes() == t2.tobytes()
            assert roll1.tobytes() == roll2.tobytes()
            # a multi-query report over ONE view is internally consistent
            rows = sum(wh.query_oee(u, view=view)["rows"]
                       for u in range(N_UNITS)
                       if wh.query_oee(u, view=view)["rows"] > 0)
            assert rows == view.rows
            checked += 1
    finally:
        stop.set()
        t.join()
    assert checked >= 10
