"""Training substrate: loss goes down, checkpoint manager, compression,
gla chunk-vs-step property, distribution helpers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.models import build_model
from repro.optim import AdamWConfig, init_state, schedule
from repro.train.checkpoint import CheckpointManager, restore, save
from repro.train.compression import compress_int8, decompress_int8
from repro.train.train_step import make_train_step


def test_loss_decreases_on_tiny_model():
    m = build_model("internlm2-1.8b", smoke=True)
    params = m.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    step = jax.jit(make_train_step(
        m, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (4, 64), 0, m.cfg.vocab)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    losses = []
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, jnp.asarray(5))) < 1e-3
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(schedule(cfg, jnp.asarray(100))) < 2e-4


def test_checkpoint_roundtrip_and_corruption(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    path = os.path.join(tmp_path, "ck")
    save(path, 7, tree, extra={"offsets": {"t": 3}})
    step, restored, extra = restore(path, tree)
    assert step == 7 and extra["offsets"]["t"] == 3
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.arange(10, dtype=np.float32))
    # corruption detection
    import numpy as _np
    data = dict(_np.load(os.path.join(path, "leaves.npz")))
    data["leaf_0"] = data["leaf_0"] + 1
    with open(os.path.join(path, "leaves.npz"), "wb") as f:
        _np.savez(f, **data)
    with pytest.raises(IOError):
        restore(path, tree)


def test_checkpoint_manager_async_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"w": jnp.zeros((4,), jnp.float32)}
    for s in (1, 2, 3):
        mgr.save_async(s, jax.tree.map(lambda x: x + s, tree))
    mgr.wait()
    got = mgr.restore_latest(tree)
    assert got is not None and got[0] == 3
    dirs = sorted(os.listdir(tmp_path))
    assert "step_1" not in dirs and "step_3" in dirs


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_int8_error_feedback_unbiased_over_time(seed):
    """EF property: accumulated dequantized updates converge to the true
    accumulated gradient (residual stays bounded)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    res = jnp.zeros_like(g)
    total_sent = jnp.zeros_like(g)
    for _ in range(20):
        q, s, res = compress_int8(g, res)
        total_sent = total_sent + decompress_int8(q, s)
    # after N rounds, sent ~= N*g with bounded residual
    np.testing.assert_allclose(np.asarray(total_sent + res),
                               np.asarray(20 * g), rtol=1e-4, atol=1e-4)
    assert float(jnp.abs(res).max()) <= float(jnp.abs(g).max())


def test_grad_accumulation_invariance():
    """1 microbatch of 4 == 4 microbatches of 1 (same total batch)."""
    import dataclasses
    m1 = build_model("internlm2-1.8b", smoke=True)
    cfg4 = dataclasses.replace(m1.cfg, microbatches=4)
    from repro.models.model import Model
    m4 = Model(cfg4)
    params = m1.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (4, 64), 0, m1.cfg.vocab)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    ocfg = AdamWConfig(warmup_steps=1, total_steps=10)
    p1, _, met1 = jax.jit(make_train_step(m1, ocfg))(params, opt, batch)
    p4, _, met4 = jax.jit(make_train_step(m4, ocfg))(params, opt, batch)
    assert abs(float(met1["loss"]) - float(met4["loss"])) < 0.02
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.08, atol=0.02)


def test_hlo_analyzer_on_known_program():
    """The trip-count-corrected analyzer counts scan FLOPs exactly."""
    from repro.launch.hlo_analysis import analyze_hlo
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    a = analyze_hlo(txt)
    expected = 7 * 2 * 64 * 128 * 128
    assert abs(a["flops"] - expected) / expected < 0.05, a["flops"]
