"""Compute-backend layer tests: numpy/jax/pallas parity on every hot op,
single-dispatch coalescing, and rebalance offset handoff (paper §3.2)."""
import numpy as np
import pytest

from repro.configs.dod_etl import steelworks_config
from repro.core import (DODETLPipeline, MessageQueue, RecordBatch,
                        SourceDatabase, TopicConfig, get_backend, make_batch)
from repro.core.backend import available_backends
from repro.core.cache import InMemoryTable
from repro.data.sampler import SamplerConfig, SteelworksSampler

BACKENDS = ("numpy", "jax", "pallas")


def _pipeline(backend, n_records=300, n_workers=2, n_partitions=4,
              late_frac=0.1, seed=0):
    cfg = steelworks_config(n_partitions=n_partitions, backend=backend)
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n_records, n_equipment=n_partitions,
        late_master_frac=late_frac, seed=seed)).generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers)
    pipe.extract()
    pipe.bootstrap_caches()
    return pipe


def _sorted_facts(pipe):
    t = pipe.warehouse.fact_table()
    return t[np.lexsort((t[:, 1], t[:, 0]))]


def test_backends_registered():
    assert set(BACKENDS) <= set(available_backends())
    for name in BACKENDS:
        assert get_backend(name).name == name
        assert get_backend(name) is get_backend(name)   # singleton


def test_backend_selection_config_env_and_default(monkeypatch):
    monkeypatch.delenv("DODETL_BACKEND", raising=False)
    assert get_backend(None).name == "jax"
    monkeypatch.setenv("DODETL_BACKEND", "numpy")
    assert get_backend(None).name == "numpy"
    assert get_backend("pallas").name == "pallas"       # explicit wins
    cfg = steelworks_config(n_partitions=2, backend="numpy")
    src = SourceDatabase()
    pipe = DODETLPipeline(cfg, src, n_workers=1)
    assert pipe.backend.name == "numpy"
    assert pipe.workers[0].transformer.backend.name == "numpy"


def test_hash_probe_parity():
    rng = np.random.default_rng(3)
    tbl = InMemoryTable(512)
    keys = rng.choice(10**6, 200, replace=False).astype(np.int64)
    payload = rng.normal(size=(200, 8)).astype(np.float32)
    tbl.upsert(keys, payload, np.arange(200, dtype=np.int64))
    queries = np.concatenate([keys[:50], keys[:50] + 10**7])  # hits + misses
    outs = {}
    for name in BACKENDS:
        be = get_backend(name)
        state = (tbl.device_state() if be.device
                 else (tbl.keys, tbl.values, tbl.txn))
        outs[name] = be.hash_probe(queries, *state)
    ref_vals, ref_found, _ = outs["numpy"]
    assert ref_found[:50].all() and not ref_found[50:].any()
    for name in ("jax", "pallas"):
        vals, found, _ = outs[name]
        np.testing.assert_array_equal(found, ref_found)
        np.testing.assert_allclose(vals[found], ref_vals[ref_found],
                                   atol=1e-5)


def test_segment_reduce_parity():
    rng = np.random.default_rng(5)
    n, n_units = 333, 8
    facts = np.zeros((n, 10), np.float32)
    facts[:, 0] = rng.integers(0, n_units, n)
    facts[:10, 0] = n_units + 3       # out-of-range units: dropped, not a crash
    facts[:, 3:7] = rng.uniform(0, 1, (n, 4)).astype(np.float32)
    facts[:, 9] = (rng.random(n) > 0.2).astype(np.float32)
    ref = get_backend("numpy").segment_reduce(facts, n_units)
    in_range = facts[:, 0] < n_units
    assert ref[:, 4].sum() == ((facts[:, 9] > 0.5) & in_range).sum()
    for name in ("jax", "pallas"):
        agg = get_backend(name).segment_reduce(facts, n_units)
        np.testing.assert_allclose(agg, ref, rtol=1e-5, atol=1e-5)


def test_backend_parity_end_to_end():
    """The tentpole guarantee: the SAME seeded workload through every
    backend produces identical facts (technology-independence, §3.3)."""
    tables = {}
    for name in BACKENDS:
        pipe = _pipeline(name)
        pipe.run_to_completion()
        assert pipe.warehouse.rows_loaded == 300
        assert all(len(w.buffer) == 0 for w in pipe.workers)
        tables[name] = _sorted_facts(pipe)
    for name in ("jax", "pallas"):
        np.testing.assert_allclose(tables[name], tables["numpy"],
                                   rtol=1e-5, atol=1e-5)


def test_consume_many_matches_per_partition_reads():
    q = MessageQueue()
    q.create_topic(TopicConfig("t", 0, 4, "business_key"))
    ids = np.arange(100, dtype=np.int64)
    q.publish("t", make_batch(0, 0, ids, ids % 7, ids + 100,
                              np.zeros((100, 8), np.float32)))
    singles = [q.consume("a", "t", p) for p in range(4)]
    coalesced, counts = q.consume_many("b", "t", range(4))
    assert len(coalesced) == sum(len(s) for s in singles) == 100
    assert counts == {p: len(s) for p, s in enumerate(singles) if len(s)}
    np.testing.assert_array_equal(
        np.sort(coalesced.row_key),
        np.sort(np.concatenate([s.row_key for s in singles])))
    # committing per partition after a coalesced read drains the topic
    for p, c in counts.items():
        q.commit("b", "t", p, c)
    again, counts2 = q.consume_many("b", "t", range(4))
    assert len(again) == 0 and counts2 == {}


def test_split_by_partition_roundtrip():
    ids = np.arange(57, dtype=np.int64)
    batch = make_batch(0, 0, ids, ids % 11, ids, np.zeros((57, 8), np.float32))
    parts = batch.split_by_partition(4)
    assert sum(len(b) for _, b in parts) == 57
    merged = RecordBatch.concat([b for _, b in parts])
    np.testing.assert_array_equal(np.sort(merged.row_key), ids)


def test_buffer_drain():
    from repro.core import OperationalMessageBuffer
    buf = OperationalMessageBuffer(64)
    buf.push(make_batch(0, 0, np.arange(9), np.arange(9), np.arange(9),
                        np.zeros((9, 8), np.float32)))
    drained = buf.drain()
    assert len(drained) == 9 and len(buf) == 0
    assert len(buf.drain()) == 0


def test_rebalance_offset_handoff_loses_nothing():
    """Committed offsets transfer to the new owners across BOTH a failure
    and an elastic scale-up; every record lands exactly once."""
    pipe = _pipeline("jax", n_records=900, n_workers=3, n_partitions=6)
    pipe.step(max_records_per_partition=40)        # partial progress
    mid = pipe.warehouse.rows_loaded
    assert 0 < mid < 900
    pipe.fail_workers(["w1"])
    pipe.step(max_records_per_partition=40)
    pipe.add_workers(2)
    pipe.run_to_completion()
    assert pipe.warehouse.rows_loaded == 900       # no loss, no duplicates
    assert all(len(w.buffer) == 0 for w in pipe.workers)
    # oracle: unperturbed single-worker run over the same seeded workload
    oracle = _pipeline("jax", n_records=900, n_workers=1, n_partitions=6)
    oracle.run_to_completion()
    np.testing.assert_allclose(_sorted_facts(pipe), _sorted_facts(oracle),
                               rtol=1e-5, atol=1e-5)


def test_single_dispatch_per_worker_per_step():
    """The tentpole refactor's invariant: one transform dispatch per worker
    per step, no matter how many partitions the worker owns."""
    pipe = _pipeline("jax", n_records=400, n_workers=2, n_partitions=8)
    before = {w.name: w.transformer.dispatches for w in pipe.workers}
    pipe.step(max_records_per_partition=50)
    for w in pipe.workers:
        assert len(w.partitions) == 4
        assert w.transformer.dispatches == before[w.name] + 1


def test_kpi_rollup_matches_query_oee():
    pipe = _pipeline("jax", n_records=400, n_workers=2, n_partitions=4)
    pipe.run_to_completion()
    agg = pipe.warehouse.kpi_rollup(4, backend="numpy")
    for unit in range(4):
        q = pipe.warehouse.query_oee(unit)
        if np.isnan(q["oee"]):
            assert agg[unit, 4] == 0
            continue
        assert agg[unit, 4] == q["rows"]
        np.testing.assert_allclose(agg[unit, 3] / agg[unit, 4], q["oee"],
                                   rtol=1e-5)
