"""Compute-backend layer tests: numpy/jax/pallas parity on every hot op,
single-dispatch coalescing, the device-resident FactBlock plane
(transform_and_rollup = one dispatch, zero syncs before load), and
rebalance offset handoff (paper §3.2)."""
import numpy as np
import pytest

from repro.configs.dod_etl import steelworks_config
from repro.core import (DODETLPipeline, MessageQueue, RecordBatch,
                        SourceDatabase, TopicConfig, get_backend, make_batch)
from repro.core.backend import (ComputeBackend, available_backends,
                                _segment_reduce_np)
from repro.core.cache import InMemoryTable
from repro.data.sampler import SamplerConfig, SteelworksSampler

BACKENDS = ("numpy", "jax", "pallas")


def _master_tables(rng, n_units=8, n_prod=300):
    """Populated equipment/quality caches + production payloads with a mix
    of hits and misses, for direct backend-op tests."""
    eq = InMemoryTable(256)
    eqp = np.zeros((n_units, 8), np.float32)
    eqp[:, 1] = np.arange(n_units)
    eqp[:, 4] = 100.0
    eqp[:, 5] = (rng.random(n_units) > 0.3).astype(np.float32)
    eqp[:, 6] = 5.0 + rng.random(n_units).astype(np.float32)
    eqp[:, 7] = 50.0
    eq.upsert(np.arange(n_units), eqp, np.arange(n_units, dtype=np.int64))
    qu = InMemoryTable(1024)
    qp = np.zeros((n_prod, 8), np.float32)
    qp[:, 3] = np.arange(n_prod)
    qp[:, 4] = rng.integers(0, 3, n_prod)
    qp[:, 6] = rng.integers(0, 2, n_prod)
    qu.upsert(np.arange(n_prod), qp, np.arange(n_prod, dtype=np.int64))
    return eq, qu


def _prod_payloads(rng, n, n_units=8, n_prod=300):
    prod = np.zeros((n, 8), np.float32)
    prod[:, 0] = rng.integers(0, n_prod, n)
    prod[:, 1] = rng.integers(0, n_units + 2, n)     # some join misses
    prod[:, 3] = rng.uniform(0, 50, n)
    prod[:, 4] = prod[:, 3] + rng.uniform(1, 30, n)
    prod[:, 5] = rng.uniform(1, 100, n)
    return prod


def _pipeline(backend, n_records=300, n_workers=2, n_partitions=4,
              late_frac=0.1, seed=0):
    cfg = steelworks_config(n_partitions=n_partitions, backend=backend)
    src = SourceDatabase()
    SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n_records, n_equipment=n_partitions,
        late_master_frac=late_frac, seed=seed)).generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers)
    pipe.extract()
    pipe.bootstrap_caches()
    return pipe


def _sorted_facts(pipe):
    t = pipe.warehouse.fact_table()
    return t[np.lexsort((t[:, 1], t[:, 0]))]


def test_backends_registered():
    assert set(BACKENDS) <= set(available_backends())
    for name in BACKENDS:
        assert get_backend(name).name == name
        assert get_backend(name) is get_backend(name)   # singleton


def test_backend_selection_config_env_and_default(monkeypatch):
    monkeypatch.delenv("DODETL_BACKEND", raising=False)
    assert get_backend(None).name == "jax"
    monkeypatch.setenv("DODETL_BACKEND", "numpy")
    assert get_backend(None).name == "numpy"
    assert get_backend("pallas").name == "pallas"       # explicit wins
    cfg = steelworks_config(n_partitions=2, backend="numpy")
    src = SourceDatabase()
    pipe = DODETLPipeline(cfg, src, n_workers=1)
    assert pipe.backend.name == "numpy"
    assert pipe.workers[0].transformer.backend.name == "numpy"


def test_hash_probe_parity():
    rng = np.random.default_rng(3)
    tbl = InMemoryTable(512)
    keys = rng.choice(10**6, 200, replace=False).astype(np.int64)
    payload = rng.normal(size=(200, 8)).astype(np.float32)
    tbl.upsert(keys, payload, np.arange(200, dtype=np.int64))
    queries = np.concatenate([keys[:50], keys[:50] + 10**7])  # hits + misses
    outs = {}
    for name in BACKENDS:
        be = get_backend(name)
        state = (tbl.device_state() if be.device
                 else (tbl.keys, tbl.values, tbl.txn))
        outs[name] = be.hash_probe(queries, *state)
    ref_vals, ref_found, _ = outs["numpy"]
    assert ref_found[:50].all() and not ref_found[50:].any()
    for name in ("jax", "pallas"):
        vals, found, _ = outs[name]
        np.testing.assert_array_equal(found, ref_found)
        np.testing.assert_allclose(vals[found], ref_vals[ref_found],
                                   atol=1e-5)


def test_segment_reduce_parity():
    rng = np.random.default_rng(5)
    n, n_units = 333, 8
    facts = np.zeros((n, 10), np.float32)
    facts[:, 0] = rng.integers(0, n_units, n)
    facts[:10, 0] = n_units + 3       # out-of-range units: dropped, not a crash
    facts[:, 3:7] = rng.uniform(0, 1, (n, 4)).astype(np.float32)
    facts[:, 9] = (rng.random(n) > 0.2).astype(np.float32)
    ref = get_backend("numpy").segment_reduce(facts, n_units)
    in_range = facts[:, 0] < n_units
    assert ref[:, 4].sum() == ((facts[:, 9] > 0.5) & in_range).sum()
    for name in ("jax", "pallas"):
        agg = get_backend(name).segment_reduce(facts, n_units)
        np.testing.assert_allclose(agg, ref, rtol=1e-5, atol=1e-5)


def test_backend_parity_end_to_end():
    """The tentpole guarantee: the SAME seeded workload through every
    backend produces identical facts (technology-independence, §3.3)."""
    tables = {}
    for name in BACKENDS:
        pipe = _pipeline(name)
        pipe.run_to_completion()
        assert pipe.warehouse.rows_loaded == 300
        assert all(len(w.buffer) == 0 for w in pipe.workers)
        tables[name] = _sorted_facts(pipe)
    for name in ("jax", "pallas"):
        np.testing.assert_allclose(tables[name], tables["numpy"],
                                   rtol=1e-5, atol=1e-5)


def test_consume_many_matches_per_partition_reads():
    q = MessageQueue()
    q.create_topic(TopicConfig("t", 0, 4, "business_key"))
    ids = np.arange(100, dtype=np.int64)
    q.publish("t", make_batch(0, 0, ids, ids % 7, ids + 100,
                              np.zeros((100, 8), np.float32)))
    singles = [q.consume("a", "t", p) for p in range(4)]
    coalesced, counts = q.consume_many("b", "t", range(4))
    assert len(coalesced) == sum(len(s) for s in singles) == 100
    assert counts == {p: len(s) for p, s in enumerate(singles) if len(s)}
    np.testing.assert_array_equal(
        np.sort(coalesced.row_key),
        np.sort(np.concatenate([s.row_key for s in singles])))
    # committing per partition after a coalesced read drains the topic
    for p, c in counts.items():
        q.commit("b", "t", p, c)
    again, counts2 = q.consume_many("b", "t", range(4))
    assert len(again) == 0 and counts2 == {}


def test_split_by_partition_roundtrip():
    ids = np.arange(57, dtype=np.int64)
    batch = make_batch(0, 0, ids, ids % 11, ids, np.zeros((57, 8), np.float32))
    parts = batch.split_by_partition(4)
    assert sum(len(b) for _, b in parts) == 57
    merged = RecordBatch.concat([b for _, b in parts])
    np.testing.assert_array_equal(np.sort(merged.row_key), ids)


def test_buffer_drain():
    from repro.core import OperationalMessageBuffer
    buf = OperationalMessageBuffer(64)
    buf.push(make_batch(0, 0, np.arange(9), np.arange(9), np.arange(9),
                        np.zeros((9, 8), np.float32)))
    drained = buf.drain()
    assert len(drained) == 9 and len(buf) == 0
    assert len(buf.drain()) == 0


def test_rebalance_offset_handoff_loses_nothing():
    """Committed offsets transfer to the new owners across BOTH a failure
    and an elastic scale-up; every record lands exactly once."""
    pipe = _pipeline("jax", n_records=900, n_workers=3, n_partitions=6)
    pipe.step(max_records_per_partition=40)        # partial progress
    mid = pipe.warehouse.rows_loaded
    assert 0 < mid < 900
    pipe.fail_workers(["w1"])
    pipe.step(max_records_per_partition=40)
    pipe.add_workers(2)
    pipe.run_to_completion()
    assert pipe.warehouse.rows_loaded == 900       # no loss, no duplicates
    assert all(len(w.buffer) == 0 for w in pipe.workers)
    # oracle: unperturbed single-worker run over the same seeded workload
    oracle = _pipeline("jax", n_records=900, n_workers=1, n_partitions=6)
    oracle.run_to_completion()
    np.testing.assert_allclose(_sorted_facts(pipe), _sorted_facts(oracle),
                               rtol=1e-5, atol=1e-5)


def test_single_dispatch_per_worker_per_step():
    """The tentpole refactor's invariant: one transform dispatch per worker
    per step, no matter how many partitions the worker owns."""
    pipe = _pipeline("jax", n_records=400, n_workers=2, n_partitions=8)
    before = {w.name: w.transformer.dispatches for w in pipe.workers}
    pipe.step(max_records_per_partition=50)
    for w in pipe.workers:
        assert len(w.partitions) == 4
        assert w.transformer.dispatches == before[w.name] + 1


# ---------------------------------------------------- device-resident plane
def test_factblock_transform_and_rollup_parity():
    """The fused op's contract on every backend: the block's facts/found
    equal the plain transform's, and the fused rollup equals the
    segment_reduce oracle over the block's valid facts."""
    rng = np.random.default_rng(11)
    eq, qu = _master_tables(rng)
    prod = _prod_payloads(rng, 137)
    ref_facts, ref_found = get_backend("numpy").transform(prod, eq, qu)
    ref_roll = _segment_reduce_np(ref_facts[ref_found], 8)
    assert ref_found.any() and not ref_found.all()
    for name in BACKENDS:
        be = get_backend(name)
        block = be.transform_and_rollup(prod, eq, qu, n_units=8)
        assert len(block) == 137
        facts, found = block.to_host()
        np.testing.assert_array_equal(found, ref_found)
        np.testing.assert_allclose(facts, ref_facts, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(block.rollup_host(), ref_roll,
                                   rtol=1e-5, atol=1e-4)
        # materialization is cached: repeat calls return the same arrays
        again_facts, again_found = block.to_host()
        assert again_facts is facts and again_found is found


def test_factblock_dispatch_and_sync_counters():
    """The tentpole invariant, counted: on the jax backend a fused
    transform+rollup is ONE device dispatch and ZERO host syncs until the
    load boundary materializes the block (exactly one sync, cached after
    that). Device backends stay sync-free before to_host."""
    rng = np.random.default_rng(12)
    eq, qu = _master_tables(rng)
    prod = _prod_payloads(rng, 200)
    jx = get_backend("jax")
    jx.transform_and_rollup(prod, eq, qu, n_units=8)     # warm the jit
    jx.reset_stats()
    block = jx.transform_and_rollup(prod, eq, qu, n_units=8)
    assert jx.op_dispatches == 1 and jx.host_syncs == 0
    block.start_host_copy()                  # async D2H: still no sync
    assert jx.host_syncs == 0
    block.to_host()
    block.rollup_host()
    assert jx.host_syncs == 1                # the load boundary's one sync
    block.to_host()
    assert jx.host_syncs == 1                # cached, no second round trip
    for name in ("numpy", "pallas"):
        be = get_backend(name)
        be.reset_stats()
        b = be.transform_and_rollup(prod, eq, qu, n_units=8)
        assert be.host_syncs == 0            # device-resident until load
        assert be.op_dispatches >= 1
        b.to_host()
        assert be.host_syncs == (1 if be.device else 0)


def test_worker_step_single_round_trip():
    """End-to-end counter check through the real worker step: one fused
    dispatch and one host sync per process_operational step on jax."""
    pipe = _pipeline("jax", n_records=200, n_workers=1, n_partitions=4)
    pipe.step(max_records_per_partition=25)              # warm the buckets
    be = pipe.backend
    be.reset_stats()
    done = pipe.step(max_records_per_partition=25)
    assert done > 0
    assert be.op_dispatches == 1 and be.host_syncs == 1


def test_cache_snapshot_lookup_all_backends():
    """Regression: CacheSnapshot.__slots__ omitted ``_backend`` and
    __init__ never assigned it, so ``snapshot.backend`` / ``lookup()``
    raised AttributeError on first use. Exercise the full lookup path on
    every backend."""
    rng = np.random.default_rng(13)
    for name in BACKENDS:
        be = get_backend(name)
        tbl = InMemoryTable(512, backend=name)
        keys = rng.choice(10**6, 100, replace=False).astype(np.int64)
        payload = rng.normal(size=(100, 8)).astype(np.float32)
        tbl.upsert(keys, payload, np.arange(100, dtype=np.int64))
        snap = tbl.snapshot_view(be.device)
        assert snap.backend.name == name
        queries = np.concatenate([keys[:30], keys[:10] + 10**7])
        vals, found, txn = snap.lookup(queries)
        assert found[:30].all() and not found[30:].any()
        np.testing.assert_allclose(vals[:30], payload[:30], atol=1e-5)
        np.testing.assert_array_equal(txn[:30], np.arange(30))


def test_pad_bucket_mutable_never_aliases():
    """Regression: a power-of-two-sized input came back aliased and
    PallasBackend.segment_reduce's pad-marking write scribbled on the
    caller's facts."""
    x = np.arange(32, dtype=np.float32).reshape(8, 4)
    padded = ComputeBackend._pad_bucket(x, floor=8, mutable=True)
    assert padded is not x and not np.shares_memory(padded, x)
    np.testing.assert_array_equal(padded, x)
    # read-only fast path may alias (documented), padding never does
    grown = ComputeBackend._pad_bucket(x, floor=16, mutable=False)
    assert not np.shares_memory(grown, x) and len(grown) == 16


def test_pallas_segment_reduce_does_not_mutate_input():
    rng = np.random.default_rng(14)
    n = 256                                  # exactly one pallas bucket
    facts = np.zeros((n, 10), np.float32)
    facts[:, 0] = rng.integers(0, 4, n)
    facts[:, 3:7] = rng.uniform(0, 1, (n, 4)).astype(np.float32)
    facts[:, 9] = 1.0
    before = facts.tobytes()
    agg = get_backend("pallas").segment_reduce(facts, 4)
    assert facts.tobytes() == before         # input untouched
    np.testing.assert_allclose(agg, _segment_reduce_np(facts, 4),
                               rtol=1e-5, atol=1e-5)


def test_warehouse_kpi_running_matches_rescan():
    """The fused rollups accumulated at load time reproduce the full
    rescan — and gap honestly (None) when any load lacked a rollup."""
    pipe = _pipeline("jax", n_records=400, n_workers=2, n_partitions=4)
    pipe.run_to_completion()
    running = pipe.warehouse.kpi_running()
    assert running is not None
    scan = pipe.warehouse.kpi_rollup(pipe.cfg.n_business_keys,
                                     backend="numpy")
    np.testing.assert_array_equal(running[:, 4], scan[:, 4])  # counts exact
    np.testing.assert_allclose(running, scan, rtol=1e-4, atol=1e-4)
    # a rollup-less load (legacy path) invalidates the O(1) aggregate
    pipe.warehouse.load(0, np.zeros((3, 10), np.float32))
    assert pipe.warehouse.kpi_running() is None


def test_kpi_rollup_matches_query_oee():
    pipe = _pipeline("jax", n_records=400, n_workers=2, n_partitions=4)
    pipe.run_to_completion()
    agg = pipe.warehouse.kpi_rollup(4, backend="numpy")
    for unit in range(4):
        q = pipe.warehouse.query_oee(unit)
        if np.isnan(q["oee"]):
            assert agg[unit, 4] == 0
            continue
        assert agg[unit, 4] == q["rows"]
        np.testing.assert_allclose(agg[unit, 3] / agg[unit, 4], q["oee"],
                                   rtol=1e-5)
