"""Small-mesh dry-run smoke: lower+compile one train and one decode cell on
a forced 4-device host mesh (subprocess: the device-count env must be set
before jax initializes). The full 512-device matrix runs via
scripts/run_dryrun_matrix.sh; its artifacts live in experiments/dryrun.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, json
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models.model import Model
from repro.models.sharding import ShardingCtx, default_rules
from repro.optim import AdamWConfig, abstract_state
from repro.train.train_step import make_train_step
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2), ("data", "model"))
cfg = get_smoke_config("internlm2-1.8b")
model = Model(cfg)
rules = default_rules()
rules["batch"] = "data"
ctx = ShardingCtx(mesh=mesh, rules=rules)
specs = model.specs(rules, mesh)
named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                               is_leaf=lambda x: isinstance(x, P))
step = make_train_step(model, AdamWConfig(), ctx)
batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
         "targets": jax.ShapeDtypeStruct((4, 64), jnp.int32)}
opt = abstract_state(model.abstract())
jitted = jax.jit(step, in_shardings=(named(specs), None, None),
                 donate_argnums=(0,))
compiled = jitted.lower(model.abstract(), opt, batch).compile()
ca = compiled.cost_analysis()
print(json.dumps({"ok": True, "flops": float((ca if isinstance(ca, dict)
                                              else ca[0]).get("flops", 0))}))
"""


def test_small_mesh_train_lower_compile():
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    last = out.stdout.strip().splitlines()[-1]
    assert json.loads(last)["ok"]


def test_matrix_artifacts_all_ok():
    """Every produced dry-run artifact must be ok/skipped (the matrix is
    produced by scripts/run_dryrun_matrix.sh; skip if absent)."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("dry-run matrix not generated in this environment")
    bad = []
    n = 0
    for f in os.listdir(d):
        if not f.endswith(".json"):
            continue
        n += 1
        rec = json.load(open(os.path.join(d, f)))
        if rec.get("status") not in ("ok", "skipped"):
            bad.append(f)
    assert n >= 80, f"expected 80 cells, found {n}"
    assert not bad, bad
