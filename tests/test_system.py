"""End-to-end behaviour tests for DOD-ETL (the paper's system)."""
import numpy as np
import pytest

from repro.configs.dod_etl import steelworks_config
from repro.core import (BaselineStreamProcessor, DODETLPipeline,
                        SourceDatabase, RecordBatch)
from repro.data.sampler import SamplerConfig, SteelworksSampler


def build_pipeline(n_records=1500, n_workers=3, n_partitions=6,
                   late_frac=0.05, complex_model=False, join_depth=1):
    cfg = steelworks_config(n_partitions=n_partitions,
                            complex_model=complex_model)
    src = SourceDatabase()
    sampler = SteelworksSampler(cfg, SamplerConfig(
        records_per_table=n_records, n_equipment=n_partitions,
        late_master_frac=late_frac))
    sampler.generate(src)
    pipe = DODETLPipeline(cfg, src, n_workers=n_workers,
                          join_depth=join_depth)
    return cfg, src, pipe


def test_pipeline_end_to_end_processes_every_record():
    cfg, src, pipe = build_pipeline()
    pipe.extract()
    pipe.bootstrap_caches()
    done = pipe.run_to_completion()
    assert done == 1500                       # every production record lands
    assert pipe.warehouse.rows_loaded == 1500
    assert all(len(w.buffer) == 0 for w in pipe.workers)


def test_no_source_lookbacks():
    """DOD-ETL's core property: extraction touches only the CDC log."""
    cfg, src, pipe = build_pipeline()
    pipe.extract()
    pipe.bootstrap_caches()
    pipe.run_to_completion()
    assert src.lookup_count == 0
    assert src.scan_count == 0


def test_late_master_data_goes_through_buffer():
    """Out-of-sync arrival (paper §3.2): operational records whose master
    rows lag are buffered, then eventually processed with referential
    integrity."""
    cfg, src, pipe = build_pipeline(late_frac=0.2)
    # extract only the head of the log (master tail not yet extracted)
    for listener in pipe.tracker.listeners:
        listener.poll(limit=4000)
    pipe.bootstrap_caches()
    pipe.step()
    buffered_mid = sum(w.transformer.records_late for w in pipe.workers)
    assert buffered_mid > 0                  # some records were early
    pipe.extract()                           # the late master tail arrives
    pipe.run_to_completion()
    assert pipe.warehouse.rows_loaded == 1500
    assert all(len(w.buffer) == 0 for w in pipe.workers)
    # referential integrity: every loaded fact was marked valid
    assert (pipe.warehouse.fact_table()[:, -1] > 0.5).all()


def test_fault_tolerance_consistency():
    """Paper §4.1.3: kill 2 of 5 workers mid-run; processing completes with
    zero consistency errors (facts match a single-worker oracle run)."""
    cfg, src, pipe = build_pipeline(n_workers=5, n_partitions=10,
                                    n_records=1200)
    pipe.extract()
    pipe.bootstrap_caches()
    pipe.step(max_records_per_partition=30)   # partial progress
    redump = pipe.fail_workers(["w1", "w3"])
    assert redump >= 0.0
    assert len(pipe.workers) == 3
    pipe.run_to_completion()
    assert pipe.warehouse.rows_loaded == 1200

    # oracle: same workload, one worker, no failure
    cfg2, src2, pipe2 = build_pipeline(n_workers=1, n_partitions=10,
                                       n_records=1200)
    pipe2.extract()
    pipe2.bootstrap_caches()
    pipe2.run_to_completion()
    a = pipe.warehouse.fact_table()
    b = pipe2.warehouse.fact_table()
    order = lambda t: t[np.lexsort((t[:, 1], t[:, 0]))]
    np.testing.assert_allclose(order(a), order(b), rtol=1e-5, atol=1e-5)


def test_elastic_scale_up_down():
    from repro.runtime.cluster import SimulatedCluster
    cfg, src, pipe = build_pipeline(n_workers=2, n_partitions=8)
    cluster = SimulatedCluster(pipe)
    pipe.extract()
    pipe.bootstrap_caches()
    cluster.run_round(max_records_per_partition=40)
    cluster.scale_to(4)
    assert len(pipe.workers) == 4
    cluster.run_round()
    cluster.scale_to(2)
    assert len(pipe.workers) == 2
    pipe.run_to_completion()
    assert pipe.warehouse.rows_loaded == 1500


def test_checkpoint_restart_resumes_stream():
    """Restart from a checkpoint resumes exactly (no loss, no dupes)."""
    cfg, src, pipe = build_pipeline(n_records=800, n_workers=2,
                                    n_partitions=4)
    pipe.extract()
    pipe.bootstrap_caches()
    pipe.step(max_records_per_partition=50)
    state = pipe.checkpoint()
    rows_before = pipe.warehouse.rows_loaded

    # "crash": rebuild the pipeline from scratch, restore, continue
    pipe2 = DODETLPipeline(cfg, src, n_workers=2)
    pipe2.tracker = pipe.tracker  # same already-extracted queue? no:
    # restore against a fresh pipeline on the same queue state
    pipe2 = DODETLPipeline(cfg, src, n_workers=2)
    pipe2.queue = pipe.queue
    for w in pipe2.workers:
        w.queue = pipe.queue
    pipe2.restore(state)
    pipe2.bootstrap_caches()
    pipe2.run_to_completion()
    total = rows_before + pipe2.warehouse.rows_loaded
    assert total == 800, f"{rows_before} + {pipe2.warehouse.rows_loaded}"


def test_baseline_matches_dodetl_output():
    """The baseline (source look-backs, record-at-a-time) computes the SAME
    facts — it is only slower (Table 2)."""
    cfg, src, pipe = build_pipeline(n_records=300, n_workers=1,
                                    n_partitions=4, late_frac=0.0)
    pipe.extract()
    pipe.bootstrap_caches()
    pipe.run_to_completion()

    baseline = BaselineStreamProcessor(cfg, src)
    prod_tid = [t.name for t in cfg.tables].index("production")
    batches = []
    for b in src.log._batches:
        mine = b.filter(b.table_id == prod_tid)
        if len(mine):
            batches.append(mine)
    facts_b = np.concatenate([baseline.process(b) for b in batches])
    assert src.lookup_count > 0               # baseline DID hammer the source
    a = pipe.warehouse.fact_table()
    order = lambda t: t[np.lexsort((t[:, 1], t[:, 0]))]
    np.testing.assert_allclose(order(a)[:, 3:7], order(facts_b)[:, 3:7],
                               rtol=1e-4, atol=1e-4)


def test_complex_model_still_correct():
    """ISA-95-style normalized schema (join_depth > 1) processes fully
    (paper §4.1.4: slower, not wrong)."""
    cfg, src, pipe = build_pipeline(n_records=400, complex_model=True,
                                    join_depth=3)
    pipe.extract()
    pipe.bootstrap_caches()
    pipe.run_to_completion()
    assert pipe.warehouse.rows_loaded == 400
