"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gla_chunk.gla_chunk import gla_chunk_kernel
from repro.kernels.gla_chunk.ref import gla_ref
from repro.kernels.hash_join.ops import hash_join
from repro.kernels.hash_join.ref import hash_join_ref
from repro.kernels.segment_kpi.ops import segment_kpi
from repro.kernels.segment_kpi.ref import segment_kpi_ref


@pytest.mark.parametrize("b,hq,hkv,s,d,causal,dtype,tol", [
    (2, 4, 2, 256, 64, True, jnp.float32, 2e-5),
    (1, 8, 8, 384, 128, True, jnp.bfloat16, 2e-2),
    (2, 6, 2, 256, 64, False, jnp.float32, 2e-5),
    (1, 12, 4, 512, 64, True, jnp.bfloat16, 2e-2),
    (1, 2, 1, 128, 128, True, jnp.float32, 2e-5),
])
def test_flash_attention_sweep(b, hq, hkv, s, d, causal, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bh,s,dk,dv,inclusive,use_u,chunk", [
    (4, 256, 64, 64, False, True, 64),     # rwkv6 regime
    (2, 128, 64, 128, True, False, 64),    # mamba2/SSD regime
    (3, 192, 32, 32, False, False, 64),
    (1, 512, 128, 64, True, False, 128),
])
def test_gla_chunk_sweep(bh, s, dk, dv, inclusive, use_u, chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (bh, s, dk), jnp.float32)
    k = jax.random.normal(ks[1], (bh, s, dk), jnp.float32)
    v = jax.random.normal(ks[2], (bh, s, dv), jnp.float32)
    lw = -jnp.exp(jax.random.normal(ks[3], (bh, s, dk)))
    u = jax.random.normal(ks[4], (bh, dk), jnp.float32) if use_u else None
    out = gla_chunk_kernel(q, k, v, lw, u, inclusive=inclusive, chunk=chunk,
                           interpret=True)
    ref = gla_ref(q, k, v, lw, u, inclusive=inclusive, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_gla_chunk_matches_step_recurrence():
    """Chunked kernel vs the token-by-token recurrence (decode path)."""
    from repro.models.gla import gla_step
    bh, s, dk, dv = 2, 128, 32, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (bh, s, dk), jnp.float32)
    k = jax.random.normal(ks[1], (bh, s, dk), jnp.float32)
    v = jax.random.normal(ks[2], (bh, s, dv), jnp.float32)
    lw = -jnp.exp(jax.random.normal(ks[3], (bh, s, dk)))
    out = gla_chunk_kernel(q, k, v, lw, None, inclusive=True, chunk=64,
                           interpret=True)
    S = jnp.zeros((bh, 1, dk, dv))
    outs = []
    for t in range(s):
        o, S = gla_step(q[:, t, None], k[:, t, None], v[:, t, None],
                        lw[:, t, None], S, inclusive=True)
        outs.append(o[:, 0])
    ref = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("n_slots,n_keys,n_queries", [
    (512, 300, 128), (1024, 700, 512), (256, 50, 64)])
def test_hash_join_sweep(n_slots, n_keys, n_queries):
    from repro.core.cache import InMemoryTable
    rng = np.random.default_rng(0)
    tbl = InMemoryTable(n_slots)
    keys = rng.choice(10**6, n_keys, replace=False).astype(np.int64)
    tbl.upsert(keys, rng.normal(size=(n_keys, 8)).astype(np.float32),
               np.arange(n_keys, dtype=np.int64))
    queries = jnp.asarray(np.concatenate(
        [rng.choice(keys, n_queries // 2),
         rng.integers(2 * 10**6, 3 * 10**6, n_queries - n_queries // 2)]),
        jnp.int32)
    kt, vt, tt = tbl.device_state()
    v1, f1, t1 = hash_join(queries, kt, vt, tt)
    v2, f2, t2 = hash_join_ref(queries, kt, vt, tt)
    assert bool((f1 == f2).all())
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    assert float(jnp.asarray(f1, jnp.float32).mean()) >= 0.49


def test_segment_kpi_sweep():
    rng = np.random.default_rng(3)
    for n, units in [(256, 8), (1000, 20), (513, 32)]:
        prod = np.abs(rng.normal(size=(n, 8))).astype(np.float32)
        prod[:, 1] = rng.integers(0, units, n)
        prod[:, 4] = prod[:, 3] + np.abs(prod[:, 4]) + 0.1
        eq = np.abs(rng.normal(size=(n, 8))).astype(np.float32)
        eq[:, 1] = prod[:, 1]
        eq[:, 4] = eq[:, 3] + np.abs(eq[:, 4]) + 5
        eq[:, 5] = rng.random(n) > 0.3
        qr = np.abs(rng.normal(size=(n, 8))).astype(np.float32)
        qr[:, 1] = prod[:, 1]
        f_k, a_k = segment_kpi(jnp.asarray(prod), jnp.asarray(eq),
                               jnp.asarray(qr), n_units=units)
        f_r, a_r = segment_kpi_ref(jnp.asarray(prod), jnp.asarray(eq),
                                   jnp.asarray(qr), n_units=units)
        np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r),
                                   rtol=1e-4, atol=1e-4)


def test_gla_pipeline_vs_models_gla():
    """kernels/gla_chunk ops wrapper == models.gla (the layer actually
    calls the latter on CPU; the contract must be identical)."""
    from repro.kernels.gla_chunk.ops import gla as gla_op
    from repro.models.gla import gla_chunk
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    b, s, h, dk, dv = 2, 128, 3, 32, 32
    q = jax.random.normal(ks[0], (b, s, h, dk), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, dk), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, dv), jnp.float32)
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, dk)))
    u = jax.random.normal(ks[4], (h, dk), jnp.float32)
    out1 = gla_op(q, k, v, lw, u, inclusive=False, chunk=64)
    out2, _ = gla_chunk(q, k, v, lw, u=u, inclusive=False, chunk=64,
                        ratio_dtype=jnp.float32)   # kernel computes in f32
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-4, atol=2e-4)
    # the production (bf16-ratio) path stays within ~1% of f32 at tensor
    # scale (individual near-zero elements are not rtol-comparable)
    out3, _ = gla_chunk(q, k, v, lw, u=u, inclusive=False, chunk=64)
    diff = float(jnp.abs(out3 - out2).max())
    scale = float(jnp.abs(out2).max())
    assert diff < 0.01 * scale, (diff, scale)
