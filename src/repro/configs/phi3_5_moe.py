"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400,
16 experts top-2, vocab=32064. [hf:microsoft/Phi-3.5-MoE-instruct]
"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab=32064,
        rope_theta=1e4,
        moe=MoEConfig(
            n_experts=16,
            top_k=2,
            n_shared_experts=0,
            d_ff_expert=6400,
        ),
        source="hf:microsoft/Phi-3.5-MoE-instruct",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="phi3.5-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96, group_size=64),
        microbatches=1,
        remat=False,
    )


register("phi3.5-moe-42b-a6.6b", full, smoke)
