"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152, RoPE. [arXiv:2402.19173]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        d_ff=18432,
        vocab=49152,
        rope_theta=1e5,
        source="arXiv:2402.19173",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="starcoder2-smoke",
        family="dense",
        n_layers=2,
        d_model=72,             # keeps the non-power-of-two flavour (36H/4kv)
        n_heads=6,
        n_kv_heads=2,
        d_ff=144,
        vocab=256,
        microbatches=1,
        remat=False,
    )


register("starcoder2-7b", full, smoke)
