"""rwkv6-7b (Finch) [ssm]: 32L d_model=4096 attention-free, d_ff=14336
vocab=65536, data-dependent decay. Sub-quadratic: runs long_500k.
[arXiv:2404.05892]
"""
from repro.configs.base import ModelConfig, SSMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,             # wkv heads (head_size 64)
        n_kv_heads=64,
        d_ff=14336,
        vocab=65536,
        pos_scheme="none",
        ssm=SSMConfig(state_size=64, n_ssm_heads=64),
        supports_decode=True,
        subquadratic=True,
        source="arXiv:2404.05892",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="rwkv6-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        pos_scheme="none",
        ssm=SSMConfig(state_size=16, n_ssm_heads=4),
        subquadratic=True,
        microbatches=1,
        remat=False,
    )


register("rwkv6-7b", full, smoke)
