"""The paper's own configuration: the DOD-ETL pipeline deployment knobs
(§3.1 "configuration process") plus the steelworks case-study schema
(§4: production / equipment / quality tables, OEE KPIs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class TableConfig:
    """Per-table deployment parameters (paper §3.1)."""

    name: str
    nature: str                 # "operational" | "master"
    row_key: str                # unique row identifier column
    business_key: str           # domain partition/filter column
    columns: Tuple[str, ...]    # payload schema (fixed-width numeric rows)


@dataclasses.dataclass(frozen=True)
class ETLConfig:
    """Full DOD-ETL deployment configuration."""

    tables: Tuple[TableConfig, ...]
    n_partitions: int = 20       # operational-topic partitions (paper: 20)
    n_business_keys: int = 20    # distinct equipment units (paper: 20)
    cache_slots: int = 4096      # hash slots per in-memory master table
    cache_row_width: int = 8     # f32 payload lanes per master row
    buffer_capacity: int = 1024  # late-message ring buffer entries
    queue_retention: int = 1 << 20
    seed: int = 0
    backend: str = ""            # compute backend: "numpy" | "jax" | "pallas"
                                 # ("" = DODETL_BACKEND env var, else "jax")
    partition_strategy: str = "static"   # key->partition routing strategy:
                                 # "static" (hash%n), "consistent" (vnode
                                 # ring), "skew" (load-adaptive ranges)
    # --- concurrent runtime (repro.runtime.cluster.ConcurrentCluster) ---
    handoff_depth: int = 4       # bounded hand-off queue slots between the
                                 # ingest -> transform -> load worker stages
    idle_backoff_s: float = 0.001  # stage sleep when its input is drained
    credit_capacity: int = 4096  # per-worker flow-control credits (records):
                                 # ingest spends on fetch, load refunds at
                                 # commit — a stalled downstream exhausts the
                                 # ledger and throttles extraction

    def table(self, name: str) -> TableConfig:
        for t in self.tables:
            if t.name == name:
                return t
        raise KeyError(name)

    @property
    def operational_tables(self) -> Tuple[TableConfig, ...]:
        return tuple(t for t in self.tables if t.nature == "operational")

    @property
    def master_tables(self) -> Tuple[TableConfig, ...]:
        return tuple(t for t in self.tables if t.nature == "master")


def steelworks_config(n_partitions: int = 20, complex_model: bool = False,
                      backend: str = "",
                      partition_strategy: str = "static") -> ETLConfig:
    """The paper's steelworks deployment (§4).

    ``complex_model=True`` approximates the ISA-95 production workload of
    §4.1.4: each logical category is split across several normalized tables
    so the transform must perform deeper join chains.
    """
    if not complex_model:
        tables = (
            TableConfig("production", "operational", "prod_id", "equipment_id",
                        ("prod_id", "equipment_id", "txn_time", "t_start",
                         "t_end", "qty", "speed", "order_id")),
            TableConfig("equipment", "master", "equip_row_id", "equipment_id",
                        ("equip_row_id", "equipment_id", "txn_time", "t_start",
                         "t_end", "status", "max_speed", "planned")),
            TableConfig("quality", "master", "qual_row_id", "equipment_id",
                        ("qual_row_id", "equipment_id", "txn_time", "prod_id",
                         "defects", "grade", "scrap", "rework")),
        )
    else:
        # ISA-95-flavoured normalization: 9 tables, category split 3-ways.
        tables = tuple(
            TableConfig(f"{cat}_{part}",
                        "operational" if cat == "production" else "master",
                        f"{cat}_{part}_row", "equipment_id",
                        (f"{cat}_{part}_row", "equipment_id", "txn_time",
                         "a", "b", "c", "d", "e"))
            for cat in ("production", "equipment", "quality")
            for part in ("segment", "event", "detail")
        )
    return ETLConfig(tables=tables, n_partitions=n_partitions,
                     n_business_keys=n_partitions, backend=backend,
                     partition_strategy=partition_strategy)


# KPI definitions (paper §4): OEE = availability * performance * quality.
KPI_COLUMNS: Dict[str, int] = {
    "availability": 0,
    "performance": 1,
    "quality": 2,
    "oee": 3,
}
