"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, llama-style, code. [arXiv:2405.04324]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="granite-20b",
        family="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,           # MQA
        d_ff=24576,
        vocab=49152,
        rope_theta=1e4,
        source="arXiv:2405.04324",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="granite-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab=256,
        microbatches=1,
        remat=False,
    )


register("granite-20b", full, smoke)
