"""zamba2-1.2b [hybrid]: 38L d_model=2048 Mamba2 backbone + one shared
attention block (32H kv=32) applied periodically, d_ff=8192, vocab=32000,
ssm_state=64. Sub-quadratic backbone: runs long_500k.
[arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig, SSMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32000,
        rope_theta=1e4,
        ssm=SSMConfig(state_size=64, n_ssm_heads=64, expand=2, conv_kernel=4),
        shared_attn_every=6,     # shared block applied every 6 mamba layers
        supports_decode=True,
        subquadratic=True,
        source="arXiv:2411.15242",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="zamba2-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        ssm=SSMConfig(state_size=16, n_ssm_heads=4, expand=2, conv_kernel=4),
        shared_attn_every=2,
        subquadratic=True,
        microbatches=1,
        remat=False,
    )


register("zamba2-1.2b", full, smoke)
