"""Architecture/config registry. Importing this package registers all
assigned architectures plus the paper's own ETL config.
"""
from repro.configs.base import (  # noqa: F401
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeSuite,
    SHAPE_SUITES,
    REGISTRY,
    get_config,
    get_smoke_config,
    list_archs,
)
# one registered architecture per model family (dense / vlm / ssm / moe /
# hybrid / enc-dec) — the redundant same-family seed configs were pruned
from repro.configs import (  # noqa: F401
    whisper_small,
    internlm2_1_8b,
    qwen2_vl_7b,
    rwkv6_7b,
    qwen2_moe_a2_7b,
    zamba2_1_2b,
)
from repro.configs.dod_etl import ETLConfig, TableConfig, steelworks_config  # noqa: F401
