"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544. [arXiv:2403.17297]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="internlm2-1.8b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab=92544,
        rope_theta=1e6,
        source="arXiv:2403.17297",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="internlm2-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        microbatches=1,
        remat=False,
    )


register("internlm2-1.8b", full, smoke)
