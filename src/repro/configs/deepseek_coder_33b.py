"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256, llama-arch. [arXiv:2401.14196]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab=32256,
        rope_theta=1e5,
        source="arXiv:2401.14196",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="deepseek-coder-smoke",
        family="dense",
        n_layers=2,
        d_model=56,
        n_heads=7,              # mirrors the 56H/8kv ratio
        n_kv_heads=1,
        d_ff=112,
        vocab=256,
        microbatches=1,
        remat=False,
    )


register("deepseek-coder-33b", full, smoke)
