"""Model / run configuration system.

One ``ModelConfig`` dataclass covers all assigned architecture families:
dense decoder-only transformers (GQA/MQA), encoder-decoder (whisper),
VLM backbones (qwen2-vl), attention-free SSMs (rwkv6), MoE transformers
(qwen2-moe) and hybrids (zamba2: Mamba2 + shared attention).

Every architecture registers itself in ``REGISTRY`` via ``register``;
``get_config(arch_id)`` returns the full published config and
``get_smoke_config(arch_id)`` a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Input-shape suites (assigned): every LM arch is paired with all four.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSuite:
    """One assigned (seq_len, global_batch) cell and which step it lowers."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPE_SUITES: Dict[str, ShapeSuite] = {
    "train_4k": ShapeSuite("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSuite("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSuite("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSuite("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0          # per-expert hidden width
    capacity_factor: float = 1.25
    group_size: int = 2048        # GShard-style dispatch group
    router_aux_weight: float = 1e-2
    n_experts_padded: int = 0     # pad expert dim for EP divisibility
                                  # (dummy experts masked out of routing)

    @property
    def padded_experts(self) -> int:
        return max(self.n_experts_padded, self.n_experts)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_size: int = 64          # per-head recurrent state width
    n_ssm_heads: int = 0          # heads of the linear recurrence
    conv_kernel: int = 4          # short conv (mamba2); rwkv6 uses token-shift
    expand: int = 2               # mamba2 inner expansion


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0              # fixed encoder context (1500 for whisper)
    # positional scheme: "rope" | "mrope" | "sinusoidal" | "none"
    pos_scheme: str = "rope"
    rope_theta: float = 1e6
    # mixture of experts
    moe: Optional[MoEConfig] = None
    # state-space / linear recurrence
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every k layers
    shared_attn_every: int = 0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # which shape suites this arch supports (decode needs a decoder;
    # long_500k needs sub-quadratic sequence mixing)
    supports_decode: bool = True
    subquadratic: bool = False
    # training-side knobs (overridable per run)
    remat: bool = True
    microbatches: int = 8
    dtype: str = "bfloat16"
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/unembedding can
        TP-shard on a 16-way axis (MaxText-style vocab padding; padded logits
        are sliced off before the loss/argmax)."""
        return (self.vocab + 255) // 256 * 256

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops accounting)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d
        if self.family in ("ssm",):
            # rwkv6: time-mix (r,k,v,g,w projections + lora decay) + channel mix
            tm = 4 * d * d + d * d + 2 * (d * 32 * 2)
            cm = 2 * d * self.d_ff + self.d_ff * d  # actually rwkv cm is 2 mats
            per_layer = tm + cm
        elif self.family == "hybrid":
            ssm = self.ssm or SSMConfig()
            d_in = ssm.expand * d
            nh = ssm.n_ssm_heads or (d_in // ssm.state_size)
            per_layer = (d * (2 * d_in + 2 * ssm.state_size + nh)
                         + d_in * d)                 # mamba2 only (no MLP)
        else:
            per_layer = attn + 3 * d * self.d_ff  # SwiGLU MLP
        total = L * per_layer
        if self.moe is not None and self.moe.n_experts:
            moe_ff = 3 * d * self.moe.d_ff_expert
            dense_ff = 3 * d * self.d_ff
            shared = self.moe.n_shared_experts * moe_ff
            total += L * (self.moe.n_experts * moe_ff + shared - dense_ff)
            total += L * d * self.moe.n_experts  # router
        if self.shared_attn_every:
            # hybrid: one shared attention+mlp block (not per-layer)
            total += attn + 3 * d * self.d_ff
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn + 2 * d * self.d_ff)
            total += L * attn  # decoder cross-attention
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if self.moe is None or not self.moe.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        moe_ff = 3 * d * self.moe.d_ff_expert
        active = self.param_count()
        active -= L * (self.moe.n_experts - self.moe.top_k) * moe_ff
        return int(active)

    def shape_cells(self) -> Tuple[ShapeSuite, ...]:
        cells = [SHAPE_SUITES["train_4k"], SHAPE_SUITES["prefill_32k"]]
        if self.supports_decode:
            cells.append(SHAPE_SUITES["decode_32k"])
            if self.subquadratic:
                cells.append(SHAPE_SUITES["long_500k"])
        return tuple(cells)


REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
SMOKE_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]):
    REGISTRY[arch] = full
    SMOKE_REGISTRY[arch] = smoke


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch]()


def get_smoke_config(arch: str) -> ModelConfig:
    return SMOKE_REGISTRY[arch]()


def list_archs():
    return sorted(REGISTRY)
