"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, M-RoPE, dynamic resolution. Backbone only; the vision
frontend is a stub — input_specs() provides precomputed patch embeddings.
[arXiv:2409.12191]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab=152064,
        pos_scheme="mrope",
        rope_theta=1e6,
        source="arXiv:2409.12191",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-vl-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        pos_scheme="mrope",
        microbatches=1,
        remat=False,
    )


register("qwen2-vl-7b", full, smoke)
