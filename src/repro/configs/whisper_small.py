"""whisper-small [audio]: enc-dec, 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865, conv frontend stubbed (input_specs provides frame embeddings).
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="whisper-small",
        family="encdec",
        n_layers=12,             # decoder layers
        n_enc_layers=12,
        enc_seq=1500,            # 30 s audio -> 1500 frames post conv stem
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab=51865,
        pos_scheme="sinusoidal",
        supports_decode=True,
        subquadratic=False,
        tie_embeddings=True,
        source="arXiv:2212.04356",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="whisper-small-smoke",
        family="encdec",
        n_layers=2,
        n_enc_layers=2,
        enc_seq=16,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        pos_scheme="sinusoidal",
        tie_embeddings=True,
        microbatches=1,
        remat=False,
    )


register("whisper-small", full, smoke)
