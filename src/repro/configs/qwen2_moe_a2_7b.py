"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408,
60 routed experts top-4 + 4 shared, vocab=151936.
[hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=151936,
        rope_theta=1e6,
        moe=MoEConfig(
            n_experts=60,
            top_k=4,
            n_shared_experts=4,
            d_ff_expert=1408,
            n_experts_padded=64,   # EP over a 16-way axis; 4 dummy experts
        ),
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab=256,
        moe=MoEConfig(n_experts=6, top_k=2, n_shared_experts=2,
                      d_ff_expert=64, group_size=64),
        microbatches=1,
        remat=False,
    )


register("qwen2-moe-a2.7b", full, smoke)
