from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    AdamWState,
    abstract_state,
    apply_updates,
    global_norm,
    init_state,
    schedule,
)
