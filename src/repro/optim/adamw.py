"""AdamW with ZeRO-style sharded moments, global-norm clipping and a
linear-warmup cosine schedule. Pure pytree functions (no optax dependency).

Moments are f32 and carry their own PartitionSpecs (typically sharded over
*both* mesh axes — ZeRO-1 — while params stay TP-sharded; GSPMD inserts the
reduce-scatter/all-gather pair this implies).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array        # i32 []
    mu: Any                # f32 pytree like params
    nu: Any                # f32 pytree like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zeros2 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros2)


def abstract_state(abstract_params) -> AdamWState:
    f32 = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=f32,
                      nu=jax.tree.map(lambda x: x, f32))


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState,
                  ) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
