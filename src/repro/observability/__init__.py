"""Unified observability plane: lock-sharded metrics registry, stage-span
tracer (Chrome-trace/Perfetto export), and cluster/pipeline health
snapshots. See docs/OBSERVABILITY.md."""
from repro.observability.health import (build_cluster_health,
                                        build_pipeline_health,
                                        merged_counters)
from repro.observability.registry import (Counter, Gauge, MetricsRegistry,
                                          MetricsShard, global_registry)
from repro.observability.tracer import NULL_TRACER, StageTracer

__all__ = [
    "Counter", "Gauge", "MetricsRegistry", "MetricsShard",
    "global_registry", "NULL_TRACER", "StageTracer",
    "build_cluster_health", "build_pipeline_health", "merged_counters",
]
