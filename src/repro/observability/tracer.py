"""Stage-span tracer: per-batch spans over the pipeline's stage seams,
exportable as Chrome-trace/Perfetto JSON.

The enable/disable seam copies ``durability.faults``'s ``NULL_INJECTOR``
pattern exactly: every instrumented component holds a ``tracer``
attribute defaulting to the module singleton ``NULL_TRACER``, whose
``span()`` returns one shared, stateless no-op context manager — the
disabled hot path costs two attribute lookups and a call, allocates
NOTHING persistent, and needs no ``if tracing:`` branches at the call
sites. Swap in a ``StageTracer`` and the same call sites emit real
spans.

Span seams (the six stage boundaries plus repartition phases):

    ingest.fetch        broker poll -> hand-off      (per worker, per poll)
    transform.dispatch  device transform dispatch    (per batch)
    load.commit         warehouse load + offset commit
    serving.fold        materialized-view delta fold (per epoch advance)
    query.batch         batched report plan execute  (per coalesced batch)
    checkpoint.step     durability journal append
    repartition.*       plan / reroute / migrate phases

Lanes: a span lands in the lane (Chrome ``tid``) named after its thread
(worker stage threads are named ``w0.ingest`` etc.), so the Perfetto
view shows one swimlane per worker stage. Export with
``tracer.export_chrome_trace(path)`` and open the file at
https://ui.perfetto.dev — see docs/OBSERVABILITY.md for a worked run.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Dict, List, Optional


class _NullSpan:
    """The shared no-op span. Stateless (``__slots__ = ()``): entering,
    exiting, annotating and dropping it all do nothing, so ONE instance
    serves every disabled call site forever — zero allocation."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def put(self, key, value) -> None:
        pass

    def drop(self) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _NullTracer:
    """Disabled tracer: ``span()``/``instant()`` are allocation-free
    no-ops (pinned by a tracemalloc test). Default for every component's
    ``tracer`` attribute — the same seam as ``NULL_INJECTOR``."""

    __slots__ = ()
    enabled = False

    def span(self, name: str, lane: Optional[str] = None) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, lane: Optional[str] = None) -> None:
        return None


NULL_TRACER = _NullTracer()


class _Span(object):
    """One live span: context manager capturing wall interval + optional
    args; appended to the tracer's event list (under its lock) on exit.
    ``drop()`` cancels recording — used to skip empty broker polls so
    idle traces stay readable."""

    __slots__ = ("_tracer", "name", "lane", "_t0", "_args", "_dropped")

    def __init__(self, tracer: "StageTracer", name: str,
                 lane: Optional[str]):
        self._tracer = tracer
        self.name = name
        self.lane = lane
        self._t0 = 0.0
        self._args: Optional[Dict[str, object]] = None
        self._dropped = False

    def put(self, key: str, value) -> None:
        """Attach one argument (shown in the Perfetto detail pane)."""
        if self._args is None:
            self._args = {}
        self._args[key] = value

    def drop(self) -> None:
        self._dropped = True

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc) -> bool:
        if not self._dropped:
            t1 = self._tracer._clock()
            self._tracer._record(
                self.name, self.lane or threading.current_thread().name,
                self._t0, t1 - self._t0, self._args)
        return False


class StageTracer:
    """Collects spans from every pipeline thread; lock guards only the
    event-list append (the measured interval is computed outside it).
    Export with ``to_chrome()`` / ``export_chrome_trace()``."""

    enabled = True

    def __init__(self, clock=time.perf_counter, max_events: int = 1 << 20):
        self._clock = clock
        self._t0 = clock()
        self._lock = threading.Lock()
        self._events: List[tuple] = []   # (ph, name, lane, t_start, dur, args)
        self.max_events = max_events
        self.dropped_events = 0

    # ------------------------------------------------------------ write side
    def span(self, name: str, lane: Optional[str] = None) -> _Span:
        return _Span(self, name, lane)

    def instant(self, name: str, lane: Optional[str] = None) -> None:
        self._record(name, lane or threading.current_thread().name,
                     self._clock(), None, None, ph="i")

    def _record(self, name, lane, t_start, dur, args, ph="X") -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped_events += 1
                return
            self._events.append((ph, name, lane, t_start, dur, args))

    # ------------------------------------------------------------- read side
    def events(self) -> List[tuple]:
        with self._lock:
            return list(self._events)

    def span_names(self) -> List[str]:
        names: List[str] = []
        for ev in self.events():
            if ev[1] not in names:
                names.append(ev[1])
        return names

    def clear(self) -> None:
        with self._lock:
            self._events = []
            self.dropped_events = 0

    def to_chrome(self) -> Dict[str, object]:
        """Chrome-trace JSON object (Perfetto/chrome://tracing loadable):
        complete ("X") events with microsecond timestamps relative to
        tracer start, one ``tid`` per lane plus ``thread_name`` metadata
        so lanes are labeled swimlanes."""
        events = self.events()
        lanes: Dict[str, int] = {}
        out: List[Dict[str, object]] = [
            {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "dod-etl"}}]
        for ev in events:
            lane = ev[2]
            if lane not in lanes:
                lanes[lane] = len(lanes) + 1
                out.append({"name": "thread_name", "ph": "M", "pid": 1,
                            "tid": lanes[lane], "args": {"name": lane}})
        for ph, name, lane, t_start, dur, args in events:
            rec: Dict[str, object] = {
                "name": name, "cat": name.split(".", 1)[0], "ph": ph,
                "ts": round((t_start - self._t0) * 1e6, 3),
                "pid": 1, "tid": lanes[lane]}
            if ph == "X":
                rec["dur"] = round((dur or 0.0) * 1e6, 3)
            if args:
                rec["args"] = args
            elif ph == "i":
                rec["s"] = "t"
            out.append(rec)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped_events}}

    def export_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


__all__ = ["NULL_TRACER", "StageTracer"]
