"""`ClusterHealth` snapshots: one structured dict per call — the
observation vector a future autoscaling controller consumes (ROADMAP
item 4: a control loop needs freshness percentiles, backlog, and
per-worker load collected in ONE place, at ONE instant).

Builders are duck-typed over ``ConcurrentCluster`` / ``DODETLPipeline``
(no imports of the runtime — the runtime imports us). The snapshot is
designed to be taken on a LIVE cluster while rebalances, repartitions
and checkpoints run concurrently:

* it takes NO stage or commit locks (never blocks or deadlocks the data
  plane — a health poll must be safe at any frequency);
* every scalar is a single GIL-atomic field read (counters are plain
  ints with one writer — readable mid-increment without tearing) and
  every percentile comes from a recorder that locks only its chunk
  list;
* the partition assignment is copied ONCE per snapshot (with a retry
  around the copy, since a concurrent rebalance may resize the dict
  mid-iteration), and all per-worker partition / commit-lag views are
  derived from that one copy — so ownership and lag never mix two
  different rebalance generations within one snapshot.

Schema (``build_cluster_health``)::

    {
      "generated_at": <perf_counter seconds>,
      "wall_s":       <seconds since cluster start>,
      "workers": {name: {"alive", "partitions", "records_done",
                         "records_fetched", "throughput_rps", "in_flight",
                         "transform_q", "load_q", "buffer",
                         "dead_lettered", "credits_available",
                         "heartbeat_max_age_s",
                         "cache_rows", "freshness": {p50/p95/p99_ms, n}}},
      "freshness":  cluster-merged p50/p95/p99 (ms),
      "staleness":  serving-side percentiles (or None),
      "serving":    {"epoch", "pending_deltas"} (or None),
      "backlog":    {"operational_lag", "extraction_lag", "buffered"},
      "commit_lag": {topic: {partition: records}},
      "routing_epoch": int,
      "cache": {"rows", "retention_last_migration"},
      "checkpoint": {"steps", "last_step", "age_s"} (or None),
      "control":   {"enabled", "degraded", "breaker_open", "suspects",
                    "evictions", "restarts", "dead_lettered", ...} —
                    ControlPlane.snapshot() when a control plane is
                    attached, a static same-shape stub otherwise,
      "mesh":      sharded serving plane: {"n_shards", "device_mesh",
                    "routing_epoch", "fold_rows", "fold_rows_imbalance",
                    "owned_segments", "merge": {bytes, dispatches},
                    "reowns", "segments_moved"} — ShardedViewEngine's
                    mesh_report() when sharded, a same-shape stub
                    otherwise,
      "counters":  merged registry counters (pipeline + process-global),
    }
"""
from __future__ import annotations

import time
from typing import Dict, Optional

from repro.observability.registry import global_registry


def _copy_assignment(assignment) -> Dict[int, str]:
    """One atomic-enough copy of {partition: owner}: retried because a
    concurrent rebalance can resize the dict mid-copy."""
    src = assignment.assignment
    for _ in range(16):
        try:
            return dict(src)
        except RuntimeError:        # "dict changed size during iteration"
            continue
    return dict(src)                # last try: let a persistent race raise


def merged_counters(pipe) -> Dict[str, int]:
    """The one-read-path counter view: the pipeline registry's totals
    plus the process-global registry (backend dispatch counters)."""
    out = dict(global_registry().counters())
    out.update(pipe.metrics.counters())
    return out


def _commit_lags(pipe, assignment: Dict[int, str],
                 group_of: Dict[str, str]) -> Dict[str, Dict[int, int]]:
    """Per topic -> partition: high watermark minus the OWNER's committed
    offset, all owners resolved against one assignment copy."""
    q = pipe.queue
    out: Dict[str, Dict[int, int]] = {}
    for topic in pipe.operational_topics:
        t = q.topics[topic]
        lags: Dict[int, int] = {}
        for p, owner in assignment.items():
            g = group_of.get(owner)
            committed = q.committed(g, topic, p) if g else 0
            lags[p] = max(0, t.high_watermark(p) - committed)
        out[topic] = lags
    return out


def build_cluster_health(cluster) -> Dict:
    """``ConcurrentCluster.health()``: see the module docstring schema."""
    pipe = cluster.pipe
    now = time.perf_counter()
    wall = (now - cluster._t_start) if cluster._t_start else 0.0
    assignment = _copy_assignment(cluster.assignment)
    runtimes = dict(cluster.runtimes)
    group_of = {n: rt.worker.group for n, rt in runtimes.items()}

    workers: Dict[str, Dict] = {}
    total_buffered = 0
    total_cache_rows = 0
    total_dead_lettered = 0
    for name, rt in runtimes.items():
        w = rt.worker
        buffered = len(w.buffer)
        dead_lettered = len(w.dead_letter)
        cache_rows = w.equipment.n_rows + w.quality.n_rows
        total_dead_lettered += dead_lettered
        if not rt.dead:
            total_buffered += buffered
            total_cache_rows += cache_rows
        hb_ages = [rt.heartbeat_age(s) for s in rt.hb]
        workers[name] = {
            "alive": rt.alive,
            "partitions": sorted(p for p, o in assignment.items()
                                 if o == name),
            "records_done": rt.records_done,
            "records_fetched": rt.records_fetched,
            "throughput_rps": round(rt.records_done / wall, 3)
            if wall > 0 else 0.0,
            "in_flight": rt.in_flight(),
            "transform_q": rt.transform_q.qsize(),
            "load_q": rt.load_q.qsize(),
            "buffer": buffered,
            "dead_lettered": dead_lettered,
            "credits_available": rt.credits.available,
            "heartbeat_max_age_s": round(max(hb_ages), 4) if hb_ages
            else None,
            "cache_rows": cache_rows,
            "cache": {"equipment": w.equipment.stats(),
                      "quality": w.quality.stats()},
            "freshness": rt.latency.percentiles(drain=False),
        }

    commit_lag = _commit_lags(pipe, assignment, group_of)
    operational_lag = sum(lag for lags in commit_lag.values()
                          for lag in lags.values())
    extraction_lag = cluster._extraction_lag()

    staleness: Optional[Dict] = None
    serving: Optional[Dict] = None
    engine = cluster.serving
    if engine is not None:
        snap = engine.snapshot()
        staleness = engine.staleness(drain=False)
        serving = {"epoch": snap.epoch,
                   "pending_deltas": engine.pending(),
                   "data_age_ms": round(snap.staleness_ms(), 3)}

    # sharded serving plane: per-shard fold rows / owned segments /
    # merge traffic (the shard-imbalance signal the control plane's
    # observation vector consumes); a same-shape stub when the engine is
    # unsharded, following the `control` stub idiom below
    if engine is not None and hasattr(engine, "mesh_report"):
        mesh = engine.mesh_report()
    else:
        mesh = {"n_shards": 1, "device_mesh": False, "routing_epoch": 0,
                "fold_rows": [], "fold_rows_imbalance": 1.0,
                "owned_segments": {}, "merge": {"bytes": 0,
                                                "dispatches": 0},
                "reowns": 0, "segments_moved": 0}

    # control plane: the supervisor/controller's own snapshot when one is
    # attached; a same-shape stub otherwise so consumers (and the
    # controller's own drills) never branch on schema
    ctrl = getattr(cluster, "control", None)
    if ctrl is not None:
        control = ctrl.snapshot()
    else:
        control = {"enabled": False, "crashed": False, "degraded": False,
                   "breaker_open": False, "suspects": [],
                   "evictions": 0, "restarts": 0, "restart_failures": 0,
                   "dead_lettered": total_dead_lettered}

    checkpoint: Optional[Dict] = None
    rec = cluster.recovery
    if rec is not None:
        last_at = getattr(rec, "last_checkpoint_at", None)
        checkpoint = {
            "steps": getattr(rec, "checkpoints_taken", 0),
            "last_step": getattr(rec, "last_checkpoint_step", None),
            "age_s": round(now - last_at, 6) if last_at else None,
        }

    return {
        "generated_at": now,
        "wall_s": round(wall, 4),
        "workers": workers,
        "freshness": cluster.freshness(drain=False),
        "staleness": staleness,
        "serving": serving,
        "backlog": {"operational_lag": operational_lag,
                    "extraction_lag": extraction_lag,
                    "buffered": total_buffered},
        "commit_lag": commit_lag,
        "routing_epoch": pipe.current_routing().epoch,
        "cache": {"rows": total_cache_rows,
                  "retention_last_migration":
                      cluster.last_migration.get("cache_retention")
                      if cluster.last_migration else None},
        "checkpoint": checkpoint,
        "control": control,
        "mesh": mesh,
        "counters": merged_counters(pipe),
    }


def build_pipeline_health(pipe) -> Dict:
    """``DODETLPipeline.health()``: the sequential runtime's subset of
    the cluster schema (no stage threads, so queue depths / freshness
    lanes are absent; throughput comes from each worker's StageMetrics)."""
    now = time.perf_counter()
    assignment = _copy_assignment(pipe.assignment)
    group_of = {w.name: w.group for w in pipe.workers}

    workers: Dict[str, Dict] = {}
    total_buffered = 0
    total_cache_rows = 0
    for w in pipe.workers:
        buffered = len(w.buffer)
        cache_rows = w.equipment.n_rows + w.quality.n_rows
        total_buffered += buffered
        total_cache_rows += cache_rows
        workers[w.name] = {
            "partitions": sorted(p for p, o in assignment.items()
                                 if o == w.name),
            "records_done": w.metrics.records,
            "throughput_rps": round(w.metrics.rate, 3),
            "buffer": buffered,
            "cache_rows": cache_rows,
        }

    commit_lag = _commit_lags(pipe, assignment, group_of)
    operational_lag = sum(lag for lags in commit_lag.values()
                          for lag in lags.values())

    return {
        "generated_at": now,
        "workers": workers,
        "backlog": {"operational_lag": operational_lag,
                    "buffered": total_buffered},
        "commit_lag": commit_lag,
        "routing_epoch": pipe.current_routing().epoch,
        "cache": {"rows": total_cache_rows},
        "counters": merged_counters(pipe),
    }


__all__ = ["build_cluster_health", "build_pipeline_health",
           "merged_counters"]
