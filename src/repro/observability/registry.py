"""Lock-sharded metrics registry: one read path for every pipeline signal.

The write side follows ``LatencyRecorder``'s lock-the-list-never-the-math
discipline, taken one step further: each hot-path actor (a worker stage
thread, a backend instance, a broker topic) owns a private
``MetricsShard`` and increments plain Python ints on instrument handles
it resolved ONCE — no lock, no dict lookup, no contention on the hot
path. Locks guard only instrument-table mutation (first resolution of a
name) and shard-table mutation (first resolution of a shard); reads
merge shards on demand, summing same-named counters across shards, so
``registry.counters()["worker.cache_hits"]`` is the cluster total while
``per_shard()`` still shows each worker's share.

Instruments:

* ``Counter``   — monotone int, single-writer per shard (the shard owner
                  increments; cross-thread readers see a GIL-atomic int).
* ``Gauge``     — last-write-wins level, either pushed (``set``) or
                  pulled (``gauge_fn`` registers a zero-state callback
                  evaluated at read time — queue depths, buffer
                  occupancy, routing epochs cost nothing until read).
* histograms    — bounded-reservoir ``LatencyRecorder``s (capped memory,
                  deterministic down-sampling); same-named reservoirs
                  merge their samples on read so per-worker freshness
                  recorders aggregate to one cluster percentile.

Naming convention: instrument names are globally meaningful dotted paths
(``backend.jax.op_dispatches``, ``broker.production.published``); shards
exist purely for write-side contention isolation and carry the actor's
identity (``w0``, ``backend.jax#2``).

``GLOBAL_REGISTRY`` serves process-wide singletons (compute backends);
each ``DODETLPipeline`` owns its own registry so concurrent pipelines
and tests never cross-count.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

import numpy as np

if False:  # typing only — the runtime import is deferred (see _metrics)
    from repro.core.metrics import LatencyRecorder


def _metrics():
    """Deferred import of ``repro.core.metrics``: ``repro.core``'s package
    init pulls in the backend module, which imports THIS module for its
    dispatch counters — a module-level import here would be circular.
    Instrument creation happens long after both modules settle."""
    from repro.core import metrics
    return metrics


class Counter:
    """Monotone counter handle. Single-writer discipline: the owning
    shard's thread increments; anyone may read (int reads/writes are
    GIL-atomic, never torn)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Level instrument: either pushed via ``set`` or backed by a
    read-time callback (``fn``) so idle gauges cost nothing."""

    __slots__ = ("name", "value", "fn")

    def __init__(self, name: str, fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.value = 0.0
        self.fn = fn

    def set(self, v: float) -> None:
        self.value = v

    def read(self) -> float:
        if self.fn is not None:
            try:
                return float(self.fn())
            except Exception:
                return float("nan")
        return self.value


class MetricsShard:
    """One actor's private instrument table. Resolution (``counter``,
    ``gauge`` ...) is memoized and lock-guarded; the returned handles are
    then incremented lock-free by the owning thread."""

    def __init__(self, name: str, histogram_capacity: int = 1 << 16):
        self.name = name
        self._histogram_capacity = histogram_capacity
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, "LatencyRecorder"] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def gauge_fn(self, name: str, fn: Callable[[], float]) -> Gauge:
        """Register (or retarget) a pull-mode gauge evaluated at read
        time — the hot path never touches it."""
        g = self.gauge(name)
        g.fn = fn
        return g

    def histogram(self, name: str,
                  capacity: Optional[int] = None) -> "LatencyRecorder":
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.get(name)
                if h is None:
                    h = _metrics().LatencyRecorder(
                        capacity or self._histogram_capacity)
                    self._histograms[name] = h
        return h

    def register_histogram(self, name: str,
                           recorder: "LatencyRecorder") -> "LatencyRecorder":
        """Adopt an EXISTING recorder (e.g. a worker's freshness
        ``LatencyRecorder``) so the registry read path sees it without a
        second copy of the samples."""
        with self._lock:
            self._histograms[name] = recorder
        return recorder

    # ------------------------------------------------------------- read side
    def counter_values(self) -> Dict[str, int]:
        with self._lock:
            items = list(self._counters.items())
        return {name: c.value for name, c in items}

    def gauge_values(self) -> Dict[str, float]:
        with self._lock:
            items = list(self._gauges.items())
        return {name: g.read() for name, g in items}

    def histogram_items(self) -> List:
        with self._lock:
            return list(self._histograms.items())


class MetricsRegistry:
    """Shard table + merged read path. ``shard(name)`` hands an actor its
    private write surface; the read methods merge every shard on demand
    (sum for counters, sample-union for histograms, per-shard for
    gauges)."""

    def __init__(self, histogram_capacity: int = 1 << 16):
        self._histogram_capacity = histogram_capacity
        self._lock = threading.Lock()
        self._shards: Dict[str, MetricsShard] = {}

    def shard(self, name: str) -> MetricsShard:
        s = self._shards.get(name)
        if s is None:
            with self._lock:
                s = self._shards.get(name)
                if s is None:
                    s = MetricsShard(name, self._histogram_capacity)
                    self._shards[name] = s
        return s

    def shards(self) -> List[MetricsShard]:
        with self._lock:
            return list(self._shards.values())

    # ------------------------------------------------------------- read side
    def counters(self) -> Dict[str, int]:
        """Same-named counters summed across every shard — the cluster
        totals."""
        out: Dict[str, int] = {}
        for s in self.shards():
            for name, v in s.counter_values().items():
                out[name] = out.get(name, 0) + v
        return out

    def gauges(self) -> Dict[str, Dict[str, float]]:
        """Per-shard gauge values: ``{shard: {name: value}}`` (levels do
        not sum meaningfully across actors)."""
        return {s.name: s.gauge_values() for s in self.shards()
                if s.gauge_values()}

    def histogram_percentiles(self, name: str) -> Dict[str, float]:
        """p50/p95/p99 over the union of every shard's samples for one
        histogram name (non-draining)."""
        parts = [h.merged(drain=False)
                 for s in self.shards()
                 for hname, h in s.histogram_items() if hname == name]
        parts = [p for p in parts if len(p)]
        pm = _metrics().percentiles_ms
        if not parts:
            return pm(np.zeros(0, np.float64))
        return pm(np.concatenate(parts))

    def histogram_names(self) -> List[str]:
        names: List[str] = []
        for s in self.shards():
            for hname, _ in s.histogram_items():
                if hname not in names:
                    names.append(hname)
        return names

    def per_shard(self) -> Dict[str, Dict[str, Dict[str, float]]]:
        out: Dict[str, Dict[str, Dict[str, float]]] = {}
        for s in self.shards():
            out[s.name] = {"counters": s.counter_values(),
                           "gauges": s.gauge_values()}
        return out

    def snapshot(self) -> Dict[str, object]:
        """The one-read-path view: merged counters, per-shard gauges and
        merged histogram percentiles in a single JSON-able dict."""
        hists = {name: self.histogram_percentiles(name)
                 for name in self.histogram_names()}
        return {"counters": self.counters(), "gauges": self.gauges(),
                "histograms": hists, "per_shard": self.per_shard()}


# Process-wide registry: compute backends are process singletons, so their
# dispatch counters live here; per-pipeline signals live on the pipeline's
# own registry.
GLOBAL_REGISTRY = MetricsRegistry()


def global_registry() -> MetricsRegistry:
    return GLOBAL_REGISTRY


__all__ = ["Counter", "Gauge", "MetricsShard", "MetricsRegistry",
           "GLOBAL_REGISTRY", "global_registry"]
