"""Synthetic steelworks workload (paper §4.1: 'we built a sampler to insert
records on each database table ... 20,000 records at each table, simulating
the steelworks operation').

Deterministic given a seed. Master records (equipment status intervals,
quality inspections) and operational records (production runs) share
equipment units (= business keys) and prod_ids so the streaming join is
exercised, including out-of-order master arrival (late-buffer path).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.configs.dod_etl import ETLConfig
from repro.core.cdc import SourceDatabase
from repro.core.records import OP_INSERT, RecordBatch, make_batch


@dataclasses.dataclass
class SamplerConfig:
    records_per_table: int = 20_000
    n_equipment: int = 20            # business keys (paper: 20 units)
    late_master_frac: float = 0.05   # master rows arriving after their facts
    seed: int = 0
    zipf_s: float = 0.0              # business-key skew: production events
                                     # hit unit r with p ∝ 1/r^s (0 = the
                                     # original uniform round-robin) — a
                                     # few hot casters emitting most events


class SteelworksSampler:
    def __init__(self, etl_cfg: ETLConfig, cfg: SamplerConfig):
        self.etl = etl_cfg
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._tick = 1_000
        # Zipf unit-of-product map: a product line belongs to ONE unit for
        # the sampler's lifetime (hot casters stay hot across waves), and
        # the map is prefix-stable so streamed production waves agree with
        # the master rows generated earlier for the same prod_ids
        self._zipf_rng = np.random.default_rng((cfg.seed, 0x51))
        self._unit_of = np.zeros(0, np.int64)

    def _units_for(self, n: int, nunits: int) -> np.ndarray:
        if self.cfg.zipf_s <= 0:
            return (np.arange(n, dtype=np.int64) % nunits)
        if len(self._unit_of) < n:
            p = 1.0 / np.arange(1, nunits + 1) ** self.cfg.zipf_s
            extra = self._zipf_rng.choice(nunits, n - len(self._unit_of),
                                          p=p / p.sum())
            self._unit_of = np.concatenate(
                [self._unit_of, extra.astype(np.int64)])
        return self._unit_of[:n]

    def _times(self, n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        start = self._tick + np.arange(n) * 10
        dur = self.rng.integers(5, 50, n)
        self._tick += n * 10
        return start.astype(np.int64), (start + dur).astype(np.int64), \
            (start + dur + 1).astype(np.int64)

    def generate(self, source: SourceDatabase,
                 n_per_table: Optional[int] = None,
                 tables: Optional[Tuple[str, ...]] = None) -> int:
        """Insert n records per selected table into the source DB (through
        the transactional path, so the CDC log sees everything). Master rows
        for a fraction of prod_ids are withheld and inserted AFTER their
        production facts — the out-of-sync arrival of §3.2."""
        n = n_per_table or self.cfg.records_per_table
        names = [t.name for t in self.etl.tables]
        pick = tables or tuple(names)
        nunits = self.cfg.n_equipment

        prod_ids = np.arange(n, dtype=np.int64)
        equip = self._units_for(n, nunits)
        t_start, t_end, txn = self._times(n)
        qty = self.rng.uniform(10, 100, n).astype(np.float32)
        speed = self.rng.uniform(1, 5, n).astype(np.float32)

        total = 0
        late_cut = int(n * (1 - self.cfg.late_master_frac))

        def table_id(name): return names.index(name)

        # ---- master first (except the late tail), then operational,
        # then the late master tail (out-of-order arrival)
        def eq_batch(lo, hi, tshift=0):
            ids = np.arange(lo, hi, dtype=np.int64)
            e = ids % nunits
            # status intervals span the whole shift (overlap every production
            # window of the unit); planned productive time is the shift quota
            payload = np.stack([
                ids.astype(np.float32), e.astype(np.float32),
                (txn[lo:hi] + tshift).astype(np.float32),
                np.zeros(hi - lo, np.float32),                        # t_start
                np.full(hi - lo, 1e9, np.float32),                    # t_end
                (self.rng.random(hi - lo) > 0.2).astype(np.float32),  # status
                np.full(hi - lo, 4.0, np.float32),                    # max_speed
                np.full(hi - lo, 60.0, np.float32),                   # planned
            ], axis=-1)
            return make_batch(table_id(next(nm for nm in names
                                            if "equipment" in nm)),
                              OP_INSERT, ids, e, txn[lo:hi] + tshift, payload)

        def qual_batch(lo, hi, tshift=0):
            ids = np.arange(lo, hi, dtype=np.int64) + 10_000_000
            # a quality inspection belongs to the equipment that produced
            # its prod_id — under Zipf skew that is `equip`, so the row is
            # cached by the worker that processes the production record
            e = equip[lo:hi]
            payload = np.stack([
                ids.astype(np.float32), e.astype(np.float32),
                (txn[lo:hi] + tshift).astype(np.float32),
                np.arange(lo, hi, dtype=np.float32),                  # prod_id
                self.rng.integers(0, 5, hi - lo).astype(np.float32),  # defects
                self.rng.integers(1, 4, hi - lo).astype(np.float32),  # grade
                self.rng.integers(0, 3, hi - lo).astype(np.float32),  # scrap
                np.zeros(hi - lo, np.float32),
            ], axis=-1)
            return make_batch(table_id(next(nm for nm in names
                                            if "quality" in nm)),
                              OP_INSERT, ids, e, txn[lo:hi] + tshift, payload)

        def prod_batch(lo, hi):
            payload = np.stack([
                prod_ids[lo:hi].astype(np.float32),
                equip[lo:hi].astype(np.float32),
                txn[lo:hi].astype(np.float32),
                t_start[lo:hi].astype(np.float32),
                t_end[lo:hi].astype(np.float32),
                qty[lo:hi], speed[lo:hi],
                prod_ids[lo:hi].astype(np.float32),                  # order id
            ], axis=-1)
            return make_batch(table_id(next(nm for nm in names
                                            if "production" in nm)),
                              OP_INSERT, prod_ids[lo:hi], equip[lo:hi],
                              txn[lo:hi], payload)

        has = lambda kind: any(kind in nm for nm in pick)
        if has("equipment"):
            source.apply(eq_batch(0, late_cut))
            total += late_cut
        if has("quality"):
            source.apply(qual_batch(0, late_cut))
            total += late_cut
        if has("production"):
            source.apply(prod_batch(0, n))
            total += n
        # late master tail (arrives after its production facts)
        if has("equipment"):
            source.apply(eq_batch(late_cut, n, tshift=1000))
            total += n - late_cut
        if has("quality"):
            source.apply(qual_batch(late_cut, n, tshift=1000))
            total += n - late_cut
        # duplicate the remaining ISA-95-style normalized tables if present
        for nm in pick:
            if nm not in names:
                continue
            if ("segment" in nm or "event" in nm or "detail" in nm) and \
                    "production" not in nm:
                tid = table_id(nm)
                ids = np.arange(n, dtype=np.int64) + tid * 50_000_000
                payload = np.tile(np.arange(8, dtype=np.float32), (n, 1))
                payload[:, 1] = equip.astype(np.float32)
                payload[:, 2] = txn.astype(np.float32)
                payload[:, 3] = prod_ids.astype(np.float32)
                source.apply(make_batch(tid, OP_INSERT, ids, equip, txn,
                                        payload))
                total += n
        return total


def synthetic_facts(rng: np.random.Generator, n: int, n_units: int,
                    valid_frac: float = 1.0) -> np.ndarray:
    """Random fact rows in the transformer's output layout
    (``repro.core.transformer.FACT_COLUMNS``: col 0 unit, 1-2 window,
    3-6 KPIs, 7-8 on/off segments, 9 valid flag) — the serving-layer
    tests' and benchmarks' direct-to-warehouse workload, bypassing the
    pipeline when only the read side is under test."""
    f = np.zeros((n, 10), np.float32)
    f[:, 0] = rng.integers(0, n_units, n)
    f[:, 1] = rng.uniform(0, 60_000, n)
    f[:, 2] = f[:, 1] + rng.uniform(1, 50, n)
    f[:, 3:7] = rng.uniform(0, 1, (n, 4))
    f[:, 7] = rng.uniform(0, 40, n)
    f[:, 8] = rng.uniform(0, 40, n)
    f[:, 9] = (rng.random(n) <= valid_frac).astype(np.float32)
    return f
