"""Abstract input construction for every (architecture x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for the arguments of the step function a cell
lowers: ``train_step`` for train shapes, ``prefill_step`` for prefill,
``decode_step`` for decode/long shapes. ``input_pspecs`` returns the
matching PartitionSpec trees.

Modality frontends are stubs per the assignment: whisper cells carry
precomputed conv-stem frame embeddings [B, 1500, d_model]; qwen2-vl text
cells carry token ids plus the 3-stream M-RoPE position ids.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSuite
from repro.models.model import Model
from repro.models.param import ParamDef, tree_abstract, tree_specs


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, suite: ShapeSuite
                      ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    b, s = suite.global_batch, suite.seq_len
    batch = {
        "tokens": sds((b, s), jnp.int32),
        "targets": sds((b, s), jnp.int32),
    }
    axes = {
        "tokens": ("batch", None),
        "targets": ("batch", None),
    }
    if cfg.family == "encdec":
        batch["frames"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        axes["frames"] = ("batch", None, "act_embed")
    if cfg.pos_scheme == "mrope":
        batch["positions"] = sds((b, s, 3), jnp.int32)
        axes["positions"] = ("batch", None, None)
    return batch, axes


def decode_inputs(model: Model, suite: ShapeSuite):
    """(cache, token, index) abstract values + logical axes for decode."""
    cfg = model.cfg
    b = suite.global_batch
    cache_defs = model.cache_defs(b, suite.seq_len)
    cache = tree_abstract(cache_defs)
    token = sds((b, 1), jnp.int32)
    index = sds((), jnp.int32)
    token_axes = ("batch", None)
    return cache_defs, cache, token, index, token_axes


def batch_pspecs(axes_tree, rules) -> Any:
    def one(axes):
        return P(*(rules.get(a, None) for a in axes))
    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))
