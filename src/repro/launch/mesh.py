"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run entrypoint sets
``--xla_force_host_platform_device_count=512`` *before* importing jax; smoke
tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import os
import sys
from typing import Optional, Tuple

import jax

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def jax_initialized() -> bool:
    """True iff the jax runtime has already created a backend client.

    Probes ``jax._src.xla_bridge``'s backend cache without triggering
    initialization itself (calling ``jax.devices()`` here would *cause*
    the very initialization we are trying to detect).
    """
    xb = getattr(getattr(jax, "_src", None), "xla_bridge", None)
    if xb is None:                       # private layout moved: assume the
        return True                      # worst so callers fail loudly
    for attr in ("_backends", "_default_backend"):
        state = getattr(xb, attr, None)
        if state:
            return True
    return False


def virtual_devices(n: int) -> int:
    """Force ``n`` virtual host (CPU) devices so tests/benchmarks can build
    a >= 4-device mesh on one machine.

    Appends ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``.
    The flag is only read at jax backend initialization, so this MUST run
    before anything touches jax device state; if jax is already initialized
    the flag would be silently ignored — we refuse with a clear error
    instead (callers then re-exec in a subprocess with the env var set,
    the way ``tests/test_shard_plane.py`` drills the 4-device mesh).

    Returns ``n`` so call sites can assert the requested count.
    """
    if n < 1:
        raise ValueError(f"virtual_devices({n}): need n >= 1")
    if jax_initialized():
        raise RuntimeError(
            "virtual_devices(%d): jax is already initialized in this "
            "process, so %s would be ignored. Set "
            "XLA_FLAGS=%s=%d in the environment and re-exec (or run the "
            "caller in a fresh subprocess) before jax is imported."
            % (n, _FORCE_FLAG, _FORCE_FLAG, n))
    flags = os.environ.get("XLA_FLAGS", "")
    kept = [f for f in flags.split() if not f.startswith(_FORCE_FLAG + "=")]
    kept.append(f"{_FORCE_FLAG}={n}")
    os.environ["XLA_FLAGS"] = " ".join(kept)
    return n


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use e.g. (2, 2) with 4 forced host devices)."""
    return jax.make_mesh(shape, axes)


def make_shard_mesh(n_shards: int):
    """1-D serving-plane mesh: one axis named "shards", one device per
    warehouse/view shard (``repro.runtime.shard_plane``)."""
    return jax.make_mesh((n_shards,), ("shards",))


def mesh_devices(mesh) -> int:
    return mesh.devices.size
