"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run entrypoint sets
``--xla_force_host_platform_device_count=512`` *before* importing jax; smoke
tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use e.g. (2, 2) with 4 forced host devices)."""
    return jax.make_mesh(shape, axes)


def mesh_devices(mesh) -> int:
    return mesh.devices.size
