"""Post-compilation HLO analysis.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body exactly once —
useless for scan-over-layers programs — and carries no collective
information. This module parses the optimized (SPMD-partitioned, per-device)
HLO text directly and builds a TPU-oriented cost model:

  * call-graph multiplicities from ``backend_config known_trip_count``
    (lax.scan lowers to whiles that carry exact trip counts),
  * dot FLOPs = 2 * prod(result dims) * prod(contracting dims),
  * HBM traffic counted at materialization boundaries only (dots, fusions,
    copies, reduces, slices, collectives). Fusion operand traffic is
    *slice-aware*: an operand that the fused computation consumes only
    through (dynamic-)slices contributes the slice bytes, not the full
    buffer — critical for scan-stacked layer parameters,
  * collective wire bytes per kind with ring-algorithm multipliers.

All numbers are per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict, deque
from typing import Dict, List, Optional, Tuple

# TPU v5e hardware model (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (conservative single-link)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s4": 0.5, "u4": 0.5,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z]\w*?)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_BRACE_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SLICE_OPS = {"dynamic-slice", "slice"}
_TRAFFIC_OPS = {
    "dot", "convolution", "fusion", "copy", "reduce", "reduce-window",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "concatenate", "pad", "sort", "select-and-scatter", "fft",
    "triangular-solve", "cholesky", "rng", "rng-bit-generator", "transpose",
}


def _first_shape(text: str) -> Tuple[Optional[str], Optional[List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None, None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def _shape_bytes(dt: Optional[str], dims: Optional[List[int]]) -> float:
    if dt is None:
        return 0.0
    n = float(math.prod(dims)) if dims else 1.0
    return n * _DTYPE_BYTES.get(dt, 0)


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _BRACE_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _wire_multiplier(kind: str, n: int) -> float:
    """Per-device ring wire bytes as a multiple of the RESULT size."""
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind == "all-gather":
        return (n - 1) / n          # result is the gathered buffer
    if kind == "reduce-scatter":
        return float(n - 1)         # result is the local shard
    if kind in ("all-to-all", "ragged-all-to-all"):
        return (n - 1) / n
    if kind == "collective-permute":
        return 1.0
    return 1.0


class _Computation:
    __slots__ = ("name", "flops", "collectives", "calls", "fusion_callees",
                 "param_order", "param_bytes", "param_slice_bytes",
                 "param_full", "traffic", "alias_map")

    def __init__(self, name: str):
        self.name = name
        self.flops = 0.0
        self.collectives: List[Tuple[str, float, int]] = []
        self.calls: List[Tuple[str, float]] = []
        self.fusion_callees: List[str] = []
        self.param_order: List[str] = []           # parameter(i) names, by i
        self.param_bytes: Dict[str, float] = {}
        self.param_slice_bytes: Dict[str, float] = defaultdict(float)
        self.param_full: Dict[str, bool] = {}
        self.alias_map: Dict[str, str] = {}        # view name -> param name
        # traffic records: (op, result_bytes, [(operand, bytes)]) OR
        # ("fusion:<callee>", result_bytes, [(operand, bytes)])
        self.traffic: List[Tuple[str, float, List[Tuple[str, float]]]] = []


def _op_kind(rhs: str) -> str:
    i = 0
    if rhs.startswith("("):
        depth = 0
        for j, c in enumerate(rhs):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    i = j + 1
                    break
    else:
        sp = rhs.find(" ")
        i = sp + 1 if sp != -1 else 0
    rest = rhs[i:].lstrip()
    m = re.match(r"([\w\-]+)\(", rest)
    return m.group(1) if m else ""


def parse_hlo(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    entry: Optional[str] = None
    cur: Optional[_Computation] = None
    symbols: Dict[str, float] = {}

    for raw in text.splitlines():
        stripped = raw.strip()
        if not stripped:
            continue
        if stripped.endswith("{") and " -> " in stripped and " = " not in \
                stripped.split(" -> ")[0]:
            mc = _COMP_RE.match(stripped)
            if mc:
                cur = _Computation(mc.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                symbols = {}
                continue
        if cur is None:
            continue
        md = _DEF_RE.match(stripped)
        if not md:
            continue
        name, rhs = md.group(1), md.group(2)
        dt, dims = _first_shape(rhs)
        rbytes = _shape_bytes(dt, dims)
        symbols[name] = rbytes
        op = _op_kind(rhs)

        # operand names (inside the first paren group)
        opnds: List[str] = []
        paren = rhs.find("(")
        if paren != -1:
            opnds = _OPND_RE.findall(rhs[paren + 1:].split(")")[0])

        # ---- parameters (for slice-aware fusion operand traffic)
        if op == "parameter":
            cur.param_order.append(name)
            cur.param_bytes[name] = rbytes
            cur.param_full[name] = False
        else:
            for oi, o in enumerate(opnds):
                root = cur.alias_map.get(o, o)
                if root in cur.param_bytes:
                    if op in _SLICE_OPS:
                        cur.param_slice_bytes[root] += rbytes
                    elif op == "dynamic-update-slice" and oi == 0:
                        # in-place window write: charge the update size
                        upd = symbols.get(opnds[1], 0.0) if len(opnds) > 1                             else 0.0
                        cur.param_slice_bytes[root] += 2.0 * upd
                        cur.alias_map[name] = root
                    elif op in ("get-tuple-element", "bitcast", "reshape",
                                "transpose", "copy"):
                        # aliasing / relayout view: track back to the param
                        cur.alias_map[name] = root
                    else:
                        cur.param_full[root] = True

        # ---- call edges
        if op == "while":
            trips = 1.0
            mt = _TRIP_RE.search(rhs)
            if mt:
                trips = float(mt.group(1))
            mb = re.search(r"body=%([\w.\-]+)", rhs)
            mcnd = re.search(r"condition=%([\w.\-]+)", rhs)
            if mb:
                cur.calls.append((mb.group(1), trips))
            if mcnd:
                cur.calls.append((mcnd.group(1), trips + 1))
        elif op == "fusion":
            mfc = re.search(r"calls=%([\w.\-]+)", rhs)
            if mfc:
                cur.calls.append((mfc.group(1), 1.0))
                cur.fusion_callees.append(mfc.group(1))
                cur.traffic.append((f"fusion:{mfc.group(1)}", rbytes,
                                    [(o, symbols.get(o, 0.0)) for o in opnds]))
        elif op == "call":
            mtc = re.search(r"to_apply=%([\w.\-]+)", rhs)
            if mtc:
                cur.calls.append((mtc.group(1), 1.0))
        elif op == "conditional":
            for mb2 in re.finditer(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"true_computation=%([\w.\-]+)|"
                    r"false_computation=%([\w.\-]+))", rhs):
                if mb2.group(1):
                    for nm in _OPND_RE.findall(mb2.group(1)):
                        cur.calls.append((nm, 1.0))
                else:
                    cur.calls.append((mb2.group(2) or mb2.group(3), 1.0))
        elif "to_apply=" in rhs:
            mta = re.search(r"to_apply=%([\w.\-]+)", rhs)
            if mta:
                cur.calls.append((mta.group(1), 1.0))
                cur.fusion_callees.append(mta.group(1))  # scalar applier

        # ---- dot flops
        if op == "dot":
            contract = 1.0
            mctr = _CONTRACT_RE.search(rhs)
            if mctr and opnds:
                # need lhs operand dims: re-find its shape record
                pass
            cur.traffic.append(("dot", rbytes,
                                [(o, symbols.get(o, 0.0)) for o in opnds]))

        # ---- collectives (count at -start; skip -done)
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVE_KINDS and not op.endswith("-done"):
            if op.endswith("-start"):
                result_type = rhs.split(op + "(")[0]
                sizes = [_shape_bytes(d2, [int(x) for x in s2.split(",")]
                                      if s2 else [])
                         for d2, s2 in _SHAPE_RE.findall(result_type)]
                if not sizes:
                    cb = 0.0
                elif base == "all-gather":
                    cb = max(sizes)
                elif base == "reduce-scatter":
                    cb = min(sizes)
                else:
                    cb = sizes[-1]
            else:
                cb = rbytes
            cur.collectives.append((base, cb, _group_size(rhs)))
            cur.traffic.append((base, cb, []))   # HBM side of the collective

        # ---- other traffic boundaries
        if op in _TRAFFIC_OPS and op != "fusion" and op != "dot":
            if op in _SLICE_OPS:
                cur.traffic.append((op, 2.0 * rbytes, []))
            elif op == "dynamic-update-slice":
                known = [symbols[o] for o in opnds[1:] if o in symbols]
                upd = min(known) if known else rbytes / 16.0
                cur.traffic.append((op, 2.0 * upd, []))
            else:
                cur.traffic.append((op, rbytes,
                                    [(o, symbols.get(o, 0.0)) for o in opnds]))

    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


# dot flops need operand shapes; easiest done in a second pass with a global
# regex over def lines per computation. To keep one-pass parsing simple we
# re-scan the text for dots only.
_DOT_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*([a-z]\w*)\[([\d,]*)\][^=]*?dot\("
    r"%([\w.\-]+),\s*%([\w.\-]+)\),\s*lhs_batch_dims=\{([\d,]*)\}.*?"
    r"lhs_contracting_dims=\{([\d,]*)\}", )
# operands may carry inline types ("dot(f32[64,128]{1,0} %a, ...)" in
# newer HLO dumps) or be bare ("dot(%a, ...)")
_TYPED_OPND = r"(?:[a-z]\w*\[[\d,]*\](?:\{[\d,]*\})?\s+)?"
_DOT_SIMPLE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*([a-z]\w*)\[([\d,]*)\]\S*\s+dot\("
    + _TYPED_OPND + r"%([\w.\-]+),\s*"
    + _TYPED_OPND + r"%([\w.\-]+)\)(.*)$")


def _dot_flops_pass(text: str, comps: Dict[str, _Computation]) -> None:
    """Second pass: exact dot FLOPs (needs operand shapes)."""
    cur_name: Optional[str] = None
    symbols: Dict[str, List[int]] = {}
    for raw in text.splitlines():
        stripped = raw.strip()
        if stripped.endswith("{") and " -> " in stripped and " = " not in \
                stripped.split(" -> ")[0]:
            mc = _COMP_RE.match(stripped)
            if mc:
                cur_name = mc.group(1)
                symbols = {}
                continue
        md = _DEF_RE.match(stripped)
        if not md or cur_name is None:
            continue
        name, rhs = md.group(1), md.group(2)
        dt, dims = _first_shape(rhs)
        symbols[name] = dims or []
        if " dot(" not in rhs and not rhs.startswith("dot("):
            continue
        mres = _DOT_SIMPLE_RE.match(stripped)
        if not mres:
            continue
        rdims = [int(x) for x in mres.group(2).split(",")] if mres.group(2) else []
        lhs = mres.group(3)
        tail = mres.group(5)
        mc2 = _CONTRACT_RE.search(tail)
        contract = 1.0
        lhs_dims = symbols.get(lhs, [])
        if mc2 and mc2.group(1):
            for d in mc2.group(1).split(","):
                di = int(d)
                if di < len(lhs_dims):
                    contract *= lhs_dims[di]
        flops = 2.0 * float(math.prod(rdims) if rdims else 1) * contract
        comp = comps.get(cur_name)
        if comp is not None:
            comp.flops += flops


def _multiplicities(comps: Dict[str, _Computation]) -> Dict[str, float]:
    entry = comps.get("__entry__")
    incoming: Dict[str, float] = defaultdict(float)
    if entry is None:
        return incoming
    edges = {n: comps[n].calls for n in comps if n != "__entry__"}
    indeg: Dict[str, int] = defaultdict(int)
    for n, es in edges.items():
        for callee, _ in es:
            if callee in comps:
                indeg[callee] += 1
    dq = deque([entry.name])
    incoming[entry.name] = 1.0
    done = set()
    while dq:
        n = dq.popleft()
        if n in done:
            continue
        done.add(n)
        for callee, m in edges.get(n, []):
            if callee not in comps:
                continue
            incoming[callee] += incoming[n] * m
            indeg[callee] -= 1
            if indeg[callee] <= 0:
                dq.append(callee)
    return incoming


def _param_traffic(comp: _Computation) -> List[float]:
    """Per-parameter effective read bytes for a fusion body."""
    out = []
    for p in comp.param_order:
        full = comp.param_bytes.get(p, 0.0)
        if comp.param_full.get(p, False):
            out.append(full)
        else:
            out.append(min(comp.param_slice_bytes.get(p, 0.0), full))
    return out


def analyze_hlo(text: str) -> Dict[str, object]:
    comps = parse_hlo(text)
    _dot_flops_pass(text, comps)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {}}
    incoming = _multiplicities(comps)

    bytes_free = set()
    for c in comps.values():
        bytes_free.update(c.fusion_callees)
    grew = True
    while grew:
        grew = False
        for name in list(bytes_free):
            c = comps.get(name)
            if c is None:
                continue
            for callee in c.fusion_callees:
                if callee not in bytes_free:
                    bytes_free.add(callee)
                    grew = True

    flops = 0.0
    byts = 0.0
    colls: Dict[str, Dict[str, float]] = {}
    for name, c in comps.items():
        if name == "__entry__":
            continue
        m = incoming.get(name, 0.0)
        if m <= 0:
            continue
        flops += c.flops * m
        if name not in bytes_free:
            local = 0.0
            for kind, rbytes, opnds in c.traffic:
                if kind.startswith("fusion:"):
                    body = comps.get(kind.split(":", 1)[1])
                    if body is not None:
                        pt = _param_traffic(body)
                        # match operands positionally with body params
                        ops_b = 0.0
                        for i, (oname, obytes) in enumerate(opnds):
                            eff = pt[i] if i < len(pt) else obytes
                            ops_b += min(eff, obytes) if obytes else eff
                        local += rbytes + ops_b
                    else:
                        local += rbytes + sum(ob for _, ob in opnds)
                else:
                    local += rbytes + sum(ob for _, ob in opnds)
            byts += local * m
        for kind, cb, n in c.collectives:
            rec = colls.setdefault(kind, {"count": 0.0, "result_bytes": 0.0,
                                          "wire_bytes": 0.0, "max_group": 0})
            rec["count"] += m
            rec["result_bytes"] += cb * m
            rec["wire_bytes"] += cb * _wire_multiplier(kind, n) * m
            rec["max_group"] = max(rec["max_group"], n)
    return {"flops": flops, "bytes": byts, "collectives": colls}


def roofline_terms(*, global_flops: float, device_bytes: float,
                   collective_wire_bytes: float, n_chips: int
                   ) -> Dict[str, object]:
    """Three roofline terms in seconds per step (per-chip denominators)."""
    t_compute = global_flops / (n_chips * PEAK_FLOPS_BF16)
    t_memory = device_bytes / HBM_BW
    t_collective = collective_wire_bytes / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)), key=lambda kv: kv[1])[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "bound_s": max(t_compute, t_memory, t_collective),
    }
