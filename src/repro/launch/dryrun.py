import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell against the production mesh and extract memory/cost/collective
analysis. MUST be run as its own process (the two lines above must execute
before jax initializes devices):

    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]

Exit code 0 and a JSON artifact on success; "skipped" cells (decode for
encoder-only archs, long_500k for quadratic-attention archs) emit a JSON
with status=skipped.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPE_SUITES, get_config
from repro.launch import specs as S
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.models.param import tree_abstract, tree_specs
from repro.models.sharding import ShardingCtx, default_rules
from repro.optim import AdamWConfig, abstract_state
from repro.train.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import make_train_step


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape: str, *, multi_pod: bool,
               overrides: Dict[str, Any] | None = None):
    """Returns (jitted_fn, abstract_args, meta) for one dry-run cell."""
    overrides = dict(overrides) if overrides else {}
    fsdp = bool(overrides.pop("fsdp", False))
    sp = bool(overrides.pop("seq_parallel", False))
    dp_mode = overrides.pop("dp_mode", "auto")
    second_matmul = overrides.pop("second_matmul", "row")
    moe_group = overrides.pop("moe_group", None)
    moe_cap = overrides.pop("moe_capacity", None)
    cfg = get_config(arch)
    if cfg.moe is not None and (moe_group or moe_cap):
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe,
            group_size=moe_group or cfg.moe.group_size,
            capacity_factor=moe_cap or cfg.moe.capacity_factor))
    if cfg.param_count() > 2e10 and shape == "train_4k" \
            and "microbatches" not in overrides:
        # 20B+ models: halve per-microbatch activation footprint
        overrides["microbatches"] = 16
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    suite = SHAPE_SUITES[shape]

    # ---- applicability (assignment rules)
    if suite.kind == "decode" and not cfg.supports_decode:
        return None, None, {"status": "skipped", "reason": "no decode path"}
    if suite.name == "long_500k" and not cfg.subquadratic:
        return None, None, {"status": "skipped",
                            "reason": "quadratic attention at 500k"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    model_axis = 16
    long_ctx = suite.name == "long_500k"
    if suite.kind == "train":
        # Plain TP + ZeRO-1 moments + microbatching by default. GSPMD's dot
        # partitioner turns seq-sharded residuals into per-layer FULL-WEIGHT
        # all-gathers (measured: 3.2 TB/step on the 33B cell), so SP is a
        # per-cell override, not the default — see EXPERIMENTS.md §Perf.
        rules = default_rules(multi_pod, fsdp=fsdp, seq_parallel=sp,
                              second_matmul=second_matmul)
    else:
        rules = default_rules(multi_pod, second_matmul=second_matmul)
    if long_ctx:
        # B=1 cannot shard over data; shard the KV sequence there instead
        rules["batch"] = None
        rules["kv_seq"] = "data"
    elif suite.kind in ("decode", "prefill") and cfg.n_kv_heads < model_axis:
        # kv_heads cannot absorb the model axis -> shard cache seq over it
        # (otherwise the cache replicates model_axis-fold and decode OOMs)
        rules["kv_seq"] = "model"
    ctx = ShardingCtx(mesh=mesh, rules=rules)
    model = Model(cfg)

    param_specs = model.specs(rules, mesh)
    param_sh = _named(mesh, param_specs)
    abstract_params = model.abstract()

    meta = {
        "status": "ok", "arch": arch, "shape": shape,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "n_chips": mesh.devices.size,
        "params": model_param_count(abstract_params),
        "active_params": cfg.active_param_count(),
        "kind": suite.kind,
    }

    if suite.kind == "train":
        opt_cfg = AdamWConfig()
        batch, batch_axes = S.train_batch_specs(cfg, suite)
        meta["tokens_per_step"] = suite.global_batch * suite.seq_len
        if dp_mode == "manual":
            from repro.train.manual_dp import make_manual_dp_train_step
            jitted, opt_specs, _ = make_manual_dp_train_step(
                model, opt_cfg, mesh, rules, batch_axes,
                multi_pod=multi_pod)
            opt_abs = abstract_state(abstract_params)
            meta["dp_mode"] = "manual"
            return jitted, (abstract_params, opt_abs, batch), meta
        # auto (pure GSPMD): ZeRO moments + per-microbatch reduced grads
        zero_rules = dict(rules)
        zero_rules["embed"] = ("pod", "data") if multi_pod else "data"
        moment_specs = model.specs(zero_rules, mesh)
        grad_specs = moment_specs
        opt_specs = type(abstract_state(abstract_params))(
            step=P(), mu=moment_specs, nu=jax.tree.map(lambda x: x,
                                                       moment_specs))
        batch_specs = S.batch_pspecs(batch_axes, rules)
        step_fn = make_train_step(model, opt_cfg, ctx, grad_specs=grad_specs)
        jitted = jax.jit(
            step_fn,
            in_shardings=(param_sh, _named(mesh, opt_specs),
                          _named(mesh, batch_specs)),
            out_shardings=(param_sh, _named(mesh, opt_specs), None),
            donate_argnums=(0, 1))
        args = (abstract_params, abstract_state(abstract_params), batch)
        meta["dp_mode"] = "auto"
        return jitted, args, meta

    if suite.kind == "prefill":
        batch, batch_axes = S.train_batch_specs(cfg, suite)
        batch.pop("targets")
        batch_axes.pop("targets")
        batch_specs = S.batch_pspecs(batch_axes, rules)
        cache_defs = model.cache_defs(suite.global_batch, suite.seq_len)
        cache_specs = tree_specs(cache_defs, rules, mesh)
        step_fn = make_prefill_step(model, ctx)
        jitted = jax.jit(
            step_fn,
            in_shardings=(param_sh, _named(mesh, batch_specs)),
            out_shardings=(None, _named(mesh, cache_specs)))
        meta["tokens_per_step"] = suite.global_batch * suite.seq_len
        return jitted, (abstract_params, batch), meta

    # ---- decode
    cache_defs, cache, token, index, token_axes = S.decode_inputs(model, suite)
    cache_specs = tree_specs(cache_defs, rules, mesh)
    token_spec = S.batch_pspecs({"t": token_axes}, rules)["t"]
    step_fn = make_decode_step(model, ctx)
    jitted = jax.jit(
        step_fn,
        in_shardings=(param_sh, _named(mesh, cache_specs),
                      NamedSharding(mesh, token_spec),
                      NamedSharding(mesh, P())),
        out_shardings=(None, None, _named(mesh, cache_specs)),
        donate_argnums=(1,))
    meta["tokens_per_step"] = suite.global_batch
    return jitted, (abstract_params, cache, token, index), meta


def model_param_count(abstract_params) -> int:
    return int(sum(x.size for x in jax.tree.leaves(abstract_params)))


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: str,
             skip_hlo: bool = False, tag: str = "",
             overrides: Dict[str, Any] | None = None) -> Dict[str, Any]:
    t0 = time.time()
    jitted, args, meta = build_cell(arch, shape, multi_pod=multi_pod,
                                    overrides=overrides)
    result = dict(meta)
    if meta["status"] == "skipped":
        _write(out_dir, arch, shape, multi_pod, result, tag)
        return result
    try:
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        result["lower_s"] = round(t1 - t0, 2)
        result["compile_s"] = round(t2 - t1, 2)

        # ---- memory analysis (per-device)
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                result["memory"] = {
                    k: int(getattr(ma, k)) for k in
                    ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes")
                    if hasattr(ma, k)}
                live = (result["memory"].get("argument_size_in_bytes", 0)
                        + result["memory"].get("output_size_in_bytes", 0)
                        + result["memory"].get("temp_size_in_bytes", 0)
                        - result["memory"].get("alias_size_in_bytes", 0))
                result["memory"]["peak_estimate_bytes"] = int(live)
        except Exception as e:  # pragma: no cover
            result["memory_error"] = str(e)

        # ---- raw XLA cost analysis (per-device, while bodies counted ONCE —
        # kept for reference; the trip-count-corrected numbers below are the
        # roofline source)
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            result["xla_cost_raw"] = {
                "flops_per_device": float(ca.get("flops", 0.0)),
                "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
            }
        except Exception as e:  # pragma: no cover
            result["cost_error"] = str(e)

        # ---- trip-count-corrected analysis of the partitioned HLO
        hlo = compiled.as_text()
        result["hlo_chars"] = len(hlo)
        analysis = analyze_hlo(hlo)
        del hlo
        result["cost"] = {
            "flops_per_device": analysis["flops"],
            "bytes_per_device": analysis["bytes"],
        }
        result["collectives"] = analysis["collectives"]

        # ---- roofline
        n = meta["n_chips"]
        flops_dev = analysis["flops"]
        bytes_dev = analysis["bytes"]
        wire = sum(c["wire_bytes"] for c in analysis["collectives"].values())
        operand = sum(c["result_bytes"] for c in analysis["collectives"].values())
        result["collective_wire_bytes_per_device"] = wire
        result["collective_result_bytes_per_device"] = operand
        result["roofline"] = roofline_terms(
            global_flops=flops_dev * n, device_bytes=bytes_dev,
            collective_wire_bytes=wire, n_chips=n)
        # model flops: 6*N_active*D train, 2*N_active*D inference
        mult = 6 if meta["kind"] == "train" else 2
        result["model_flops"] = mult * meta["active_params"] * meta["tokens_per_step"]
        hlo_total = flops_dev * n
        result["model_flops_ratio"] = (result["model_flops"] / hlo_total
                                       if hlo_total else None)
    except Exception as e:
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    result["total_s"] = round(time.time() - t0, 2)
    _write(out_dir, arch, shape, multi_pod, result, tag)
    return result


def _write(out_dir, arch, shape, multi_pod, result, tag=""):
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "multipod" if multi_pod else "pod"
    safe_arch = arch.replace(".", "p").replace("/", "_")
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(out_dir, f"{safe_arch}__{shape}__{mesh_tag}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPE_SUITES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (int/float/bool)")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = json.loads(v)
    res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   out_dir=args.out, skip_hlo=args.skip_hlo, tag=args.tag,
                   overrides=overrides or None)
    status = res["status"]
    brief = {k: res.get(k) for k in
             ("status", "compile_s", "model_flops_ratio", "error")}
    if "roofline" in res:
        brief.update(res["roofline"])
    if "memory" in res:
        brief["peak_bytes_per_dev"] = res["memory"].get("peak_estimate_bytes")
    print(json.dumps({"arch": args.arch, "shape": args.shape,
                      "multi_pod": args.multi_pod, **brief}))
    raise SystemExit(0 if status in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
