from repro.models.model import Model, build_model  # noqa: F401
from repro.models.sharding import ShardingCtx, default_rules, NULL_CTX  # noqa: F401
