"""Unified model API over all assigned architecture families.

``Model(cfg)`` builds a ParamDef tree once; from it we derive materialized
params (CPU tests), abstract params (dry-run), and PartitionSpecs (launch).
``forward`` covers three modes:

  train   — full-sequence causal LM (or enc-dec) forward, returns logits
  prefill — like train but also returns a populated KV/state cache
  decode  — one token against a donated cache

All stacks ``lax.scan`` over stacked layer params so HLO size is
depth-independent; per-layer bodies are optionally ``jax.checkpoint``-ed.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.layers import embed, sinusoidal_pos, unembed
from repro.models.param import ParamDef, tree_abstract, tree_init, tree_specs
from repro.models.sharding import NULL_CTX, ShardingCtx


def _positions_for(cfg: ModelConfig, b: int, s: int, offset) -> Optional[jax.Array]:
    if cfg.pos_scheme == "mrope":
        pos = offset + jnp.arange(s, dtype=jnp.int32)
        pos = jnp.broadcast_to(pos[None, :, None], (b, s, 3))
        return pos
    if cfg.pos_scheme in ("rope",):
        pos = offset + jnp.arange(s, dtype=jnp.int32)
        return jnp.broadcast_to(pos[None], (b, s))
    return None


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.defs = self._build_defs()

    # ------------------------------------------------------------------ defs
    def _build_defs(self):
        cfg = self.cfg
        d = {
            "embed": ParamDef((cfg.padded_vocab, cfg.d_model),
                              ("vocab", "embed"), scale=1.0),
            "final_norm": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        }
        if not cfg.tie_embeddings:
            d["unembed"] = ParamDef((cfg.padded_vocab, cfg.d_model),
                                    ("vocab", "embed"))
        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            d["layers"] = blocks.decoder_block_defs(cfg, cfg.n_layers)
        elif fam == "ssm":
            d["layers"] = blocks.rwkv6_block_defs(cfg, cfg.n_layers)
        elif fam == "hybrid":
            d["layers"] = blocks.mamba2_block_defs(cfg, cfg.n_layers)
            shared_cfg = cfg  # same dims for the shared attention block
            d["shared"] = {
                "fuse": ParamDef((2 * cfg.d_model, cfg.d_model),
                                 (None, "embed")),
                "ln1": ParamDef((cfg.d_model,), ("embed",), init="ones"),
                "attn": blocks.attn_defs(shared_cfg, None),
                "ln2": ParamDef((cfg.d_model,), ("embed",), init="ones"),
                "mlp": {
                    "w_gate": ParamDef((cfg.d_model, cfg.d_ff), ("embed", "ff")),
                    "w_up": ParamDef((cfg.d_model, cfg.d_ff), ("embed", "ff")),
                    "w_down": ParamDef((cfg.d_ff, cfg.d_model), ("ff", "embed")),
                },
            }
        elif fam == "encdec":
            d["enc_layers"] = blocks.encoder_block_defs(cfg, cfg.n_enc_layers)
            d["enc_final_w"] = ParamDef((cfg.d_model,), ("embed",), init="ones")
            d["enc_final_b"] = ParamDef((cfg.d_model,), ("embed",), init="zeros")
            d["layers"] = blocks.decoder_xattn_block_defs(cfg, cfg.n_layers)
        else:
            raise ValueError(fam)
        return d

    # -------------------------------------------------------------- params
    def init(self, key) -> Any:
        return tree_init(self.defs, key)

    def abstract(self) -> Any:
        return tree_abstract(self.defs)

    def specs(self, rules, mesh=None) -> Any:
        return tree_specs(self.defs, rules, mesh)

    # --------------------------------------------------------------- caches
    def n_shared_apps(self) -> int:
        cfg = self.cfg
        if cfg.family != "hybrid" or not cfg.shared_attn_every:
            return 0
        return cfg.n_layers // cfg.shared_attn_every

    def cache_defs(self, batch: int, seq: int) -> Any:
        """ParamDef-shaped description of the decode cache (for specs /
        abstract construction). seq = max cache length."""
        cfg = self.cfg
        L = cfg.n_layers
        hd = cfg.resolved_head_dim
        fam = cfg.family

        def kv(layers, s, h):
            return {
                "k": ParamDef((layers, batch, s, h, hd),
                              ("layers", "batch", "kv_seq", "kv_heads", None),
                              init="zeros"),
                "v": ParamDef((layers, batch, s, h, hd),
                              ("layers", "batch", "kv_seq", "kv_heads", None),
                              init="zeros"),
            }

        if fam in ("dense", "moe", "vlm"):
            return kv(L, seq, cfg.n_kv_heads)
        if fam == "ssm":
            h = cfg.ssm.n_ssm_heads
            dk = cfg.d_model // h
            return {
                "state": ParamDef((L, batch, h, dk, dk),
                                  ("layers", "batch", "heads", None, None),
                                  init="zeros", dtype=jnp.float32),
                "shift_tm": ParamDef((L, batch, cfg.d_model),
                                     ("layers", "batch", "embed"), init="zeros"),
                "shift_cm": ParamDef((L, batch, cfg.d_model),
                                     ("layers", "batch", "embed"), init="zeros"),
            }
        if fam == "hybrid":
            ssm = cfg.ssm
            d_in = ssm.expand * cfg.d_model
            nh = ssm.n_ssm_heads or (d_in // ssm.state_size)
            conv_dim = d_in + 2 * ssm.state_size
            cache = {
                "mamba": {
                    "state": ParamDef((L, batch, nh, ssm.state_size, d_in // nh),
                                      ("layers", "batch", "heads", None, None),
                                      init="zeros", dtype=jnp.float32),
                    "conv": ParamDef((L, batch, ssm.conv_kernel - 1, conv_dim),
                                     ("layers", "batch", None, "heads"),
                                     init="zeros"),
                },
            }
            napp = self.n_shared_apps()
            if napp:
                cache["shared"] = kv(napp, seq, cfg.n_kv_heads)
            return cache
        if fam == "encdec":
            c = kv(L, seq, cfg.n_kv_heads)
            c_enc = {
                "xk": ParamDef((L, batch, cfg.enc_seq, cfg.n_kv_heads, hd),
                               ("layers", "batch", None, "kv_heads", None),
                               init="zeros"),
                "xv": ParamDef((L, batch, cfg.enc_seq, cfg.n_kv_heads, hd),
                               ("layers", "batch", None, "kv_heads", None),
                               init="zeros"),
            }
            return {**c, **c_enc}
        raise ValueError(fam)

    def init_cache(self, batch: int, seq: int) -> Any:
        return jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype),
                            self.cache_defs(batch, seq),
                            is_leaf=lambda x: isinstance(x, ParamDef))

    # -------------------------------------------------------------- forward
    def forward(self, params, batch: Dict[str, jax.Array], *, mode: str,
                cache=None, cache_index=None,
                ctx: ShardingCtx = NULL_CTX) -> Tuple[jax.Array, Any, jax.Array]:
        """Returns (logits, new_cache, aux_loss). In decode mode logits cover
        the single new token."""
        cfg = self.cfg
        fam = cfg.family
        if fam == "encdec":
            return self._forward_encdec(params, batch, mode=mode, cache=cache,
                                        cache_index=cache_index, ctx=ctx)

        tokens = batch["tokens"]
        b, s = tokens.shape
        x = embed(params["embed"], tokens)
        x = ctx.constrain(x, ("batch", "seq", "act_embed"))
        offset = cache_index if mode == "decode" else 0
        positions = batch.get("positions")
        if positions is None:
            positions = _positions_for(cfg, b, s, offset)

        block_fn = {
            "dense": blocks.decoder_block, "moe": blocks.decoder_block,
            "vlm": blocks.decoder_block, "ssm": blocks.rwkv6_block,
            "hybrid": blocks.mamba2_block,
        }[fam]

        if fam == "hybrid":
            x, new_cache, aux = self._hybrid_stack(
                params, x, mode=mode, positions=positions, cache=cache,
                cache_index=cache_index, ctx=ctx)
        else:
            x, new_cache, aux = self._scan_stack(
                params["layers"], block_fn, x, mode=mode, positions=positions,
                cache=cache, cache_index=cache_index, ctx=ctx)

        from repro.models.layers import rmsnorm
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = unembed(table, x)
        if cfg.padded_vocab != cfg.vocab:
            logits = logits[..., :cfg.vocab]   # drop TP-padding columns
        logits = ctx.constrain(logits, ("batch", "seq", "act_vocab"))
        return logits, new_cache, aux

    # ------------------------------------------------------------ stacks
    def _scan_stack(self, layer_params, block_fn, x, *, mode, positions,
                    cache, cache_index, ctx):
        """Blocks return a None cache in train mode, a fresh per-layer cache
        in prefill mode, and an updated cache in decode mode; ``None`` is an
        empty pytree so lax.scan threads all three uniformly."""
        cfg = self.cfg

        def body(carry, xs):
            h, aux = carry
            lp, lc = xs
            y, new_lc, a = block_fn(lp, h, cfg, mode=mode, positions=positions,
                                    cache=lc, cache_index=cache_index, ctx=ctx)
            return (y, aux + a), new_lc

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), new_cache = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (layer_params, cache))
        return x, new_cache, aux

    def _hybrid_stack(self, params, x, *, mode, positions, cache,
                      cache_index, ctx):
        """Zamba2: Mamba2 backbone with a single *shared* attention block
        applied after every ``shared_attn_every`` layers. The shared block
        consumes concat(hidden, initial_embedding) — the paper's 'master
        data consulted by every partition' pattern."""
        cfg = self.cfg
        k = cfg.shared_attn_every
        napp = self.n_shared_apps()
        n_main = napp * k
        x0 = x

        mamba_params = params["layers"]
        main_p = jax.tree.map(lambda a: a[:n_main].reshape(
            (napp, k) + a.shape[1:]), mamba_params)
        rest_p = jax.tree.map(lambda a: a[n_main:], mamba_params)

        m_cache = cache["mamba"] if cache is not None else None
        main_c = (jax.tree.map(lambda a: a[:n_main].reshape(
            (napp, k) + a.shape[1:]), m_cache) if m_cache is not None else None)
        rest_c = (jax.tree.map(lambda a: a[n_main:], m_cache)
                  if m_cache is not None else None)
        shared_c = cache.get("shared") if cache is not None else None

        shared_p = params["shared"]

        def apply_shared(h, sc):
            z = jnp.concatenate([h, x0], axis=-1)
            z = jnp.einsum("bsd,de->bse", z, shared_p["fuse"])
            from repro.models.layers import rmsnorm, swiglu_mlp
            hh = rmsnorm(z, shared_p["ln1"], cfg.norm_eps)
            a, new_sc = blocks.self_attention(
                shared_p["attn"], hh, cfg, mode=mode, positions=positions,
                cache=sc, cache_index=cache_index, ctx=ctx)
            z = z + a
            hh = rmsnorm(z, shared_p["ln2"], cfg.norm_eps)
            z = z + swiglu_mlp(shared_p["mlp"], hh)
            return h + z, new_sc

        def group_body(h, xs):
            gp, gc, sc = xs

            def inner(c2, xs2):
                lp, lc = xs2
                y, nlc, _ = blocks.mamba2_block(
                    lp, c2, cfg, mode=mode, positions=positions, cache=lc,
                    cache_index=cache_index, ctx=ctx)
                return y, nlc

            h, g_new = jax.lax.scan(inner, h, (gp, gc))
            h, new_sc = apply_shared(h, sc)
            return h, (g_new, new_sc)

        if cfg.remat:
            group_body = jax.checkpoint(group_body)

        x, (main_new, shared_new) = jax.lax.scan(
            group_body, x, (main_p, main_c, shared_c))

        # trailing layers (n_layers % k)
        n_rest = cfg.n_layers - n_main
        rest_new = None
        if n_rest:
            def rest_body(c2, xs2):
                lp, lc = xs2
                y, nlc, _ = blocks.mamba2_block(
                    lp, c2, cfg, mode=mode, positions=positions, cache=lc,
                    cache_index=cache_index, ctx=ctx)
                return y, nlc
            if cfg.remat:
                rest_body = jax.checkpoint(rest_body)
            x, rest_new = jax.lax.scan(rest_body, x, (rest_p, rest_c))

        new_cache = None
        if mode != "train":
            main_flat = jax.tree.map(
                lambda a: a.reshape((n_main,) + a.shape[2:]), main_new)
            if n_rest:
                mamba_new = jax.tree.map(
                    lambda a, b_: jnp.concatenate([a, b_], 0),
                    main_flat, rest_new)
            else:
                mamba_new = main_flat
            new_cache = {"mamba": mamba_new}
            if shared_new is not None:
                new_cache["shared"] = shared_new
        return x, new_cache, jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------ enc-dec
    def _forward_encdec(self, params, batch, *, mode, cache, cache_index, ctx):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape

        if mode in ("train", "prefill"):
            frames = batch["frames"]                   # [B, enc_seq, D] stub
            h = frames + sinusoidal_pos(frames.shape[1], cfg.d_model
                                        ).astype(frames.dtype)[None]
            h = ctx.constrain(h, ("batch", "seq", "act_embed"))

            def enc_body(carry, lp):
                return blocks.encoder_block(lp, carry, cfg, ctx=ctx), None
            if cfg.remat:
                enc_body = jax.checkpoint(enc_body)
            h, _ = jax.lax.scan(enc_body, h, params["enc_layers"])
            from repro.models.layers import layernorm
            enc_out = layernorm(h, params["enc_final_w"], params["enc_final_b"],
                                cfg.norm_eps)
            # per-decoder-layer encoder K/V
            hd = cfg.resolved_head_dim

            def enc_kv_of(lp):
                ek = jnp.einsum("bsd,dh->bsh", enc_out, lp["xattn"]["wk"])
                ev = jnp.einsum("bsd,dh->bsh", enc_out, lp["xattn"]["wv"])
                ev = ev + lp["xattn"]["bv"]
                return (ek.reshape(b, -1, cfg.n_kv_heads, hd),
                        ev.reshape(b, -1, cfg.n_kv_heads, hd))
            enc_k, enc_v = jax.vmap(enc_kv_of)(params["layers"])  # [L, B, S, H, hd]
        else:
            enc_k, enc_v = cache["xk"], cache["xv"]

        x = embed(params["embed"], tokens)
        offset = cache_index if mode == "decode" else 0
        x = x + sinusoidal_pos(s, cfg.d_model, offset if mode == "decode" else 0
                               ).astype(x.dtype)[None]
        x = ctx.constrain(x, ("batch", "seq", "act_embed"))

        self_cache = None
        if cache is not None:
            self_cache = {"k": cache["k"], "v": cache["v"]}

        def body(carry, xs):
            h, aux = carry
            lp, lc, ek, ev = xs
            y, new_lc, a = blocks.decoder_xattn_block(
                lp, h, {"k": ek, "v": ev}, cfg, mode=mode, positions=None,
                cache=lc, cache_index=cache_index, ctx=ctx)
            return (y, aux + a), new_lc

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), kv_new = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], self_cache, enc_k, enc_v))

        from repro.models.layers import rmsnorm
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = unembed(table, x)
        if cfg.padded_vocab != cfg.vocab:
            logits = logits[..., :cfg.vocab]   # drop TP-padding columns
        logits = ctx.constrain(logits, ("batch", "seq", "act_vocab"))

        new_cache = None
        if mode == "prefill":
            new_cache = {"k": kv_new["k"], "v": kv_new["v"],
                         "xk": enc_k.astype(jnp.bfloat16),
                         "xv": enc_v.astype(jnp.bfloat16)}
        elif mode == "decode":
            new_cache = {"k": kv_new["k"], "v": kv_new["v"],
                         "xk": enc_k, "xv": enc_v}
        return logits, new_cache, aux


@functools.lru_cache(maxsize=32)
def build_model(arch: str, smoke: bool = False) -> Model:
    from repro.configs import get_config, get_smoke_config
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    return Model(cfg)
