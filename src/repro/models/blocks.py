"""Transformer / SSM blocks. Every block is a pair (defs fn, apply fn)
operating on explicit param pytrees; stacks scan over the leading "layers"
axis of the defs.

Cache conventions (decode):
  attention  : {"k": [B, S, Hkv, hd], "v": [B, S, Hkv, hd]}
  rwkv6      : {"state": [B, H, dk, dv] f32, "shift_tm": [B, D], "shift_cm": [B, D]}
  mamba2     : {"state": [B, H, dk, dv] f32, "conv": [B, K-1, conv_dim]}
Caches are stacked [L, ...] by the stack and scanned.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import gla
from repro.models.attention import attend_chunked, attend_decode, attend_full
from repro.models.layers import (apply_mrope, apply_rope, groupnorm_heads,
                                 layernorm, mlp_defs, gelu_mlp, gelu_mlp_defs,
                                 rmsnorm, swiglu_mlp)
from repro.models.moe import moe_defs, moe_ffn
from repro.models.param import ParamDef
from repro.models.sharding import NULL_CTX, ShardingCtx

CHUNKED_ATTN_THRESHOLD = 8192


# ---------------------------------------------------------------------------
# Self-attention (GQA) core, shared by dense/moe/vlm/hybrid/encdec blocks
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig, layers: Optional[int] = None,
              cross: bool = False, bias: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    lead = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)
    defs = {
        "wq": ParamDef(lead + (d, hq * hd), lax_ + ("embed", "heads")),
        "wk": ParamDef(lead + (d, hkv * hd), lax_ + ("embed", "kv_heads")),
        "wv": ParamDef(lead + (d, hkv * hd), lax_ + ("embed", "kv_heads")),
        "wo": ParamDef(lead + (hq * hd, d), lax_ + ("heads2", "embed_out")),
    }
    if bias:
        defs["bq"] = ParamDef(lead + (hq * hd,), lax_ + ("heads",), init="zeros")
        defs["bv"] = ParamDef(lead + (hkv * hd,), lax_ + ("kv_heads",), init="zeros")
        defs["bo"] = ParamDef(lead + (d,), lax_ + ("embed",), init="zeros")
    return defs


def _project_qkv(params, x, cfg: ModelConfig, ctx: ShardingCtx):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        v = v + params["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    # Constrain ONLY k/v: when kv_heads < the model axis the divisibility
    # policy replicates them — otherwise GSPMD splits head_dim across the
    # axis and every attention score matrix becomes a partial sum that must
    # be ALL-REDUCED (measured: 932 GB/step of f32 score all-reduces on the
    # 33B train cell). Q and the attention output stay propagation-driven:
    # constraining them too forced ~7x more SP<->TP transitions.
    k = ctx.constrain(k, ("batch", None, "kv_heads", None))
    v = ctx.constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _apply_pos(q, k, cfg: ModelConfig, positions):
    if cfg.pos_scheme == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos_scheme == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    return q, k


def self_attention(params, x: jax.Array, cfg: ModelConfig, *,
                   mode: str, positions, cache=None, cache_index=None,
                   causal: bool = True, ctx: ShardingCtx = NULL_CTX):
    """mode: train | prefill | decode. Returns (y, new_cache)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(params, x, cfg, ctx)
    q, k = _apply_pos(q, k, cfg, positions)

    new_cache = cache
    if mode == "decode":
        assert cache is not None
        if ctx.rules.get("kv_seq") is not None:
            # the cache's sequence dim is sharded: DUS at a dynamic index
            # makes GSPMD gather the whole buffer (measured 0.46 s/token on
            # the 33B decode cell). A one-hot masked update is elementwise
            # => works under any sharding: one full read+write of the local
            # shard (~10 ms at 33B) instead of a cross-shard gather.
            onehot = (jnp.arange(cache["k"].shape[1]) == cache_index
                      ).astype(cache["k"].dtype)[None, :, None, None]
            k_cache = cache["k"] * (1 - onehot) + \
                k.astype(cache["k"].dtype) * onehot
            v_cache = cache["v"] * (1 - onehot) + \
                v.astype(cache["v"].dtype) * onehot
        else:
            k_cache = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype),
                (0, cache_index, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype),
                (0, cache_index, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
        out = attend_decode(q, k_cache, v_cache, cache_len=cache_index + 1)
    else:
        s = x.shape[1]
        if s > CHUNKED_ATTN_THRESHOLD:
            out = attend_chunked(q, k, v, causal=causal)
        else:
            out = attend_full(q, k, v, causal=causal)
        if mode == "prefill":
            new_cache = {"k": k.astype(jnp.bfloat16),
                         "v": v.astype(jnp.bfloat16)}
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, -1, cfg.n_heads * hd),
                   params["wo"])
    if "bo" in params:
        y = y + params["bo"]
    return y, new_cache


def cross_attention(params, x: jax.Array, enc_kv, cfg: ModelConfig, *,
                    ctx: ShardingCtx = NULL_CTX):
    """Whisper decoder cross-attention against precomputed encoder K/V."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    out = attend_full(q, enc_kv["k"], enc_kv["v"], causal=False)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, cfg.n_heads * hd),
                   params["wo"])
    if "bo" in params:
        y = y + params["bo"]
    return y


# ---------------------------------------------------------------------------
# Dense / MoE decoder block (pre-RMSNorm, SwiGLU or MoE FFN)
# ---------------------------------------------------------------------------

def decoder_block_defs(cfg: ModelConfig, layers: int):
    defs = {
        "ln1": ParamDef((layers, cfg.d_model), ("layers", "embed"), init="ones"),
        "attn": attn_defs(cfg, layers),
        "ln2": ParamDef((layers, cfg.d_model), ("layers", "embed"), init="ones"),
    }
    if cfg.moe is not None and cfg.moe.n_experts:
        defs["moe"] = moe_defs(cfg.d_model, cfg.moe, layers)
    else:
        defs["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff, layers)
    return defs


def decoder_block(params, x, cfg: ModelConfig, *, mode, positions,
                  cache=None, cache_index=None, ctx: ShardingCtx = NULL_CTX):
    h = rmsnorm(x, params["ln1"], cfg.norm_eps)
    a, new_cache = self_attention(params["attn"], h, cfg, mode=mode,
                                  positions=positions, cache=cache,
                                  cache_index=cache_index, ctx=ctx)
    x = x + a
    h = rmsnorm(x, params["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in params:
        f, aux = moe_ffn(params["moe"], h, cfg.moe)
    else:
        f = swiglu_mlp(params["mlp"], h)
        f = ctx.constrain(f, ("batch", "seq", "act_embed"))
    x = x + f
    x = ctx.constrain(x, ("batch", "seq", "act_embed"))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# RWKV6 (Finch) block: data-dependent-decay time mix + channel mix
# ---------------------------------------------------------------------------

RWKV_LORA = 32


def rwkv6_block_defs(cfg: ModelConfig, layers: int):
    d = cfg.d_model
    h = cfg.ssm.n_ssm_heads
    dk = d // h
    f = cfg.d_ff
    L = layers
    la = ("layers",)
    return {
        "ln1": ParamDef((L, d), la + ("embed",), init="ones"),
        "ln2": ParamDef((L, d), la + ("embed",), init="ones"),
        "tm": {
            # token-shift interpolation coefficients for r,k,v,w,g
            "mu": ParamDef((L, 5, d), la + (None, "embed")),
            "w_base": ParamDef((L, d), la + ("embed",)),     # per-channel decay base
            "w_lora_a": ParamDef((L, d, RWKV_LORA), la + ("embed", None)),
            "w_lora_b": ParamDef((L, RWKV_LORA, d), la + (None, "embed"), init="zeros"),
            "u": ParamDef((L, h, dk), la + ("heads", None)), # bonus
            "wr": ParamDef((L, d, d), la + ("embed", "heads")),
            "wk": ParamDef((L, d, d), la + ("embed", "heads")),
            "wv": ParamDef((L, d, d), la + ("embed", "heads")),
            "wg": ParamDef((L, d, d), la + ("embed", "heads")),
            "wo": ParamDef((L, d, d), la + ("heads", "embed")),
            "ln_x_w": ParamDef((L, d), la + ("embed",), init="ones"),
            "ln_x_b": ParamDef((L, d), la + ("embed",), init="zeros"),
        },
        "cm": {
            "mu_k": ParamDef((L, d), la + ("embed",)),
            "mu_r": ParamDef((L, d), la + ("embed",)),
            "wk": ParamDef((L, d, f), la + ("embed", "ff")),
            "wv": ParamDef((L, f, d), la + ("ff", "embed")),
            "wr": ParamDef((L, d, d), la + ("embed", "heads")),
        },
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]):
    """x: [B, S, D] -> x shifted right by one token; position 0 sees ``prev``
    (decode carry) or zeros."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev.astype(x.dtype))
    return shifted


def rwkv6_time_mix(p, x, cfg: ModelConfig, *, mode, cache, ctx: ShardingCtx):
    b, s, d = x.shape
    h = cfg.ssm.n_ssm_heads
    dk = d // h
    prev = cache["shift_tm"] if cache is not None else None
    xs = _token_shift(x, prev)
    mu = p["mu"]                                          # [5, D]
    mix = x[:, :, None, :] + (xs - x)[:, :, None, :] * mu[None, None]
    xr, xk, xv, xw, xg = (mix[:, :, i] for i in range(5))
    r = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(b, s, h, dk)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(b, s, h, dk)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(b, s, h, dk)
    g = jnp.einsum("bsd,de->bse", xg, p["wg"])
    # data-dependent decay: w = exp(-exp(base + lora(xw)))
    lora = jnp.einsum("bsd,dr->bsr", xw, p["w_lora_a"])
    lora = jnp.einsum("bsr,rd->bsd", jnp.tanh(lora.astype(jnp.float32)).astype(x.dtype),
                      p["w_lora_b"])
    log_w = -jnp.exp(jnp.clip(p["w_base"].astype(jnp.float32) +
                              lora.astype(jnp.float32), -8.0, 4.0))
    log_w = log_w.reshape(b, s, h, dk)

    if mode == "decode":
        state = cache["state"]
        o, new_state = gla.gla_step(r[:, 0], k[:, 0], v[:, 0], log_w[:, 0],
                                    state, u=p["u"], inclusive=False)
        out = o[:, None]                                   # [B,1,H,dk]
        new_cache = {"state": new_state, "shift_tm": x[:, -1]}
    else:
        init = cache["state"] if cache is not None else None
        # per-channel decay ratios cancel badly in bf16 (3.6% decode
        # divergence measured) -> rwkv6 keeps f32 ratios; mamba2's scalar
        # decay keeps the bf16 fast path (EXPERIMENTS.md SSPerf cell 2)
        out, final_state = gla.gla_chunk(r, k, v, log_w, u=p["u"],
                                         inclusive=False,
                                         initial_state=init,
                                         ratio_dtype=jnp.float32)
        new_cache = (None if mode == "train"
                     else {"state": final_state, "shift_tm": x[:, -1]})
    y = out.reshape(b, s, d)
    y = groupnorm_heads(y, p["ln_x_w"], p["ln_x_b"], h)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bsd,de->bse", y, p["wo"])
    return y, new_cache


def rwkv6_channel_mix(p, x, *, cache):
    prev = cache["shift_cm"] if cache is not None else None
    xs = _token_shift(x, prev)
    xk = x + (xs - x) * p["mu_k"]
    xr = x + (xs - x) * p["mu_r"]
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]).astype(jnp.float32))
    return (r.astype(x.dtype) * kv), x[:, -1]


def rwkv6_block(params, x, cfg: ModelConfig, *, mode, positions=None,
                cache=None, cache_index=None, ctx: ShardingCtx = NULL_CTX):
    h = rmsnorm(x, params["ln1"], cfg.norm_eps)
    a, tm_cache = rwkv6_time_mix(params["tm"], h, cfg, mode=mode,
                                 cache=cache, ctx=ctx)
    x = x + a
    h = rmsnorm(x, params["ln2"], cfg.norm_eps)
    f, shift_cm = rwkv6_channel_mix(params["cm"], h, cache=cache)
    x = x + f
    x = ctx.constrain(x, ("batch", "seq", "act_embed"))
    new_cache = (None if mode == "train"
                 else dict(tm_cache, shift_cm=shift_cm))
    return x, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block — used by zamba2 hybrid backbone
# ---------------------------------------------------------------------------

def mamba2_block_defs(cfg: ModelConfig, layers: int):
    d = cfg.d_model
    ssm = cfg.ssm
    d_in = ssm.expand * d
    nh = d_in // ssm.state_size if ssm.n_ssm_heads == 0 else ssm.n_ssm_heads
    hd = d_in // nh
    st = ssm.state_size
    L = layers
    la = ("layers",)
    # in_proj emits [z (d_in), x (d_in), B (st), C (st), dt (nh)]
    proj_out = 2 * d_in + 2 * st + nh
    return {
        "ln": ParamDef((L, d), la + ("embed",), init="ones"),
        "in_proj": ParamDef((L, d, proj_out), la + ("embed", "heads")),
        "conv_w": ParamDef((L, ssm.conv_kernel, d_in + 2 * st),
                           la + (None, "heads"), scale=0.5),
        "a_log": ParamDef((L, nh), la + ("heads",), init="zeros"),
        "dt_bias": ParamDef((L, nh), la + ("heads",), init="zeros"),
        "d_skip": ParamDef((L, nh), la + ("heads",), init="ones"),
        "norm": ParamDef((L, d_in), la + ("heads",), init="ones"),
        "out_proj": ParamDef((L, d_in, d), la + ("heads", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, conv_state: Optional[jax.Array]):
    """Depthwise causal conv. x: [B, S, C]; w: [K, C]. conv_state: [B, K-1, C]
    carried for decode. Returns (y, new_conv_state)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # [B, S+K-1, C]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_state


def mamba2_block(params, x, cfg: ModelConfig, *, mode, positions=None,
                 cache=None, cache_index=None, ctx: ShardingCtx = NULL_CTX):
    b, s, d = x.shape
    ssm = cfg.ssm
    d_in = ssm.expand * d
    st = ssm.state_size
    nh = ssm.n_ssm_heads or (d_in // st)
    hd = d_in // nh

    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, params["in_proj"])
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + st, 2 * d_in + 2 * st], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"], conv_state)
    xin, Bc, Cc = jnp.split(conv_out, [d_in, d_in + st], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # [B,S,nh]
    a = -jnp.exp(params["a_log"].astype(jnp.float32))            # [nh]
    log_w = (dt * a[None, None]).reshape(b, s, nh, 1)            # scalar/head
    # k = B (shared across heads), v = dt * x, q = C
    k = jnp.broadcast_to(Bc[:, :, None, :], (b, s, nh, st))
    q = jnp.broadcast_to(Cc[:, :, None, :], (b, s, nh, st))
    v = (xin.reshape(b, s, nh, hd).astype(jnp.float32) *
         dt[..., None]).astype(x.dtype)
    # decay is per-head scalar -> broadcast over the dk axis of k
    log_w_full = jnp.broadcast_to(log_w, (b, s, nh, st))

    if mode == "decode":
        state = cache["state"]
        o, new_state = gla.gla_step(q[:, 0], k[:, 0], v[:, 0],
                                    log_w_full[:, 0], state, inclusive=True)
        out = o[:, None]
        new_cache = {"state": new_state, "conv": new_conv}
    else:
        init = cache["state"] if cache is not None else None
        out, final = gla.gla_chunk(q, k, v, log_w_full, inclusive=True,
                                   initial_state=init)
        new_cache = (None if mode == "train"
                     else {"state": final, "conv": new_conv})

    y = out.reshape(b, s, d_in) + xin * jnp.repeat(
        params["d_skip"], hd, axis=-1).astype(x.dtype)[None, None]
    y = rmsnorm(y, params["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    x = x + y
    x = ctx.constrain(x, ("batch", "seq", "act_embed"))
    return x, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Whisper encoder block (bidirectional, LayerNorm+bias, GELU MLP)
# ---------------------------------------------------------------------------

def encoder_block_defs(cfg: ModelConfig, layers: int):
    d = cfg.d_model
    la = ("layers",)
    return {
        "ln1_w": ParamDef((layers, d), la + ("embed",), init="ones"),
        "ln1_b": ParamDef((layers, d), la + ("embed",), init="zeros"),
        "attn": attn_defs(cfg, layers, bias=True),
        "ln2_w": ParamDef((layers, d), la + ("embed",), init="ones"),
        "ln2_b": ParamDef((layers, d), la + ("embed",), init="zeros"),
        "mlp": gelu_mlp_defs(d, cfg.d_ff, layers),
    }


def encoder_block(params, x, cfg: ModelConfig, *, ctx: ShardingCtx = NULL_CTX):
    h = layernorm(x, params["ln1_w"], params["ln1_b"], cfg.norm_eps)
    a, _ = self_attention(params["attn"], h, cfg, mode="train",
                          positions=None, causal=False, ctx=ctx)
    x = x + a
    h = layernorm(x, params["ln2_w"], params["ln2_b"], cfg.norm_eps)
    x = x + gelu_mlp(params["mlp"], h)
    return ctx.constrain(x, ("batch", "seq", "act_embed"))


def decoder_xattn_block_defs(cfg: ModelConfig, layers: int):
    d = cfg.d_model
    la = ("layers",)
    return {
        "ln1_w": ParamDef((layers, d), la + ("embed",), init="ones"),
        "ln1_b": ParamDef((layers, d), la + ("embed",), init="zeros"),
        "attn": attn_defs(cfg, layers, bias=True),
        "lnx_w": ParamDef((layers, d), la + ("embed",), init="ones"),
        "lnx_b": ParamDef((layers, d), la + ("embed",), init="zeros"),
        "xattn": attn_defs(cfg, layers, bias=True),
        "ln2_w": ParamDef((layers, d), la + ("embed",), init="ones"),
        "ln2_b": ParamDef((layers, d), la + ("embed",), init="zeros"),
        "mlp": gelu_mlp_defs(d, cfg.d_ff, layers),
    }


def decoder_xattn_block(params, x, enc_kv, cfg: ModelConfig, *, mode,
                        positions=None, cache=None, cache_index=None,
                        ctx: ShardingCtx = NULL_CTX):
    h = layernorm(x, params["ln1_w"], params["ln1_b"], cfg.norm_eps)
    a, new_cache = self_attention(params["attn"], h, cfg, mode=mode,
                                  positions=positions, cache=cache,
                                  cache_index=cache_index, ctx=ctx)
    x = x + a
    h = layernorm(x, params["lnx_w"], params["lnx_b"], cfg.norm_eps)
    x = x + cross_attention(params["xattn"], h, enc_kv, cfg, ctx=ctx)
    h = layernorm(x, params["ln2_w"], params["ln2_b"], cfg.norm_eps)
    x = x + gelu_mlp(params["mlp"], h)
    x = ctx.constrain(x, ("batch", "seq", "act_embed"))
    return x, new_cache, jnp.zeros((), jnp.float32)
