"""Gated linear recurrence (chunked), shared by RWKV6 and Mamba2/SSD.

Recurrence per head (state S in R^{dk x dv}):

    S_t = diag(w_t) @ S_{t-1} + k_t v_t^T            (w_t in (0,1])
    o_t = q_t @ (S_{t-1} + diag(u) k_t v_t^T)        lag=1 w/ bonus  (RWKV6)
    o_t = q_t @ S_t                                  lag=0           (Mamba2)

The O(T) chunked form processes C tokens at a time: an intra-chunk masked
"attention" term with cumulative-decay ratios plus an inter-chunk term
against the carried state, then a chunk-level state update via lax.scan.
All decay arithmetic is done on log-decay in f32 with masking *before*
exponentiation so strongly-decaying channels cannot overflow.

This module is the pure-jnp oracle; ``repro.kernels.gla_chunk`` is the
Pallas TPU kernel with the identical contract.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gla_chunk(q: jax.Array, k: jax.Array, v: jax.Array,
              log_w: jax.Array, *,
              u: Optional[jax.Array] = None,
              inclusive: bool = False,
              chunk: int = 64,
              initial_state: Optional[jax.Array] = None,
              ratio_dtype=jnp.bfloat16,
              ) -> Tuple[jax.Array, jax.Array]:
    """q,k,log_w: [B, S, H, dk]; v: [B, S, H, dv]; u: [H, dk] or None.

    Returns (out [B, S, H, dv], final_state [B, H, dk, dv]).
    ``inclusive=False`` reads the state *before* the current token (RWKV6,
    combined with the ``u`` bonus for the diagonal); ``inclusive=True``
    reads the state after the update (Mamba2 — pass ``u=None``).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    s_orig = s
    if s % chunk:
        # pad with k=0 (no state contribution), log_w=0 (w=1: state frozen)
        pad = chunk - s % chunk
        padfn = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = padfn(q), padfn(k), padfn(v)
        log_w = padfn(log_w)
        s += pad
    n = s // chunk

    qc = q.reshape(b, n, chunk, h, dk).transpose(1, 0, 3, 2, 4)  # [n,b,h,C,dk]
    kc = k.reshape(b, n, chunk, h, dk).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n, chunk, h, dv).transpose(1, 0, 3, 2, 4)
    lw = log_w.reshape(b, n, chunk, h, dk).transpose(1, 0, 3, 2, 4)
    lw = lw.astype(jnp.float32)

    lag = 0 if inclusive else 1
    t_idx = jnp.arange(chunk)
    # valid (t, i) pairs: i <= t - lag
    pair_mask = t_idx[:, None] >= (t_idx[None, :] + lag)

    def step(S, xs):
        qb, kb, vb, lwb = xs                       # [b,h,C,*]
        L = jnp.cumsum(lwb, axis=2)                # inclusive cumulative log-decay
        # decay from chunk entry to the state the query reads
        Lq = L if inclusive else L - lwb           # L_{t} or L_{t-1}
        qf = qb.astype(jnp.float32)
        kf = kb.astype(jnp.float32)
        vf = vb.astype(jnp.float32)

        # ---- inter-chunk: q_t (decayed) @ S_in
        q_dec = qf * jnp.exp(Lq)                   # Lq <= 0 -> safe
        inter = jnp.einsum("bhtk,bhkv->bhtv", q_dec, S)

        # ---- intra-chunk: A[t,i] = sum_d q_td k_id exp(Lq_t,d - L_i,d).
        # The [C,C,dk] ratio tensor is the jnp path's HBM hot spot (the
        # Pallas kernel keeps it VMEM-resident); exp stays f32 for safety,
        # the contraction runs in bf16 — halves the dominant tensor's
        # traffic at <1e-3 relative error (EXPERIMENTS.md SSPerf).
        diff = Lq[:, :, :, None, :] - L[:, :, None, :, :]   # [b,h,t,i,dk]
        diff = jnp.where(pair_mask[None, None, :, :, None], diff, NEG_INF)
        ratios = jnp.exp(diff).astype(ratio_dtype)
        A = jnp.einsum("bhtd,bhid,bhtid->bhti",
                       qf.astype(ratio_dtype), kf.astype(ratio_dtype),
                       ratios).astype(jnp.float32)
        intra = jnp.einsum("bhti,bhiv->bhtv", A, vf)

        out = inter + intra
        if u is not None:                          # RWKV6 current-token bonus
            qu = qf * u.astype(jnp.float32)[None, :, None, :]
            dot = jnp.einsum("bhtd,bhtd->bht", qu, kf)
            out = out + dot[..., None] * vf

        # ---- state update: S_out = diag(exp(L_C)) S_in + sum_i k_i exp(L_C-L_i) v_i
        Ltot = L[:, :, -1:, :]                     # [b,h,1,dk]
        k_dec = kf * jnp.exp(Ltot - L)             # <= 0 -> safe
        S_new = jnp.exp(Ltot.squeeze(2))[..., None] * S + \
            jnp.einsum("bhtk,bhtv->bhkv", k_dec, vf)
        return S_new, out

    S0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((b, h, dk, dv), jnp.float32))
    S_final, outs = jax.lax.scan(step, S0, (qc, kc, vc, lw))
    # outs: [n, b, h, C, dv] -> [B, S, H, dv]
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dv)[:, :s_orig]
    return out.astype(v.dtype), S_final


def gla_step(q: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
             state: jax.Array, *,
             u: Optional[jax.Array] = None,
             inclusive: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Single recurrent step (decode). q,k,log_w: [B, H, dk]; v: [B, H, dv];
    state: [B, H, dk, dv] (f32). Returns (o [B, H, dv], new_state)."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    w = jnp.exp(log_w.astype(jnp.float32))
    kv = kf[..., :, None] * vf[..., None, :]          # [B,H,dk,dv]
    S_new = w[..., None] * state + kv
    read = S_new if inclusive else state
    o = jnp.einsum("bhk,bhkv->bhv", qf, read)
    if u is not None:
        dot = jnp.einsum("bhk,bhk->bh", qf * u.astype(jnp.float32)[None], kf)
        o = o + dot[..., None] * vf
    return o.astype(v.dtype), S_new
