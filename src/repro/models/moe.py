"""Mixture-of-Experts FFN with GShard-style grouped einsum dispatch.

Tokens are reshaped into groups; within each group every token picks its
top-k experts, positions are assigned by cumulative count up to a fixed
capacity (over-capacity tokens drop — standard GShard), and dispatch /
combine are one-hot einsums that GSPMD turns into all-to-alls when the
expert dimension is sharded over the ``model``/expert axis.

DOD-ETL tie-in: this is the same key->partition discipline as the paper's
message queue — a token is a message, the router key is the business key,
experts are partitions, capacity is the consumer's per-partition buffer.
``repro.core.partitioning`` reuses the same position-assignment helper.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.param import ParamDef


def moe_defs(d_model: int, cfg: MoEConfig, layers: Optional[int] = None):
    lead = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)
    e, fe = cfg.padded_experts, cfg.d_ff_expert
    defs = {
        "router": ParamDef(lead + (d_model, e), lax_ + ("embed", None),
                           dtype=jnp.float32),
        "w_gate": ParamDef(lead + (e, d_model, fe),
                           lax_ + ("experts", "embed", "ff_expert")),
        "w_up": ParamDef(lead + (e, d_model, fe),
                         lax_ + ("experts", "embed", "ff_expert")),
        "w_down": ParamDef(lead + (e, fe, d_model),
                           lax_ + ("experts", "ff_expert", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * fe
        defs["shared"] = {
            "w_gate": ParamDef(lead + (d_model, fs), lax_ + ("embed", "ff")),
            "w_up": ParamDef(lead + (d_model, fs), lax_ + ("embed", "ff")),
            "w_down": ParamDef(lead + (fs, d_model), lax_ + ("ff", "embed")),
        }
    return defs


def assign_positions(expert_idx: jax.Array, n_experts: int, capacity: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Per-group slot assignment. expert_idx: [S_assignments] int32 (already
    flattened (token, k) pairs in priority order). Returns (position [S],
    keep_mask [S]). Position is the running count of prior assignments to
    the same expert; assignments beyond capacity are dropped.
    """
    onehot = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                 # [S, E]
    position = jnp.sum(pos * onehot, axis=-1)            # [S]
    keep = position < capacity
    return position, keep


def moe_ffn(params, x: jax.Array, cfg: MoEConfig,
            ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    Grouped dispatch: [groups, group_size, D] -> one-hot dispatch
    [G, S, E, C] -> expert compute [E, G*C, D] -> combine.
    """
    b, s, d = x.shape
    e, k = cfg.padded_experts, cfg.top_k
    tokens = x.reshape(-1, d)
    n_tok = tokens.shape[0]
    gs = min(cfg.group_size, n_tok)
    while n_tok % gs:            # largest divisor of n_tok <= group_size
        gs -= 1
    g = n_tok // gs
    capacity = max(int(gs * k * cfg.capacity_factor / cfg.n_experts), 1)
    # round capacity to a multiple of 4 for layout friendliness
    capacity = (capacity + 3) // 4 * 4

    xt = tokens.reshape(g, gs, d)
    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    if e != cfg.n_experts:
        # padded (dummy) experts exist only for EP divisibility: unroutable
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)              # [g, gs, e]

    topv, topi = jax.lax.top_k(probs, k)                 # [g, gs, k]
    topv = topv / jnp.clip(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # --- load-balancing aux loss (Switch): mean_prob * mean_assign per expert
    me = jnp.mean(probs, axis=(0, 1))                    # [e]
    assign1 = jax.nn.one_hot(topi[..., 0], e)
    ce = jnp.mean(assign1, axis=(0, 1))
    aux = jnp.sum(me * ce) * e * cfg.router_aux_weight

    # --- position assignment per group, k-major priority (GShard)
    flat_idx = topi.transpose(0, 2, 1).reshape(g, k * gs)   # priority: k slot 0 first
    def per_group(idx):
        return assign_positions(idx, e, capacity)
    position, keep = jax.vmap(per_group)(flat_idx)        # [g, k*gs]
    position = position.reshape(g, k, gs).transpose(0, 2, 1)  # [g, gs, k]
    keep = keep.reshape(g, k, gs).transpose(0, 2, 1)

    gate = topv * keep                                    # dropped -> 0 weight
    # dispatch tensor [g, gs, e, c]
    disp = (jax.nn.one_hot(topi, e, dtype=x.dtype)[..., None] *
            jax.nn.one_hot(position, capacity, dtype=x.dtype)[..., None, :] *
            keep[..., None, None].astype(x.dtype)).sum(axis=2)
    comb = (jax.nn.one_hot(topi, e, dtype=jnp.float32)[..., None] *
            jax.nn.one_hot(position, capacity, dtype=jnp.float32)[..., None, :] *
            gate[..., None, None]).sum(axis=2)

    # expert inputs: [e, g, c, d]  (a2a when e is sharded over the model axis)
    xe = jnp.einsum("gsd,gsec->egcd", xt, disp)
    h_g = jnp.einsum("egcd,edf->egcf", xe, params["w_gate"])
    h_u = jnp.einsum("egcd,edf->egcf", xe, params["w_up"])
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(x.dtype) * h_u
    ye = jnp.einsum("egcf,efd->egcd", h, params["w_down"])
    out = jnp.einsum("egcd,gsec->gsd", ye.astype(jnp.float32), comb)
    out = out.reshape(b, s, d).astype(x.dtype)

    if cfg.n_shared_experts:
        sh = params["shared"]
        gsh = jnp.einsum("bsd,df->bsf", x, sh["w_gate"])
        ush = jnp.einsum("bsd,df->bsf", x, sh["w_up"])
        hsh = jax.nn.silu(gsh.astype(jnp.float32)).astype(x.dtype) * ush
        out = out + jnp.einsum("bsf,fd->bsd", hsh, sh["w_down"])
    return out, aux
