"""Parameter definition machinery.

Every parameter is declared once as a ``ParamDef`` carrying its shape, its
*logical* axis names and an init function. From one tree of ParamDefs we
derive, without duplication:

  * materialized params           (``init``)
  * abstract params               (``abstract`` — ShapeDtypeStructs, no alloc;
                                   this is what the multi-pod dry-run uses)
  * a PartitionSpec tree          (``specs`` — logical axes -> mesh axes)

Logical axis vocabulary (mapped to mesh axes by ``repro.launch.mesh`` rules):
  "vocab", "embed", "heads", "kv_heads", "head_dim", "ff", "ff_expert",
  "experts", "layers", "state", "batch", "seq", None
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Shape = Tuple[int, ...]
Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Shape
    axes: Axes
    init: str = "normal"      # "normal" | "zeros" | "ones" | "small"
    scale: float = 1.0
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _materialize(defn: ParamDef, key) -> jax.Array:
    if defn.init == "zeros":
        return jnp.zeros(defn.shape, defn.dtype)
    if defn.init == "ones":
        return jnp.ones(defn.shape, defn.dtype)
    # truncated-normal fan-in scaling
    fan_in = defn.shape[-2] if len(defn.shape) >= 2 else defn.shape[-1]
    std = defn.scale / math.sqrt(max(fan_in, 1))
    x = jax.random.truncated_normal(key, -2.0, 2.0, defn.shape, jnp.float32)
    return (x * std).astype(defn.dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_init(defs, key):
    """Materialize a tree of ParamDefs with per-leaf folded keys."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_materialize(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def tree_abstract(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def tree_specs(defs, rules: Dict[Optional[str], Any], mesh=None):
    """Logical-axis names -> PartitionSpec via the mesh rule table.

    With ``mesh`` given, a mesh axis is dropped for any dim *smaller* than
    the axis size (sharding a size-8 dim 16 ways degenerates to involuntary
    rematerialization in GSPMD); dims >= the axis size are kept and padded.
    """
    axis_sizes = (dict(zip(mesh.axis_names, mesh.devices.shape))
                  if mesh is not None else {})

    def axsize(mesh_axes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        n = 1
        for a in mesh_axes:
            n *= axis_sizes.get(a, 1)
        return n

    def one(d: ParamDef) -> P:
        out = []
        for a, dim in zip(d.axes, d.shape):
            mesh_axes = rules.get(a, None)
            if mesh is not None and mesh_axes is not None \
                    and dim < axsize(mesh_axes):
                mesh_axes = None
            out.append(mesh_axes)
        return P(*out)

    return jax.tree.map(one, defs, is_leaf=is_def)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return int(sum(math.prod(d.shape) for d in leaves))
