"""Shared neural-net layers: norms, rotary embeddings (RoPE / M-RoPE /
sinusoidal), SwiGLU MLP, embedding/unembedding. All pure functions over
explicit param pytrees; f32 accumulation inside norms/softmaxes, bf16 tensors.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, weight: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def groupnorm_heads(x: jax.Array, weight: jax.Array, bias: jax.Array,
                    n_heads: int, eps: float = 64e-5) -> jax.Array:
    """GroupNorm with one group per head over the last dim (RWKV ln_x)."""
    *lead, d = x.shape
    xg = x.reshape(*lead, n_heads, d // n_heads).astype(jnp.float32)
    mu = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    y = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, d)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary / positional
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (int32)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, int, int] = (1, 1, 2)) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the rotary dims are split into (t, h, w)
    sections, each rotated by its own position stream.

    x: [..., seq, heads, head_dim]; positions: [..., seq, 3] (t, h, w).
    ``sections`` gives relative widths; for text all three streams coincide
    and M-RoPE reduces to standard RoPE (verified in tests).
    """
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    widths = [half * s // total for s in sections]
    widths[-1] = half - sum(widths[:-1])
    freqs = rope_freqs(hd, theta)                       # [half]
    # angle per rotary channel, selecting the position stream per section
    ang_parts = []
    start = 0
    for i, w in enumerate(widths):
        pos_i = positions[..., i].astype(jnp.float32)   # [..., S]
        ang_parts.append(pos_i[..., :, None] * freqs[start:start + w])
        start += w
    ang = jnp.concatenate(ang_parts, axis=-1)           # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d_model: int, offset=0) -> jax.Array:
    """``offset`` may be a traced scalar (decode position)."""
    pos = (jnp.arange(seq, dtype=jnp.float32) +
           jnp.asarray(offset, jnp.float32))[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int, layers: Optional[int] = None):
    lead = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)
    return {
        "w_gate": ParamDef(lead + (d_model, d_ff), lax_ + ("embed", "ff")),
        "w_up": ParamDef(lead + (d_model, d_ff), lax_ + ("embed", "ff")),
        "w_down": ParamDef(lead + (d_ff, d_model), lax_ + ("ff2", "embed_out")),
    }


def swiglu_mlp(params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def gelu_mlp(params, x: jax.Array) -> jax.Array:
    """2-matrix GELU MLP (whisper)."""
    h = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def gelu_mlp_defs(d_model: int, d_ff: int, layers: Optional[int] = None):
    lead = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)
    return {
        "w_up": ParamDef(lead + (d_model, d_ff), lax_ + ("embed", "ff")),
        "w_down": ParamDef(lead + (d_ff, d_model), lax_ + ("ff2", "embed_out")),
    }


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def unembed(table: jax.Array, x: jax.Array) -> jax.Array:
    """Logits in f32 (loss-stability)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      table.astype(jnp.float32))
