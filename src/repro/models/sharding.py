"""Sharding context: one table mapping *logical* axis names to mesh axes.

Models never import mesh details; they call ``ctx.constrain(x, axes)`` on
activations and the launcher derives parameter PartitionSpecs from the same
table (``repro.models.param.tree_specs``). With no mesh (CPU smoke tests)
every call is the identity.

Default rule table (single pod, mesh ("data", "model")):
    batch      -> data            DP: batch / business-key partitions
    seq        -> None            (or "model" under sequence-parallel resid)
    embed      -> None            (or "data" under FSDP for params)
    heads      -> model           TP attention
    kv_heads   -> model
    ff         -> model           TP mlp
    ff_expert  -> None            (expert-parallel already splits experts)
    experts    -> model           EP
    vocab      -> model
    layers     -> None
    state      -> None
    kv_seq     -> None            (or "model": SP KV cache for 500k decode)

Multi-pod meshes extend "batch" to ("pod", "data").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def default_rules(multi_pod: bool = False, *,
                  fsdp: bool = False,
                  seq_parallel: bool = False,
                  second_matmul: str = "row",
                  kv_seq_shard: bool = False) -> Dict[str, Any]:
    batch = ("pod", "data") if multi_pod else "data"
    rules: Dict[str, Any] = {
        "batch": batch,
        "seq": "model" if seq_parallel else None,
        "embed": "data" if fsdp else None,   # param input dim (FSDP/ZeRO-3)
        "act_embed": None,                   # activation feature dim
        # logits vocab dim: TP normally; under SP the seq dim owns "model"
        "act_vocab": None if seq_parallel else "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        # second matmul of each pair (wo / w_down): "row" = Megatron
        # (input dim sharded, output all-reduced); "col" = output-dim
        # sharded (activation gathers instead of weight gathers)
        "ff2": "model" if second_matmul == "row" else None,
        "heads2": "model" if second_matmul == "row" else None,
        "embed_out": "model" if second_matmul == "col" else None,
        "ff_expert": None,
        "experts": "model",
        "vocab": "model",
        "layers": None,
        "state": None,
        "kv_seq": "model" if kv_seq_shard else None,
        None: None,
    }
    return rules


@dataclasses.dataclass
class ShardingCtx:
    mesh: Optional[Mesh] = None
    rules: Dict[str, Any] = dataclasses.field(default_factory=default_rules)

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        return P(*(self.rules.get(a, None) for a in axes))

    @property
    def _axis_sizes(self) -> Dict[str, int]:
        """{mesh axis name: size}, computed ONCE per ctx. The mesh is
        immutable for the ctx's lifetime, but ``spec_for_shape`` runs per
        tensor per call site — rebuilding this dict per axis there was
        measurable pure waste."""
        cached = self.__dict__.get("_axis_sizes_cache")
        if cached is None:
            cached = {} if self.mesh is None else \
                dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
            self.__dict__["_axis_sizes_cache"] = cached
        return cached

    def _axis_size(self, mesh_axes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        sizes = self._axis_sizes
        size = 1
        for a in mesh_axes:
            size *= sizes[a]
        return size

    def spec_for_shape(self, axes: Sequence[Optional[str]],
                       shape: Sequence[int]) -> P:
        """Like ``spec`` but drops a mesh axis whenever the tensor dim is
        *smaller* than it (sharding a size-1/8 dim 16 ways forces GSPMD into
        involuntary full rematerialization). Dims >= axis size but not
        divisible are kept: GSPMD pads, which is the lesser waste."""
        out = []
        for a, dim in zip(axes, shape):
            mesh_axes = self.rules.get(a, None)
            if mesh_axes is not None and dim < self._axis_size(mesh_axes):
                mesh_axes = None
            out.append(mesh_axes)
        return P(*out)

    def constrain(self, x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
        if self.mesh is None:
            return x
        assert len(axes) == x.ndim, (axes, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec_for_shape(axes, x.shape)))

    def sharding(self, axes: Sequence[Optional[str]]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(axes))


NULL_CTX = ShardingCtx()
