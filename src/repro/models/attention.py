"""Attention: grouped-query (GQA/MQA) softmax attention in three regimes.

  * ``attend_full``    — einsum path, fine up to ~8k tokens (training).
  * ``attend_chunked`` — flash-style double lax.scan with online softmax;
                         O(block²) peak memory, used for 32k prefill. This is
                         the pure-JAX twin of ``kernels/flash_attention``.
  * ``attend_decode``  — one query step against a KV cache with a length mask.

All paths compute the softmax in f32 and respect GQA head grouping
(q heads are grouped over kv heads; kv is *not* materialized per q head).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _group(q: jax.Array, n_kv: int) -> jax.Array:
    """[B, S, Hq, d] -> [B, S, Hkv, G, d]."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def attend_full(q: jax.Array, k: jax.Array, v: jax.Array, *,
                causal: bool, q_offset: int = 0,
                scale: Optional[float] = None) -> jax.Array:
    """q: [B, Sq, Hq, d]; k, v: [B, Skv, Hkv, d] -> [B, Sq, Hq, d]."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    scale = scale if scale is not None else d ** -0.5
    qg = _group(q, hkv)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)
        kpos = jnp.arange(skv)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, hq, d)


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, q_block: int = 1024, kv_block: int = 1024,
                   scale: Optional[float] = None) -> jax.Array:
    """Flash-style online-softmax attention, O(q_block*kv_block) peak scores.

    Double scan: outer over query blocks, inner over KV blocks carrying the
    running (max, normalizer, accumulator). Causal masking is applied per
    block pair; fully-masked pairs still execute (static shapes) — the Pallas
    kernel skips them on TPU and the roofline notes the 2x causal slack here.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert sq % q_block == 0 and skv % kv_block == 0, (sq, skv)
    scale = scale if scale is not None else d ** -0.5
    g = hq // hkv
    nq, nk = sq // q_block, skv // kv_block

    qg = _group(q, hkv).reshape(b, nq, q_block, hkv, g, d)
    kb = k.reshape(b, nk, kv_block, hkv, d)
    vb = v.reshape(b, nk, kv_block, hkv, d)

    qpos_base = jnp.arange(q_block)
    kpos_base = jnp.arange(kv_block)

    def q_step(_, qi):
        qblk, qidx = qi                                   # [b,qb,hkv,g,d], []
        qpos = qidx * q_block + qpos_base

        def kv_step(carry, kvi):
            m, l, acc = carry
            kblk, vblk, kidx = kvi
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk)
            s = s.astype(jnp.float32) * scale
            if causal:
                kpos = kidx * kv_block + kpos_base
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(qblk.dtype), vblk)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # [b,hkv,g,qb,d] -> [b,qb,hkv,g,d]
        return None, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None,
                           (qg.swapaxes(0, 1), jnp.arange(nq)))
    # outs: [nq, b, qb, hkv, g, d]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, d)
    return out


def attend_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                  cache_len: jax.Array,
                  scale: Optional[float] = None) -> jax.Array:
    """One decode step. q: [B, 1, Hq, d]; caches: [B, S, Hkv, d];
    cache_len: [] or [B] — number of valid cache positions (includes the
    token being decoded, whose K/V must already be written).
    """
    b, _, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    scale = scale if scale is not None else d ** -0.5
    qg = _group(q, hkv)[:, 0]                             # [B, Hkv, G, d]
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32)
    logits = logits * scale
    valid = jnp.arange(s)[None, :] < jnp.reshape(cache_len, (-1, 1))
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v_cache)
    return out.reshape(b, 1, hq, d)
