"""Durability journal: the data plane's incremental checkpoint log.

One ``append`` writes one ``step_N`` directory through
``repro.train.checkpoint.save`` — the same atomic tmp-dir + fsync +
per-leaf sha256 discipline the train state uses — so a torn write can
never be mistaken for a valid step. A step carries:

* the NEW broker records per (topic, partition) since the previous step
  (one concatenated column set per partition — safe because master-topic
  compaction is last-writer-wins by txn_time, associative over
  concatenation) and the NEW warehouse chunks (the commit-log suffix);
* the FULL small state every step: committed offsets, routing tables +
  live history horizons, publish/key-load counters, listener offsets,
  late buffers, per-worker cache watermarks, serving fold state,
  partition assignment, warehouse counters. Re-writing these is cheap
  (KBs) and makes every step self-describing for that state;
* a chain record: the previous step's totals (warehouse commit seq,
  per-partition broker lengths). ``load`` verifies the chain, so a step
  whose predecessor was lost is detected, not silently replayed over a
  gap.

Monotone int64 leaf columns (lsn, txn_time) are delta-encoded before the
write — ``np.diff`` + int32 downcast, the ``train/compression.py``
delta-coding idiom applied to the chunk-log suffix — which halves the
dominant leaves in the (uncompressed) npz container.

``load`` walks steps oldest-first, validating every leaf checksum. Torn
or corrupt steps at the TAIL are pruned (the crash window: nothing after
them can exist); corruption in the MIDDLE of the chain raises — the
journal is then not a consistent prefix and silently skipping would
violate exactly-once.
"""
from __future__ import annotations

import itertools
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.durability.faults import (CHECKPOINT_MID_WRITE, FaultInjector,
                                     NULL_INJECTOR)
from repro.observability.registry import global_registry
from repro.train import checkpoint as ckpt

_JOURNAL_SEQ = itertools.count()

_LEAF = "__leaf__"      # placeholder key marking an extracted array leaf


def _delta_encode(a: np.ndarray) -> Tuple[np.ndarray, Dict[str, Any]]:
    """Delta-encode a 1-D int64 array when the diffs fit int32 (monotone
    LSN/txn columns always do); otherwise store raw. Exact roundtrip."""
    if a.ndim == 1 and a.dtype == np.int64 and len(a) >= 8:
        d = np.diff(a)
        if len(d) and np.abs(d).max() < (1 << 31):
            return d.astype(np.int32), {"enc": "d32", "first": int(a[0]),
                                        "n": int(len(a))}
        if not len(d):
            return d.astype(np.int32), {"enc": "d32", "first": int(a[0]),
                                        "n": int(len(a))}
    return a, {"enc": "raw"}


def _delta_decode(leaf: np.ndarray, meta: Dict[str, Any]) -> np.ndarray:
    if meta.get("enc") != "d32":
        return leaf
    out = np.empty(meta["n"], np.int64)
    out[0] = meta["first"]
    if meta["n"] > 1:
        out[1:] = meta["first"] + np.cumsum(leaf.astype(np.int64))
    return out


def _extract_leaves(node, leaves: List[np.ndarray]):
    """Replace every ndarray in a nested dict/list structure with a
    ``{_LEAF: index, ...enc meta}`` placeholder, collecting the (possibly
    delta-encoded) arrays into ``leaves``. Scalars/str/None pass through
    as JSON."""
    if isinstance(node, np.ndarray):
        enc, meta = _delta_encode(node)
        idx = len(leaves)
        leaves.append(enc)
        return {_LEAF: idx, **meta}
    if isinstance(node, dict):
        return {k: _extract_leaves(v, leaves) for k, v in node.items()}
    if isinstance(node, (list, tuple)):
        return [_extract_leaves(v, leaves) for v in node]
    if isinstance(node, (np.integer,)):
        return int(node)
    if isinstance(node, (np.floating,)):
        return float(node)
    return node


def _collect_leaf_ids(node, out: set):
    """Leaf indices reachable from a layout subtree (placeholders only)."""
    if isinstance(node, dict):
        if _LEAF in node:
            out.add(node[_LEAF])
            return
        for v in node.values():
            _collect_leaf_ids(v, out)
    elif isinstance(node, list):
        for v in node:
            _collect_leaf_ids(v, out)


def _inject_leaves(node, leaves: List[np.ndarray]):
    if isinstance(node, dict):
        if _LEAF in node:
            leaf = leaves[node[_LEAF]]
            return None if leaf is None else _delta_decode(leaf, node)
        return {k: _inject_leaves(v, leaves) for k, v in node.items()}
    if isinstance(node, list):
        return [_inject_leaves(v, leaves) for v in node]
    return node


class DurabilityJournal:
    """Append-only directory of checkpoint steps (``step_0``, ``step_1``,
    ...). Thread-compatible: one checkpointer appends at a time (the
    RecoveryCoordinator serializes appends under its own lock)."""

    def __init__(self, root: str, fault: FaultInjector = NULL_INJECTOR):
        self.root = str(root)
        self.fault = fault
        os.makedirs(self.root, exist_ok=True)
        # one registry shard per journal INSTANCE (same idiom as compute
        # backends): per-instance values stay isolated, merged reads sum
        # process-wide journal activity
        shard = global_registry().shard(f"journal#{next(_JOURNAL_SEQ)}")
        self.metrics = shard
        self._c_steps = shard.counter("journal.steps_appended")
        self._c_loads = shard.counter("journal.loads")
        self._c_pruned = shard.counter("journal.steps_pruned")

    # ------------------------------------------------------------------ write
    def steps(self) -> List[int]:
        return ckpt.step_numbers(self.root)

    def _dir_for(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step}")

    def append(self, state: Dict[str, Any],
               totals: Dict[str, Any], prev: Dict[str, Any]) -> int:
        """Write one incremental step. ``state`` is the captured nested
        dict (arrays anywhere); ``totals`` are the post-step cumulative
        marks (chunk seq, broker lengths); ``prev`` the pre-step marks —
        the chain link ``load`` validates. Returns the step number."""
        steps = self.steps()
        step = (steps[-1] + 1) if steps else 0
        leaves: List[np.ndarray] = []
        layout = _extract_leaves(state, leaves)
        extra = {"layout": layout, "totals": totals, "prev": prev}
        fault = self.fault
        ckpt.save(self._dir_for(step), step, leaves, extra,
                  pre_commit=lambda: fault.trip(CHECKPOINT_MID_WRITE))
        self._c_steps.inc()
        return step

    def last_totals(self) -> Optional[Dict[str, Any]]:
        """Cumulative marks as of the newest complete step (manifest
        extras only — a step dir is only visible post-rename, so its
        manifest is always whole)."""
        for step in reversed(self.steps()):
            try:
                with open(os.path.join(self._dir_for(step),
                                       "manifest.json")) as f:
                    return json.load(f)["extra"]["totals"]
            except (OSError, KeyError, json.JSONDecodeError):
                continue
        return None

    # ------------------------------------------------------------------- read
    def load(self) -> Optional[Dict[str, Any]]:
        """Reassemble the accumulated state from every valid step.

        Returns None for an empty journal. The result is the LAST step's
        small state plus the across-step concatenation of broker segments
        (per topic/partition, in step order) and warehouse chunks, with
        ``_totals`` (cumulative marks) and ``_step`` (newest step number)
        attached. Tail corruption prunes; mid-chain corruption raises."""
        ckpt.sweep_tmp(self.root)        # crash leftovers are never valid
        steps = self.steps()
        if not steps:
            return None
        restored: List[Tuple[int, Dict[str, Any], Dict[str, Any]]] = []
        failed_at: Optional[int] = None
        for s in steps:
            try:
                # non-final steps: only their broker segments and
                # warehouse chunks are consumed downstream — restore just
                # those leaves (the final step's FULL small state is what
                # the recovered pipeline resumes from). Skipped leaves
                # are never validated, but never read either; structural
                # corruption (a torn zip) still raises here.
                only = None
                if s != steps[-1]:
                    with open(os.path.join(self._dir_for(s),
                                           "manifest.json")) as f:
                        layout = json.load(f)["extra"]["layout"]
                    only = set()
                    _collect_leaf_ids(layout["broker"]["segments"], only)
                    _collect_leaf_ids(layout["warehouse"]["chunks"], only)
                _, leaves, extra = ckpt.restore(self._dir_for(s), None,
                                                only=only)
            except Exception:
                failed_at = s
                break
            restored.append((s, _inject_leaves(extra["layout"], leaves),
                             extra))
        if failed_at is not None:
            later = [s for s in steps if s > failed_at]
            if later:
                raise IOError(
                    f"journal step {failed_at} corrupt with later steps "
                    f"{later} present: not a consistent prefix")
            # tail crash: drop the torn step, recover from the prefix
            shutil.rmtree(self._dir_for(failed_at), ignore_errors=True)
            self._c_pruned.inc()
        self._c_loads.inc()
        if not restored:
            return None
        # chain validation + accumulation
        segments: Dict[str, Dict[int, List[Dict[str, np.ndarray]]]] = {}
        chunks: List[np.ndarray] = []
        expected = {"chunk_seq": 0, "broker_lengths": {}}
        for s, state, extra in restored:
            prev = extra["prev"]
            if prev["chunk_seq"] != expected["chunk_seq"]:
                raise IOError(
                    f"journal chain broken at step {s}: expects chunk seq "
                    f"{prev['chunk_seq']}, accumulated "
                    f"{expected['chunk_seq']}")
            for topic, seg in state["broker"]["segments"].items():
                for p_str, cols in seg.items():
                    if cols is None or not len(cols.get("row_key", ())):
                        continue
                    segments.setdefault(topic, {}).setdefault(
                        int(p_str), []).append(cols)
            chunks.extend(state["warehouse"]["chunks"])
            expected = extra["totals"]
        last = restored[-1][1]
        last["broker"]["segments"] = segments
        last["warehouse"]["chunks"] = chunks
        last["_totals"] = restored[-1][2]["totals"]
        last["_step"] = restored[-1][0]
        return last
