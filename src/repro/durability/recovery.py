"""Exactly-once crash recovery: consistent capture + cold-restart restore.

``RecoveryCoordinator`` owns one ``DurabilityJournal`` and the two halves
of the durability contract:

* **capture** — snapshot the data plane at a *commit boundary*. The
  capture takes the Change Tracker's extraction lock plus every live
  worker's commit lock (``extra_locks``, sorted by the caller), so the
  journaled broker content, committed offsets, warehouse chunk log,
  listener offsets, late buffers and cache watermarks are all consistent
  with each other: no listener is mid-publish, no worker is between its
  warehouse load and its offset commit. Read-ahead positions are
  deliberately NOT captured (a restart abandons them — the same contract
  a worker death has always had), and the serving front is read lock-free
  (it is an immutable epoch whose ``deltas_folded`` can never exceed the
  warehouse commit seq captured under the same locks, because folds only
  consume published commits).

* **restore** — rebuild a FRESH pipeline from the journal: broker logs +
  compaction indexes + routing epochs, committed offsets, the full
  chunk log, listener offsets, partition assignment, late buffers,
  caches (re-dumped from the restored compacted topics, then the
  checkpointed watermarks reinstated — the re-dump advances the
  watermark past records the crashed process had not pumped yet, which
  would release late-buffer records early), and the serving fold state.
  The view engine resumes from its checkpointed epoch and the warehouse
  replays ONLY the chunk-log suffix past ``deltas_folded`` — recovery
  work is O(suffix since last checkpoint), never O(history).

Everything a consumer re-reads after restore sits between the committed
offset and the broker high watermark: records fetched-but-uncommitted at
the crash. Their warehouse loads (if any happened) are *gone* — the
warehouse rolled back to the checkpoint+committed-suffix state — so
reprocessing them is exactly-once, not at-least-once.

Imports of ``repro.core.pipeline`` are lazy (inside functions):
``pipeline`` imports ``repro.durability.faults``, which initializes this
package — a module-level import back into ``pipeline`` would cycle.
"""
from __future__ import annotations

import contextlib
import copy
import threading
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.durability.journal import DurabilityJournal

_EMPTY_MARKS: Dict[str, Any] = {"chunk_seq": 0, "broker_lengths": {}}


class RecoveryCoordinator:
    """Checkpoint scheduling + restore against one journal. Thread-safe:
    ``checkpoint`` serializes under its own lock (concurrent callers
    queue; each step's incremental marks stay consistent)."""

    def __init__(self, journal: DurabilityJournal):
        self.journal = journal
        self._lock = threading.Lock()
        self._marks: Optional[Dict[str, Any]] = None   # cumulative, journaled
        # health-snapshot surface: how many steps this coordinator took,
        # when the last one landed (perf_counter — compare against "now"
        # for checkpoint age) and at which journal step
        self.checkpoints_taken = 0
        self.restores_done = 0
        self.last_checkpoint_at: Optional[float] = None
        self.last_checkpoint_step: Optional[int] = None

    def _current_marks(self) -> Dict[str, Any]:
        if self._marks is None:
            self._marks = self.journal.last_totals() or copy.deepcopy(
                _EMPTY_MARKS)
        return self._marks

    # ----------------------------------------------------------------- capture
    def capture(self, pipe, engine=None, extra_locks=()) -> Dict[str, Any]:
        """One consistent snapshot of the data plane (see module doc).
        ``extra_locks`` are the live workers' commit locks — the caller
        (the concurrent cluster) supplies them in a FIXED sort order so
        two concurrent captures cannot deadlock; the sequential runtime
        passes none (nothing runs between its steps)."""
        marks = self._current_marks()
        with contextlib.ExitStack() as stack:
            stack.enter_context(pipe.tracker.lock)
            for lk in extra_locks:
                stack.enter_context(lk)
            state: Dict[str, Any] = {
                "broker": pipe.queue.export_state(
                    since=marks.get("broker_lengths")),
                "warehouse": pipe.warehouse.export_state(
                    int(marks.get("chunk_seq", 0))),
                "serving": (engine.export_fold_state()
                            if engine is not None else None),
                "workers": {
                    w.name: {
                        "buffer": w.buffer.export_state(),
                        "dead_letter": w.dead_letter.export_state(),
                        "watermarks": {
                            "equipment": int(w.equipment.watermark),
                            "quality": int(w.quality.watermark),
                        },
                    } for w in pipe.workers},
                "listeners": {l.table.name: int(l.offset)
                              for l in pipe.tracker.listeners},
                "assignment": {
                    "n_partitions": int(pipe.assignment.n_partitions),
                    "owners": {str(p): o for p, o in
                               pipe.assignment.assignment.items()},
                },
            }
        return state

    def checkpoint(self, pipe, engine=None, extra_locks=()) -> int:
        """Capture + append one incremental journal step. Returns the
        step number. The cumulative marks only advance after the step is
        durably renamed in — a crash mid-write leaves the marks (and the
        next checkpoint's increments) exactly where they were."""
        with self._lock:
            prev = copy.deepcopy(self._current_marks())
            state = self.capture(pipe, engine=engine,
                                 extra_locks=extra_locks)
            totals = {
                "chunk_seq": int(state["warehouse"]["seq"]),
                "broker_lengths": {
                    topic: [int(n) for n in meta["lengths"]]
                    for topic, meta in state["broker"]["meta"].items()},
            }
            step = self.journal.append(state, totals, prev)
            self._marks = totals
            self.checkpoints_taken += 1
            self.last_checkpoint_at = time.perf_counter()
            self.last_checkpoint_step = step
            pipe.metrics.shard("coordinator").counter(
                "pipeline.checkpoints").inc()
            return step

    # ----------------------------------------------------------------- restore
    def restore(self, pipe, engine=None,
                state: Optional[Dict[str, Any]] = None
                ) -> Optional[Dict[str, Any]]:
        """Cold-restart restore into a FRESH pipeline (and optionally a
        fresh view engine). Returns an info dict — ``step``,
        ``commit_seq``, ``replayed_chunks`` (the serving suffix) — or
        None when the journal is empty (nothing to restore; the pipeline
        simply starts cold)."""
        if state is None:
            state = self.journal.load()
        if state is None:
            return None
        # 1. broker first: logs, compaction, routing epochs, committed
        #    offsets — everything below consults routing or offsets
        pipe.queue.restore_broker_state(state["broker"])
        # 2. warehouse BEFORE any serving attach (chunks land silently)
        pipe.warehouse.restore_state(state["warehouse"])
        # 3. extraction frontier
        for l in pipe.tracker.listeners:
            if l.table.name in state["listeners"]:
                l.offset = int(state["listeners"][l.table.name])
        # 4. partition ownership (business-key filters depend on it)
        asg = state["assignment"]
        if int(asg["n_partitions"]) > pipe.assignment.n_partitions:
            pipe.assignment.grow(int(asg["n_partitions"]))
        pipe.assignment.assignment = {int(p): o
                                      for p, o in asg["owners"].items()}
        pipe._apply_assignment()
        # 5. workers: late buffers, caches (re-dump from the restored
        #    compacted topics), then the checkpointed watermarks — the
        #    re-dump sets the watermark to the snapshot's max txn_time,
        #    which may cover master records the crashed process had not
        #    pumped yet; releasing late records against that watermark
        #    would diverge from the uninterrupted run
        for w in pipe.workers:
            ws = state["workers"].get(w.name)
            if ws is None:
                continue
            w.buffer = _restore_buffer(ws["buffer"], pipe.cfg.buffer_capacity)
            w.transformer.buffer = w.buffer
            # quarantined records' offsets are committed — losing the DLQ
            # across a restore would silently lose the records themselves
            w.dead_letter = _restore_dead_letter(ws.get("dead_letter"))
            w.reset_caches(pipe.master_topic_map, pipe.cfg.n_business_keys)
            w.equipment.watermark = int(ws["watermarks"]["equipment"])
            w.quality.watermark = int(ws["watermarks"]["quality"])
        # 6. serving: resume the checkpointed epoch, replay only the
        #    chunk-log suffix past it
        replayed = 0
        if engine is not None:
            serving = state.get("serving")
            folded = 0
            if serving is not None:
                engine.restore_fold_state(serving)
                folded = int(serving["deltas_folded"])
            replayed = int(state["warehouse"]["seq"]) - folded
            pipe.warehouse.attach_serving(engine, replay_from=folded)
        self._marks = copy.deepcopy(state["_totals"])
        self.restores_done += 1
        pipe.metrics.shard("coordinator").counter(
            "pipeline.restores").inc()
        return {"step": int(state["_step"]),
                "commit_seq": int(state["warehouse"]["seq"]),
                "replayed_chunks": replayed}


def recover_pipeline(cfg, source, journal: DurabilityJournal, *,
                     engine=None, join_depth: int = 1, backend=None,
                     fault=None, n_workers: int = 1
                     ) -> Tuple[Any, RecoveryCoordinator,
                                Optional[Dict[str, Any]]]:
    """Cold restart in one call: build a fresh ``DODETLPipeline`` shaped
    like the journaled one (same worker names — consumer groups derive
    from them, so the committed offsets must land on matching groups) and
    restore into it. Returns ``(pipeline, coordinator, info)``; ``info``
    is None when the journal was empty.

    ``source`` is the surviving system of record (the CDC log outlives
    the ETL deployment — the paper's premise); ``cfg`` must match the
    crashed deployment's config. ``n_workers`` only applies when the
    journal is empty (a crash before the first checkpoint): a journaled
    state dictates the worker set, a cold start needs the caller to
    restate the deployment shape.
    """
    from repro.core.pipeline import DODETLPipeline   # lazy: import cycle
    coord = RecoveryCoordinator(journal)
    state = journal.load()
    names = sorted(state["workers"]) if state else None
    pipe = DODETLPipeline(cfg, source,
                          n_workers=(len(names) if names else n_workers),
                          join_depth=join_depth, backend=backend,
                          fault=fault)
    if names and [w.name for w in pipe.workers] != names:
        # recreate the journaled worker set (e.g. post-failover names)
        pipe.workers = [pipe._new_worker(n, join_depth) for n in names]
        pipe._apply_assignment()
    info = coord.restore(pipe, engine=engine, state=state) \
        if state is not None else None
    return pipe, coord, info


def _restore_buffer(state: Dict[str, Any], capacity: int):
    from repro.core.buffer import OperationalMessageBuffer
    return OperationalMessageBuffer.restore(state, capacity)


def _restore_dead_letter(state: Optional[Dict[str, Any]]):
    from repro.core.buffer import DeadLetterBuffer
    return DeadLetterBuffer.restore(state)
