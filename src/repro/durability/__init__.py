"""Durability layer: incremental journal checkpoints of the data plane +
exactly-once crash recovery (ROADMAP item 2; see ARCHITECTURE.md
"Durability & recovery").

Public surface:

* ``FaultInjector`` / ``InjectedCrash`` — deterministic named crash
  points at the pipeline's stage seams (``repro.durability.faults``);
* ``DurabilityJournal`` — atomic incremental checkpoint steps built on
  ``repro.train.checkpoint`` (``repro.durability.journal``);
* ``RecoveryCoordinator`` / ``recover_pipeline`` — consistent capture at
  commit boundaries and full cold-restart restore
  (``repro.durability.recovery``).
"""
from repro.durability.faults import (CRASH_POINTS, FaultInjector,
                                     InjectedCrash, NULL_INJECTOR)
from repro.durability.journal import DurabilityJournal
from repro.durability.recovery import RecoveryCoordinator, recover_pipeline

__all__ = ["CRASH_POINTS", "FaultInjector", "InjectedCrash",
           "NULL_INJECTOR", "DurabilityJournal", "RecoveryCoordinator",
           "recover_pipeline"]
