"""Deterministic fault injection at the pipeline's stage seams.

A ``FaultInjector`` is armed with a schedule ``{point: ordinal}``: the
ordinal-th time execution reaches the named crash point, the process
"dies" — either by raising ``InjectedCrash`` (in-process drills: the
test abandons the pipeline objects, exactly as a kill would, and
recovers into fresh ones) or by ``SIGKILL``-ing the whole process
(cross-process kill-9 drills: the parent recovers from the journal).

Crash points are *seams*, not random preemption: each one sits at a
stage boundary where in-flight state differs (fetched-uncommitted,
transformed-unloaded, loaded-uncommitted, checkpoint written-unrenamed,
repartition half-applied). Recovery must be exactly-once from every one
of them — that is what ``tests/test_recovery.py`` drills.

The default injector (``NULL_INJECTOR``) never trips; ``trip`` on it is
one dict lookup, so production paths pay nothing measurable.
"""
from __future__ import annotations

import os
import signal
import threading
from typing import Dict, Optional

# canonical crash-point names (the seams wired through pipeline/cluster)
INGEST_FETCH = "ingest.fetch"            # records fetched, nothing committed
TRANSFORM_DONE = "transform.done"        # facts computed, nothing loaded
LOAD_PRE_COMMIT = "load.pre_commit"      # warehouse loaded, offsets NOT committed
COMMIT_POST = "commit.post"              # offsets committed (post-boundary)
CHECKPOINT_MID_WRITE = "checkpoint.mid_write"  # journal tmp written, not renamed
REPARTITION_MID = "repartition.mid"      # epoch switched, ownership not rebalanced

CRASH_POINTS = (INGEST_FETCH, TRANSFORM_DONE, LOAD_PRE_COMMIT, COMMIT_POST,
                CHECKPOINT_MID_WRITE, REPARTITION_MID)


class InjectedCrash(BaseException):
    """Raised at a tripped crash point. Derives from BaseException so an
    over-broad ``except Exception`` in a stage loop cannot swallow the
    simulated death and keep processing."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"injected crash at {point} (hit #{hit})")
        self.point = point
        self.hit = hit


class FaultInjector:
    """Named crash points with per-point Nth-hit ordinals.

    ``schedule`` maps point name -> ordinal (1-based): the ordinal-th
    ``trip(point)`` call crashes; earlier and later hits pass through.
    ``mode``:

    * ``"raise"``   — raise ``InjectedCrash`` in the tripping thread
      (other stage threads keep running until the drill abandons them —
      the in-process analogue of a kill);
    * ``"sigkill"`` — ``os.kill(os.getpid(), SIGKILL)``: the real thing,
      for cross-process drills (benchmarks/recovery_bench.py --kill9).

    Hit counting is lock-protected so concurrent stage threads tripping
    the same point resolve to exactly one ordinal each; ``tripped`` is a
    ``threading.Event`` drills wait on before abandoning the cluster.
    """

    def __init__(self, schedule: Optional[Dict[str, int]] = None,
                 mode: str = "raise"):
        assert mode in ("raise", "sigkill"), mode
        self.schedule = dict(schedule or {})
        self.mode = mode
        self.counts: Dict[str, int] = {}
        self.tripped = threading.Event()
        self.tripped_at: Optional[str] = None
        self._lock = threading.Lock()

    def trip(self, point: str) -> None:
        """Crash if ``point``'s scheduled ordinal is reached; no-op
        otherwise (and always a no-op once something has tripped — the
        process is already 'dead', surviving threads must not re-die
        into cascading exceptions mid-teardown)."""
        target = self.schedule.get(point)
        if target is None:
            return
        with self._lock:
            if self.tripped.is_set():
                return
            hit = self.counts.get(point, 0) + 1
            self.counts[point] = hit
            if hit != target:
                return
            self.tripped_at = point
            self.tripped.set()
        if self.mode == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)
        raise InjectedCrash(point, hit)


class _NullInjector(FaultInjector):
    """The default: never trips. ``trip`` short-circuits on the empty
    schedule, so hot paths carry one dict ``get`` per seam."""

    def __init__(self):
        super().__init__({}, "raise")


NULL_INJECTOR = _NullInjector()
