"""Deterministic fault injection at the pipeline's stage seams.

A ``FaultInjector`` is armed with a schedule ``{point: ordinal}``: the
ordinal-th time execution reaches the named crash point, the process
"dies" — either by raising ``InjectedCrash`` (in-process drills: the
test abandons the pipeline objects, exactly as a kill would, and
recovers into fresh ones) or by ``SIGKILL``-ing the whole process
(cross-process kill-9 drills: the parent recovers from the journal).
A third action, ``"hang"``, freezes the tripping thread instead of
killing it — the grey-failure case (a wedged stage, a straggler) that
the control plane's heartbeat detection exists to catch.

Crash points are *seams*, not random preemption: each one sits at a
stage boundary where in-flight state differs (fetched-uncommitted,
transformed-unloaded, loaded-uncommitted, checkpoint written-unrenamed,
repartition half-applied). Recovery must be exactly-once from every one
of them — that is what ``tests/test_recovery.py`` drills; the control
seams (``heartbeat.miss``, ``restart.pre_hydrate``, ``control.decide``)
are what ``tests/test_control.py`` drills.

The default injector (``NULL_INJECTOR``) never trips; ``trip`` on it is
one dict lookup, so production paths pay nothing measurable.
"""
from __future__ import annotations

import os
import signal
import threading
import time
from typing import Dict, Optional

# canonical crash-point names (the seams wired through pipeline/cluster)
INGEST_FETCH = "ingest.fetch"            # records fetched, nothing committed
TRANSFORM_DONE = "transform.done"        # facts computed, nothing loaded
LOAD_PRE_COMMIT = "load.pre_commit"      # warehouse loaded, offsets NOT committed
COMMIT_POST = "commit.post"              # offsets committed (post-boundary)
CHECKPOINT_MID_WRITE = "checkpoint.mid_write"  # journal tmp written, not renamed
REPARTITION_MID = "repartition.mid"      # epoch switched, ownership not rebalanced
HEARTBEAT_MISS = "heartbeat.miss"        # stage loop heartbeat (hang = frozen stage)
RESTART_PRE_HYDRATE = "restart.pre_hydrate"  # supervisor about to re-hydrate a worker
CONTROL_DECIDE = "control.decide"        # controller about to execute a decision

CRASH_POINTS = (INGEST_FETCH, TRANSFORM_DONE, LOAD_PRE_COMMIT, COMMIT_POST,
                CHECKPOINT_MID_WRITE, REPARTITION_MID, HEARTBEAT_MISS,
                RESTART_PRE_HYDRATE, CONTROL_DECIDE)

_ACTIONS = ("raise", "sigkill", "hang")


class InjectedCrash(BaseException):
    """Raised at a tripped crash point. Derives from BaseException so an
    over-broad ``except Exception`` in a stage loop cannot swallow the
    simulated death and keep processing."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"injected crash at {point} (hit #{hit})")
        self.point = point
        self.hit = hit


class FaultInjector:
    """Named crash points with per-point Nth-hit ordinals.

    ``schedule`` maps point name -> ordinal(s). An ordinal is 1-based;
    a single int trips that hit only, a set/list/tuple of ints trips at
    each listed hit (e.g. every restart attempt). ``mode`` is the
    default action, overridable per point via ``actions``:

    * ``"raise"``   — raise ``InjectedCrash`` in the tripping thread
      (other stage threads keep running until the drill abandons them —
      the in-process analogue of a kill);
    * ``"sigkill"`` — ``os.kill(os.getpid(), SIGKILL)``: the real thing,
      for cross-process drills (benchmarks/recovery_bench.py --kill9);
    * ``"hang"``    — block the tripping thread on an internal event
      until ``release_hangs()`` (or a long safety timeout). A hang is a
      grey failure, not a death: it does NOT set ``tripped``, so
      checkpointing and the rest of the process carry on around the
      frozen thread — exactly what heartbeat detection must catch.

    ``sticky`` (default True) preserves the original drill contract:
    after the first kill-trip, every later trip is a no-op (the process
    is already 'dead'). Control-plane chaos schedules pass
    ``sticky=False`` so several independent faults can fire in one run.

    Hit counting is lock-protected so concurrent stage threads tripping
    the same point resolve to exactly one ordinal each; ``tripped`` is a
    ``threading.Event`` drills wait on before abandoning the cluster,
    and ``hung`` is its grey-failure sibling (set on the first hang).
    """

    def __init__(self, schedule: Optional[Dict[str, object]] = None,
                 mode: str = "raise",
                 actions: Optional[Dict[str, str]] = None,
                 sticky: bool = True,
                 hang_timeout_s: float = 300.0):
        assert mode in _ACTIONS, mode
        for pt, act in (actions or {}).items():
            assert act in _ACTIONS, (pt, act)
        self.schedule = dict(schedule or {})
        self.mode = mode
        self.actions = dict(actions or {})
        self.sticky = sticky
        self.hang_timeout_s = hang_timeout_s
        self.counts: Dict[str, int] = {}
        self.tripped = threading.Event()
        self.tripped_at: Optional[str] = None
        self.hung = threading.Event()
        self.hangs: Dict[str, int] = {}
        self.hung_at_s: Optional[float] = None
        self._hang_release = threading.Event()
        self._lock = threading.Lock()

    def _scheduled(self, point: str, hit: int) -> bool:
        target = self.schedule.get(point)
        if target is None:
            return False
        if isinstance(target, int):
            return hit == target
        return hit in target

    def trip(self, point: str) -> None:
        """Act if ``point``'s scheduled ordinal is reached; no-op
        otherwise. With ``sticky`` (the default), all trips become
        no-ops once something has kill-tripped — the process is already
        'dead', surviving threads must not re-die into cascading
        exceptions mid-teardown. Hangs never arm that latch."""
        if self.schedule.get(point) is None:
            return
        with self._lock:
            if self.sticky and self.tripped.is_set():
                return
            hit = self.counts.get(point, 0) + 1
            self.counts[point] = hit
            if not self._scheduled(point, hit):
                return
            action = self.actions.get(point, self.mode)
            if action == "hang":
                self.hangs[point] = self.hangs.get(point, 0) + 1
                if not self.hung.is_set():
                    self.hung_at_s = time.perf_counter()
                    self.hung.set()
            else:
                self.tripped_at = point
                self.tripped.set()
        if action == "hang":
            self._hang_release.wait(self.hang_timeout_s)
            return
        if action == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)
        raise InjectedCrash(point, hit)

    def release_hangs(self) -> None:
        """Unblock every thread frozen by a ``hang`` trip (drill
        teardown — released threads observe their stop flags and exit)."""
        self._hang_release.set()


class _NullInjector(FaultInjector):
    """The default: never trips. ``trip`` short-circuits on the empty
    schedule, so hot paths carry one dict ``get`` per seam."""

    def __init__(self):
        super().__init__({}, "raise")


NULL_INJECTOR = _NullInjector()
