"""Manual data-parallel training step via shard_map (beyond-paper §Perf).

The pure-GSPMD step pays a full gradient all-reduce per *microbatch*
(measured: 3.4 TB/step on the 33B train cell at n_mb=16) because XLA cannot
prove the reduction can be deferred across scan iterations. Here the data
axis is MANUAL: each data shard runs its own microbatch loop with zero
cross-data traffic, then the gradient crosses the wire exactly once as a
``psum_scatter`` (ZeRO reduce-scatter) and the weight delta returns once as
an ``all_gather``. The model (TP/EP) axis stays AUTO, so all intra-layer
partitioning is still GSPMD-driven from the parameter shardings.

Wire cost per step: params_bytes * (RS + AG) ~= params * 2, independent of
microbatch count — vs params * 2 * n_mb for the auto step.

Optimizer states live permanently in the scattered (ZeRO) layout; each leaf
records its scatter dimension (the largest dim divisible by the data-axis
size; tiny/indivisible leaves stay replicated and use a plain psum).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.models.sharding import NULL_CTX, ShardingCtx
from repro.optim import AdamWConfig, AdamWState, schedule
from repro.train.train_step import cross_entropy, _split_microbatches


def scatter_dims(model: Model, data_size: int, model_specs) -> Any:
    """Per-leaf ZeRO scatter dimension: the largest dim that is divisible by
    the data-axis size AND not already sharded by the (auto) model axis;
    -1 -> replicated over data."""
    from repro.models.param import is_def

    def one(d, spec):
        taken = set()
        for i, entry in enumerate(spec):
            if entry is not None:
                taken.add(i)
        best, best_size = -1, 0
        for i, s in enumerate(d.shape):
            if i in taken:
                continue
            if s % data_size == 0 and s > best_size:
                best, best_size = i, s
        return best

    flat_defs = jax.tree.leaves(model.defs, is_leaf=is_def)
    flat_specs = jax.tree.leaves(model_specs,
                                 is_leaf=lambda x: isinstance(x, P))
    treedef = jax.tree.structure(model.defs, is_leaf=is_def)
    return jax.tree.unflatten(
        treedef, [one(df, sp) for df, sp in zip(flat_defs, flat_specs)])


def merge_specs(model_specs, sdims, data_axes) -> Any:
    """Moment layout: model-TP spec + data scatter on the ZeRO dim."""
    def one(spec, d):
        entries = list(spec) + [None] * (8 - len(spec))
        if d >= 0:
            entries[d] = data_axes if len(data_axes) > 1 else data_axes[0]
        # trim trailing Nones
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    flat_specs = jax.tree.leaves(model_specs,
                                 is_leaf=lambda x: isinstance(x, P))
    flat_d = jax.tree.leaves(sdims)
    treedef = jax.tree.structure(sdims)
    return jax.tree.unflatten(
        treedef, [one(sp, d) for sp, d in zip(flat_specs, flat_d)])


def make_manual_dp_train_step(model: Model, opt_cfg: AdamWConfig,
                              mesh: Mesh, rules: Dict[str, Any],
                              batch_axes: Dict[str, Tuple], *,
                              multi_pod: bool = False,
                              compress_pod_axis: bool = False):
    """Returns (jitted_step, opt_specs, param_sharding, batch_sharding_fn).

    The returned step has signature (params, opt_state, batch) ->
    (params, opt_state, metrics); opt moments must be laid out per
    ``opt_specs`` (ZeRO-scattered over the data axes).
    """
    cfg = model.cfg
    data_axes = ("pod", "data") if multi_pod else ("data",)
    data_size = 1
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in data_axes:
        data_size *= axis_sizes[a]

    param_specs_model = model.specs(rules, mesh)
    sdims = scatter_dims(model, data_size, param_specs_model)
    ctx = NULL_CTX  # inside shard_map the data dims are local; TP is auto

    def loss_fn(p, mb):
        logits, _, aux = model.forward(p, mb, mode="train", ctx=ctx)
        return cross_entropy(logits, mb["targets"]) + aux

    vg = jax.value_and_grad(loss_fn)

    def shard_body(params, opt_state: AdamWState, batch):
        n_mb = max(cfg.microbatches, 1)
        mbs = _split_microbatches(batch, n_mb)

        def mb_step(carry, mb):
            g_acc, loss_acc = carry
            loss, g = vg(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, loss_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(mb_step, (g0, 0.0), mbs)
        loss = jax.lax.pmean(loss_sum / n_mb, data_axes)

        # ---- the single cross-data reduction, fused with the ZeRO scatter
        def reduce_leaf(g, d):
            g = g / n_mb
            if d < 0:
                return jax.lax.pmean(g, data_axes)
            for ax in data_axes:   # scatter over each data axis in turn
                g = jax.lax.psum_scatter(g, ax, scatter_dimension=d,
                                         tiled=True)
            return g / data_size   # psum_scatter sums; take the mean

        g_sharded = jax.tree.map(
            reduce_leaf, grads,
            jax.tree.unflatten(jax.tree.structure(grads),
                               jax.tree.leaves(sdims)))

        # ---- AdamW on the scattered shards
        step = opt_state.step + 1
        lr = schedule(opt_cfg, step)
        b1c = 1 - opt_cfg.b1 ** step.astype(jnp.float32)
        b2c = 1 - opt_cfg.b2 ** step.astype(jnp.float32)
        # global grad-norm: scattered leaves partition the param space so a
        # plain psum of local sumsq is exact; replicated leaves appear on
        # every shard and must be pre-divided
        local_sq = jnp.zeros((), jnp.float32)
        for g, d in zip(jax.tree.leaves(g_sharded), jax.tree.leaves(sdims)):
            sq = jnp.sum(jnp.square(g))
            local_sq += sq / data_size if d < 0 else sq
        gnorm = jnp.sqrt(jax.lax.psum(local_sq, data_axes))
        scale = jnp.minimum(1.0, opt_cfg.clip_norm / (gnorm + 1e-9))

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(g_sharded)
        flat_m = jax.tree.leaves(opt_state.mu)
        flat_v = jax.tree.leaves(opt_state.nu)
        flat_d = jax.tree.leaves(sdims)
        new_p, new_m, new_v = [], [], []
        for p_leaf, g, m, v, d in zip(flat_p, flat_g, flat_m, flat_v, flat_d):
            g = g * scale
            m_new = opt_cfg.b1 * m + (1 - opt_cfg.b1) * g
            v_new = opt_cfg.b2 * v + (1 - opt_cfg.b2) * jnp.square(g)
            delta = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + opt_cfg.eps)
            if d >= 0:
                # apply weight decay on the local param shard
                sz = p_leaf.shape[d] // data_size
                idx = jax.lax.axis_index(data_axes[0])
                if len(data_axes) == 2:
                    idx = idx * axis_sizes[data_axes[1]] + \
                        jax.lax.axis_index(data_axes[1])
                p_shard = jax.lax.dynamic_slice_in_dim(
                    p_leaf, idx * sz, sz, axis=d)
                if p_leaf.ndim >= 2:
                    delta = delta + opt_cfg.weight_decay * \
                        p_shard.astype(jnp.float32)
                upd = p_shard.astype(jnp.float32) - lr * delta
                upd = upd.astype(p_leaf.dtype)
                for ax in reversed(data_axes):
                    upd = jax.lax.all_gather(upd, ax, axis=d, tiled=True)
                new_p.append(upd)
            else:
                if p_leaf.ndim >= 2:
                    delta = delta + opt_cfg.weight_decay * \
                        p_leaf.astype(jnp.float32)
                new_p.append((p_leaf.astype(jnp.float32) - lr * delta
                              ).astype(p_leaf.dtype))
            new_m.append(m_new)
            new_v.append(v_new)

        params_out = jax.tree.unflatten(treedef, new_p)
        opt_out = AdamWState(step,
                             jax.tree.unflatten(treedef, new_m),
                             jax.tree.unflatten(treedef, new_v))
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params_out, opt_out, metrics

    # ---------------------------------------------------------- shard_map
    def spec_of(d):
        return P(*(([None] * d + [data_axes]) if d >= 0 else []))

    mspecs = jax.tree.map(spec_of, sdims,
                          is_leaf=lambda x: isinstance(x, int))
    opt_specs = AdamWState(step=P(), mu=mspecs,
                           nu=jax.tree.map(lambda x: x, mspecs))
    param_specs_manual = jax.tree.map(lambda _: P(), sdims,
                                      is_leaf=lambda x: isinstance(x, int))
    batch_specs = {k: P(*(data_axes if a == "batch" else None
                          for a in axes))
                   for k, axes in batch_axes.items()}

    smapped = jax.shard_map(
        shard_body, mesh=mesh,
        in_specs=(param_specs_manual, opt_specs, batch_specs),
        out_specs=(param_specs_manual, opt_specs, P()),
        axis_names=frozenset(data_axes),
        check_vma=False)

    # full shardings at the jit boundary: params TP over model; moments
    # TP over model PLUS ZeRO-scattered over data (256-way for matrices)
    def named(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    merged = merge_specs(param_specs_model, sdims, data_axes)
    opt_shardings = AdamWState(
        step=NamedSharding(mesh, P()),
        mu=named(merged), nu=named(jax.tree.map(lambda x: x, merged)))

    jitted = jax.jit(smapped,
                     in_shardings=(named(param_specs_model), opt_shardings,
                                   named(batch_specs)),
                     out_shardings=(named(param_specs_model), opt_shardings,
                                    None),
                     donate_argnums=(0, 1))
    return jitted, opt_specs, sdims


def abstract_zero_opt_state(model: Model, sdims, data_size: int):
    """Abstract ZeRO-scattered AdamW state matching ``opt_specs``."""
    def one(defn, d):
        shape = list(defn.shape)
        return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)
    from repro.models.param import is_def
    flat_defs = jax.tree.leaves(model.defs, is_leaf=is_def)
    flat_d = jax.tree.leaves(sdims)
    leaves = [one(df, d) for df, d in zip(flat_defs, flat_d)]
    treedef = jax.tree.structure(model.defs,
                                 is_leaf=is_def)
    mu = jax.tree.unflatten(treedef, leaves)
    nu = jax.tree.unflatten(treedef, list(leaves))
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=mu, nu=nu)
