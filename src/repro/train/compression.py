"""Gradient compression for the cross-pod (DCN) axis — beyond-paper
distributed-optimization trick recorded in EXPERIMENTS.md §Perf.

int8 error-feedback quantization: per-tensor scale = max|g| / 127, residual
(g - dequant(quant(g))) is carried to the next step so the compression is
unbiased over time (the EF-SGD scheme from the gradient-compression
literature, restricted to the slow pod axis where 4x fewer bytes directly
cuts the cross-DCN collective term).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array, residual: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (quantized int8, scale, new_residual)."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def make_ef_compressor(init_params):
    """Stateful-by-closure error-feedback compressor over a grad pytree.
    Returns (compress_fn, get_state, set_state); compress_fn quantizes +
    dequantizes each leaf (the wire between would be the int8 all-reduce on
    the pod axis — GSPMD emits the collective on the constrained output)."""
    state = {"residual": jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), init_params)}

    def compress(grads):
        new_res = {}
        outs = {}
        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(state["residual"])
        out_leaves, res_leaves = [], []
        for g, r in zip(flat_g, flat_r):
            q, s, nr = compress_int8(g, r)
            out_leaves.append(decompress_int8(q, s))
            res_leaves.append(nr)
        state["residual"] = jax.tree.unflatten(treedef, res_leaves)
        return jax.tree.unflatten(treedef, out_leaves)

    return compress, lambda: state["residual"], \
        lambda r: state.update(residual=r)
