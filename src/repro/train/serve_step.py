"""Serving steps: batched prefill and single-token decode with a donated
KV/state cache. ``decode_32k`` / ``long_500k`` dry-run cells lower
``decode_step`` (one new token against a seq_len-deep cache).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.models.sharding import NULL_CTX, ShardingCtx


def make_prefill_step(model: Model, ctx: ShardingCtx = NULL_CTX):
    def prefill_step(params, batch: Dict[str, jax.Array]):
        logits, cache, _ = model.forward(params, batch, mode="prefill", ctx=ctx)
        # greedy next token from the last position
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return prefill_step


def make_decode_step(model: Model, ctx: ShardingCtx = NULL_CTX):
    def decode_step(params, cache, token: jax.Array, index: jax.Array):
        """token: [B, 1] int32; index: [] int32 — position being decoded.
        Returns (next_token [B], logits [B, V], new_cache). ``cache`` should
        be donated by the caller's jit."""
        batch = {"tokens": token}
        logits, new_cache, _ = model.forward(
            params, batch, mode="decode", cache=cache, cache_index=index,
            ctx=ctx)
        next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return next_tok, logits[:, 0], new_cache
    return decode_step
