"""Fault-tolerant checkpointing for train state + data plane.

Design points for 1000+-node deployments (documented in DESIGN.md §8):
  * every host writes only its own shards (here: the single-host slice),
  * writes go to a temp dir + atomic rename, with a manifest carrying step,
    pytree structure and per-leaf checksums — a torn write can never be
    mistaken for a valid checkpoint,
  * ``save_async`` snapshots arrays on host (device_get) then writes on a
    background thread so the train loop continues,
  * the data plane (queue offsets, listener offsets, late buffers, cache
    watermarks) checkpoints WITH the model, so restart resumes the stream
    exactly where training left off — the DOD-ETL no-message-loss property
    extended to training.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

# strict step-dir name: a crash mid-save leaves `step_N.tmp-<pid>-<ns>`
# siblings behind, which ALSO start with "step_" — a lazy prefix match here
# used to crash `latest_step`/`_gc` on the very restart that needed them
_STEP_DIR = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:                      # platform without dir-fd fsync
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save(path: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None,
         pre_commit=None) -> str:
    """Atomic checkpoint write. Returns the final directory.

    ``pre_commit``, if given, runs after the tmp dir is fully written and
    fsynced but BEFORE the atomic rename — the seam where a crash leaves a
    complete-but-invisible checkpoint (the fault injector's
    ``checkpoint.mid_write`` point)."""
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(x) for x in leaves]
    tmp = f"{path}.tmp-{os.getpid()}-{time.time_ns()}"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": int(step), "n_leaves": len(host_leaves),
                "treedef": str(treedef), "leaves": [], "extra": extra or {}}
    with open(os.path.join(tmp, "leaves.npz"), "wb") as f:
        np.savez(f, **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
        f.flush()
        os.fsync(f.fileno())
    for i, a in enumerate(host_leaves):
        manifest["leaves"].append({
            "i": i, "shape": list(a.shape), "dtype": str(a.dtype),
            "sha256": hashlib.sha256(a.tobytes()).hexdigest()[:16],
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if pre_commit is not None:
        pre_commit()
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path) or ".")
    return path


def restore(path: str, tree_like: Any = None, only=None
            ) -> Tuple[int, Any, Dict[str, Any]]:
    """Validates checksums; raises on corruption. ``tree_like`` provides the
    pytree structure (and expected shapes/dtypes); when None the flat leaf
    LIST is returned as saved — the durability journal's mode, where the
    tree layout travels in ``extra`` instead of a live template.

    ``only`` (flat-list mode only): an index set — leaves outside it are
    returned as None without being read or validated. The journal uses
    this to skip the dead small-state leaves of non-final steps, whose
    per-member zip overhead would otherwise dominate recovery."""
    assert only is None or tree_like is None, \
        "partial restore is a flat-list-mode feature"
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    wanted = None if only is None else set(only)
    leaves = []
    for rec in manifest["leaves"]:
        if wanted is not None and rec["i"] not in wanted:
            leaves.append(None)
            continue
        a = data[f"leaf_{rec['i']}"]
        digest = hashlib.sha256(a.tobytes()).hexdigest()[:16]
        if digest != rec["sha256"]:
            raise IOError(f"checkpoint leaf {rec['i']} checksum mismatch")
        leaves.append(a)
    if len(leaves) != manifest["n_leaves"]:
        raise IOError(f"checkpoint has {len(leaves)} leaves, manifest "
                      f"says {manifest['n_leaves']}")
    if tree_like is None:
        return manifest["step"], leaves, manifest.get("extra", {})
    ref_leaves, treedef = _flatten(tree_like)
    if len(ref_leaves) != len(leaves):
        raise IOError(f"checkpoint has {len(leaves)} leaves, "
                      f"expected {len(ref_leaves)}")
    restored = jax.tree.unflatten(treedef, leaves)
    return manifest["step"], restored, manifest.get("extra", {})


def step_numbers(root: str) -> List[int]:
    """Sorted step numbers of every complete (renamed-into-place) step dir
    under ``root``; tmp leftovers and stray files are ignored."""
    if not os.path.isdir(root):
        return []
    steps = []
    for d in os.listdir(root):
        m = _STEP_DIR.match(d)
        if m and os.path.exists(os.path.join(root, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def sweep_tmp(root: str) -> int:
    """Remove crash leftovers: `*.tmp-*` dirs from saves that never reached
    their rename. Returns the number removed."""
    if not os.path.isdir(root):
        return 0
    n = 0
    for d in os.listdir(root):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(root, d), ignore_errors=True)
            n += 1
    return n


def latest_step(root: str) -> Optional[int]:
    steps = step_numbers(root)
    return steps[-1] if steps else None


class CheckpointManager:
    """Rolling async checkpoints: keep_last retention + background writes."""

    def __init__(self, root: str, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    def dir_for(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step}")

    def save_sync(self, step: int, tree: Any,
                  extra: Optional[Dict[str, Any]] = None) -> str:
        out = save(self.dir_for(step), step, tree, extra)
        self._gc()
        return out

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host = jax.tree.map(np.asarray, tree)   # snapshot before mutation

        def work():
            save(self.dir_for(step), step, host, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_like: Any = None
                       ) -> Optional[Tuple[int, Any, Dict[str, Any]]]:
        """Restore the newest valid checkpoint, falling back past torn or
        corrupt ones (truncated leaves, checksum mismatches) to the newest
        step that verifies. Returns None when nothing restorable exists."""
        self.wait()
        for step in reversed(step_numbers(self.root)):
            try:
                return restore(self.dir_for(step), tree_like)
            except Exception:        # torn/corrupt (truncated npz raises
                continue             # BadZipFile): try the previous step
        return None

    def _gc(self) -> None:
        for s in step_numbers(self.root)[:-self.keep_last]:
            shutil.rmtree(self.dir_for(s), ignore_errors=True)
