"""Fault-tolerant checkpointing for train state + data plane.

Design points for 1000+-node deployments (documented in DESIGN.md §8):
  * every host writes only its own shards (here: the single-host slice),
  * writes go to a temp dir + atomic rename, with a manifest carrying step,
    pytree structure and per-leaf checksums — a torn write can never be
    mistaken for a valid checkpoint,
  * ``save_async`` snapshots arrays on host (device_get) then writes on a
    background thread so the train loop continues,
  * the data plane (queue offsets, listener offsets, late buffers, cache
    watermarks) checkpoints WITH the model, so restart resumes the stream
    exactly where training left off — the DOD-ETL no-message-loss property
    extended to training.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomic checkpoint write. Returns the final directory."""
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(x) for x in leaves]
    tmp = f"{path}.tmp-{os.getpid()}-{time.time_ns()}"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": int(step), "n_leaves": len(host_leaves),
                "treedef": str(treedef), "leaves": [], "extra": extra or {}}
    with open(os.path.join(tmp, "leaves.npz"), "wb") as f:
        np.savez(f, **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
    for i, a in enumerate(host_leaves):
        manifest["leaves"].append({
            "i": i, "shape": list(a.shape), "dtype": str(a.dtype),
            "sha256": hashlib.sha256(a.tobytes()).hexdigest()[:16],
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


def restore(path: str, tree_like: Any) -> Tuple[int, Any, Dict[str, Any]]:
    """Validates checksums; raises on corruption. ``tree_like`` provides the
    pytree structure (and expected shapes/dtypes)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    leaves = []
    for rec in manifest["leaves"]:
        a = data[f"leaf_{rec['i']}"]
        digest = hashlib.sha256(a.tobytes()).hexdigest()[:16]
        if digest != rec["sha256"]:
            raise IOError(f"checkpoint leaf {rec['i']} checksum mismatch")
        leaves.append(a)
    ref_leaves, treedef = _flatten(tree_like)
    if len(ref_leaves) != len(leaves):
        raise IOError(f"checkpoint has {len(leaves)} leaves, "
                      f"expected {len(ref_leaves)}")
    restored = jax.tree.unflatten(treedef, leaves)
    return manifest["step"], restored, manifest.get("extra", {})


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_") and os.path.exists(
                os.path.join(root, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


class CheckpointManager:
    """Rolling async checkpoints: keep_last retention + background writes."""

    def __init__(self, root: str, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    def dir_for(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step}")

    def save_sync(self, step: int, tree: Any,
                  extra: Optional[Dict[str, Any]] = None) -> str:
        out = save(self.dir_for(step), step, tree, extra)
        self._gc()
        return out

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host = jax.tree.map(np.asarray, tree)   # snapshot before mutation

        def work():
            save(self.dir_for(step), step, host, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_like: Any
                       ) -> Optional[Tuple[int, Any, Dict[str, Any]]]:
        self.wait()
        step = latest_step(self.root)
        if step is None:
            return None
        return restore(self.dir_for(step), tree_like)

    def _gc(self) -> None:
        steps = sorted(s for s in (
            int(d.split("_")[1]) for d in os.listdir(self.root)
            if d.startswith("step_")))
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir_for(s), ignore_errors=True)
