"""Training step: microbatched gradient accumulation (scan), next-token
cross-entropy, ZeRO-sharded AdamW update, optional cross-pod gradient
compression hook.

The returned ``train_step(params, opt_state, batch)`` is jit-compatible and
is what the multi-pod dry-run lowers for ``train_4k`` cells.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.models.sharding import NULL_CTX, ShardingCtx
from repro.optim import AdamWConfig, AdamWState, apply_updates


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """logits: [B, S, V] f32; targets: [B, S] int32. Mean CE over tokens."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def _split_microbatches(batch: Dict[str, jax.Array], n_mb: int):
    def split(x):
        b = x.shape[0]
        assert b % n_mb == 0, (b, n_mb)
        return x.reshape((n_mb, b // n_mb) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_loss_fn(model: Model, ctx: ShardingCtx = NULL_CTX):
    def loss_fn(params, mb: Dict[str, jax.Array]):
        logits, _, aux = model.forward(params, mb, mode="train", ctx=ctx)
        loss = cross_entropy(logits, mb["targets"])
        return loss + aux, (loss, aux)
    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    ctx: ShardingCtx = NULL_CTX,
                    grad_specs=None,
                    compress_fn=None):
    """grad_specs: optional PartitionSpec tree to constrain accumulated
    grads (ZeRO-2: shard accumulation over the data axis).
    compress_fn: optional (grads -> grads) hook applied once per step before
    the optimizer — e.g. int8 error-feedback compression on the pod axis.
    """
    cfg = model.cfg
    loss_fn = make_loss_fn(model, ctx)
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain_grads(g):
        if grad_specs is None or ctx.mesh is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, jax.sharding.NamedSharding(ctx.mesh, s)), g, grad_specs)

    def train_step(params, opt_state: AdamWState, batch: Dict[str, jax.Array]
                   ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
        n_mb = max(cfg.microbatches, 1)
        mbs = _split_microbatches(batch, n_mb)

        # Explicit per-microbatch value_and_grad + accumulation. Under pure
        # GSPMD this pays a gradient all-reduce per microbatch (XLA cannot
        # defer the reduction across scan iterations) — the shard_map manual
        # DP step in repro.train.manual_dp removes exactly that cost; both
        # are measured in EXPERIMENTS.md §Perf.
        def mb_step(carry, mb):
            g_acc, loss_acc = carry
            (tot, (loss, aux)), g = vg(params, mb)
            g = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                             g_acc, constrain_grads(g))
            return (g, loss_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        g0 = constrain_grads(g0)
        (grads, loss_sum), _ = jax.lax.scan(mb_step, (g0, 0.0), mbs)
        grads = jax.tree.map(lambda g: g / n_mb, grads)
        if compress_fn is not None:
            grads = compress_fn(grads)
        new_params, new_opt, metrics = apply_updates(opt_cfg, params, grads,
                                                     opt_state)
        metrics["loss"] = loss_sum / n_mb
        return new_params, new_opt, metrics

    return train_step
