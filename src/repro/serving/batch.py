"""Batched query plane: thousands of heterogeneous report queries per
backend dispatch (the read-side analogue of the write path's 3→1 dispatch
coalescing).

The serving plane tops out when every report is a separate
Python-dispatched read. This module splits querying into the classic
plan/execute shape:

  * ``ReportQuery``    — one query as data (kind + view + args).
  * ``compile_queries``— encode a batch into a ``QueryPlan`` of PACKED
                         descriptors (int32 kind/view/arg columns) and
                         vectorized group indices. Compiling is the only
                         per-query Python work and is paid ONCE — a
                         dashboard re-issuing the same query set every
                         refresh reuses its plan across epochs.
  * ``QueryPlan.execute`` — answer the whole batch against one pinned
                         ``ReportSnapshot``: all per-unit point queries
                         against a view become ONE ``batch_gather_stats``
                         dispatch, every distinct shared report (view
                         read, top-k, windowed rate, curve, shift,
                         rollup) is computed once via the snapshot's
                         per-epoch memo, and the result is a columnar
                         ``BatchResult``. No per-query Python on the
                         execute path.
  * ``BatchResult.reports`` — materialize per-query ``Report`` objects in
                         submission order (the byte-parity surface with
                         the per-query loop); columnar consumers read the
                         packed arrays directly and skip it.
  * ``BatchedReportServer`` — the admission front (idiom:
                         examples/serve_lm.py request batching): callers
                         ``submit()`` single queries from any thread, the
                         dispatcher coalesces them (``max_batch`` /
                         ``max_wait_ms``) and answers each coalesced
                         group per PINNED snapshot — a query's epoch is
                         fixed at admission, so a batch spanning an epoch
                         swap stamps each query with its own epoch.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.engine import serving_clock
from repro.serving.server import Report, ReportSnapshot, ReportServer

# kind codes of the packed descriptor encoding (stable wire format)
KIND_CODES: Dict[str, int] = {
    "view": 0,              # generic per-segment table read
    "oee": 1,               # per-unit OEE means (arg = unit; -1 = fleet)
    "top_downtime": 2,      # top-k downtime ranking (arg = k)
    "production_rate": 3,   # per-window production report
    "shift_report": 4,      # per (unit, shift) means
    "kpi_rollup": 5,        # [n_units, 5] warehouse-shaped rollup
    "production_curve": 6,  # cumulative windowed fold (prefix_fold)
}
_CODE_KINDS = {v: k for k, v in KIND_CODES.items()}
_OEE = KIND_CODES["oee"]

# default view per kind (kind "view"/"production_curve" take an explicit
# view name; the rest address their canonical steelworks view)
_DEFAULT_VIEW = {
    "oee": "oee_by_equipment",
    "top_downtime": "downtime_by_equipment",
    "production_rate": "production_rate_windows",
    "production_curve": "production_rate_windows",
    "shift_report": "kpi_by_unit_shift",
    "kpi_rollup": "oee_by_equipment",
}


@dataclasses.dataclass(frozen=True)
class ReportQuery:
    """One report query as data. ``kind`` is a ``KIND_CODES`` key;
    ``view`` is required for kind "view" (optional override for
    "production_curve"); ``unit`` selects a single unit for kind "oee"
    (None = fleet-wide); ``k`` is the top-downtime depth."""

    kind: str
    view: Optional[str] = None
    unit: Optional[int] = None
    k: int = 5


class QueryPlan:
    """A compiled query batch: packed int32 descriptor columns + the
    vectorized group indices ``execute`` dispatches from. Immutable;
    reusable across any number of epochs/snapshots."""

    def __init__(self, codes: np.ndarray, view_ids: np.ndarray,
                 args: np.ndarray, views: Tuple[str, ...]):
        codes = np.ascontiguousarray(codes, np.int32)
        view_ids = np.ascontiguousarray(view_ids, np.int32)
        args = np.ascontiguousarray(args, np.int32)
        if not (len(codes) == len(view_ids) == len(args)):
            raise ValueError("descriptor columns must share one length")
        bad = ~np.isin(codes, list(_CODE_KINDS))
        if bad.any():
            raise ValueError(f"unknown kind codes {np.unique(codes[bad])}")
        if len(codes) and (view_ids.min() < 0
                           or view_ids.max() >= max(len(views), 1)):
            raise ValueError("view_id out of range")
        for arr in (codes, view_ids, args):
            arr.flags.writeable = False
        self.codes = codes
        self.view_ids = view_ids
        self.args = args
        self.views = tuple(views)

        # ---- vectorized grouping (once per plan, reused every execute)
        point = (codes == _OEE) & (args >= 0)
        # point groups: one gather dispatch per distinct view
        self.point_groups: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._point_row = np.full(len(codes), -1, np.int64)
        for vid in np.unique(view_ids[point]):
            pos = np.flatnonzero(point & (view_ids == vid))
            self.point_groups[int(vid)] = (pos, args[pos].astype(np.int64))
            self._point_row[pos] = np.arange(len(pos))
        # shared groups: one computation per distinct (code, view, arg)
        srows = np.stack([np.where(point, -1, codes), view_ids,
                          np.where(point, 0, args)], axis=1)
        skeys, sinv = np.unique(srows, axis=0, return_inverse=True)
        self.shared_keys: List[Tuple[int, int, int]] = [
            tuple(int(x) for x in row) for row in skeys if row[0] >= 0]
        self._shared_idx = np.where(point, -1, sinv)
        self._shared_map = {tuple(int(x) for x in row): i
                            for i, row in enumerate(skeys)}

    def __len__(self) -> int:
        return len(self.codes)

    def descriptors(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The packed wire format: (codes, view_ids, args) int32 columns."""
        return self.codes, self.view_ids, self.args

    # ------------------------------------------------------------- execute
    def execute(self, rsnap: ReportSnapshot) -> "BatchResult":
        """Answer every query against ONE pinned snapshot: one
        ``batch_gather_stats`` dispatch per point-query view, one shared
        computation per distinct report (epoch-memoized, so a second
        batch on the same epoch recomputes nothing). Columnar out."""
        snap = rsnap.snap
        # sharded serving plane: when the snapshot carries shard-local
        # tables (ShardedEpochSnapshot), each query descriptor routes to
        # its segment's OWNING shard — one gather dispatch per shard with
        # resident queries, against that shard's local table. Owned rows
        # are bitwise-identical to the merged table's, so the scattered
        # answers are bitwise the unsharded dispatch (duck-typed: no
        # runtime import, plain snapshots take the single-dispatch path).
        shard_states = getattr(snap, "shard_states", None)
        seg_owners = getattr(snap, "seg_owners", None)
        point_stats: Dict[int, np.ndarray] = {}
        for vid, (pos, units) in self.point_groups.items():
            name = self.views[vid]
            st = snap.view(name)
            if len(units) and (units.min() < 0
                               or units.max() >= st.spec.n_segments):
                raise ValueError(
                    f"unit ids out of range for view {name!r}")
            if shard_states and name in shard_states and len(units):
                tabs = shard_states[name]
                owner_u = np.asarray(seg_owners[name],
                                     np.int64)[units]
                out = np.empty((len(units), 1 + 4 * st.spec.n_lanes),
                               np.float32)
                for k in np.unique(owner_u):
                    mask = owner_u == k
                    out[mask] = rsnap.backend.batch_gather_stats(
                        tabs[int(k)], units[mask])
                point_stats[vid] = out
            else:
                point_stats[vid] = rsnap.backend.batch_gather_stats(
                    st.table, units)
        shared: List[object] = [None] * (max(self._shared_map.values()) + 1
                                         if self._shared_map else 0)
        for code, vid, arg in self.shared_keys:
            shared[self._shared_map[(code, vid, arg)]] = \
                self._run_shared(rsnap, code, vid, arg)
        return BatchResult(plan=self, snap=snap,
                           staleness_ms=snap.staleness_ms(),
                           served_at=serving_clock(),
                           point_stats=point_stats, shared=shared)

    def _run_shared(self, rsnap: ReportSnapshot, code: int, vid: int,
                    arg: int):
        kind = _CODE_KINDS[code]
        view = self.views[vid]
        if kind == "view":
            return rsnap.query(view)
        if kind == "oee":
            return rsnap.oee(None)
        if kind == "top_downtime":
            return rsnap.top_downtime(arg)
        if kind == "production_rate":
            return rsnap.production_rate()
        if kind == "shift_report":
            return rsnap.shift_report()
        if kind == "production_curve":
            return rsnap.production_curve(view)
        # kpi_rollup: ndarray payload, wrapped for a uniform Report surface
        return Report(view=view, epoch=rsnap.epoch,
                      staleness_ms=rsnap.snap.staleness_ms(),
                      rows=rsnap.snap.rows_folded,
                      data={"kpi_rollup": rsnap.kpi_rollup()})


class BatchResult:
    """Columnar batch answer bound to one epoch.

    ``point_stats`` holds, per point-query view, the packed
    [B_g, 1 + 4L] gather output ([count | sums | mins | maxs | means])
    aligned with the plan's group positions; ``shared`` holds each
    distinct shared ``Report`` exactly once. ``reports()`` fans these out
    into per-query ``Report`` objects in submission order."""

    def __init__(self, plan: QueryPlan, snap, staleness_ms: float,
                 served_at: float, point_stats: Dict[int, np.ndarray],
                 shared: List[object]):
        self.plan = plan
        self.snap = snap
        self.epoch = snap.epoch
        self.rows = snap.rows_folded
        self.staleness_ms = staleness_ms
        self.served_at = served_at
        self.point_stats = point_stats
        self.shared = shared

    def __len__(self) -> int:
        return len(self.plan)

    def point_positions(self, view: str) -> np.ndarray:
        vid = self.plan.views.index(view)
        return self.plan.point_groups[vid][0]

    def reports(self) -> List[Report]:
        """Per-query ``Report``s in submission order. Shared kinds reuse
        ONE Report object across every query that asked for it; point
        queries materialize a small dict each (only this path pays
        per-query Python — columnar consumers read the arrays)."""
        plan = self.plan
        out: List[Optional[Report]] = [None] * len(plan)
        sidx = plan._shared_idx
        for i in np.flatnonzero(sidx >= 0):
            out[i] = self.shared[sidx[i]]
        for vid, (pos, _units) in plan.point_groups.items():
            view = plan.views[vid]
            st = self.snap.view(view)
            lanes = st.spec.lanes
            L = len(lanes)
            stats = self.point_stats[vid]
            means = stats[:, 1 + 3 * L:]
            cnts = stats[:, 0]
            for row, i in enumerate(pos):
                data = dict(zip(lanes, (float(m) for m in means[row])))
                data["rows"] = float(cnts[row])
                out[i] = Report(view=view, epoch=self.epoch,
                                staleness_ms=self.staleness_ms,
                                rows=self.rows, data=data)
        return out  # type: ignore[return-value]


def compile_queries(queries: Sequence[ReportQuery]) -> QueryPlan:
    """Encode a query batch into packed descriptors + a ``QueryPlan``.
    The one place per-query Python runs; everything downstream is
    vectorized."""
    qs = list(queries)
    n = len(qs)
    codes = np.empty(n, np.int32)
    view_ids = np.empty(n, np.int32)
    args = np.zeros(n, np.int32)
    view_idx: Dict[str, int] = {}
    for i, q in enumerate(qs):
        code = KIND_CODES.get(q.kind)
        if code is None:
            raise ValueError(f"unknown query kind {q.kind!r}")
        view = q.view or _DEFAULT_VIEW.get(q.kind)
        if view is None:
            raise ValueError(f"kind {q.kind!r} requires an explicit view")
        codes[i] = code
        view_ids[i] = view_idx.setdefault(view, len(view_idx))
        if q.kind == "oee":
            if q.unit is not None and q.unit < 0:
                raise ValueError(f"negative unit {q.unit}")
            args[i] = -1 if q.unit is None else int(q.unit)
        elif q.kind == "top_downtime":
            if q.k < 1:
                raise ValueError(f"top_downtime needs k >= 1, got {q.k}")
            args[i] = int(q.k)
    return QueryPlan(codes, view_ids, args,
                     tuple(sorted(view_idx, key=view_idx.get)))


class BatchTicket:
    """One submitted query's future. ``result()`` blocks until the
    dispatcher answers; the query's epoch was pinned at submission."""

    __slots__ = ("query", "snapshot", "admitted_at", "_event", "_report",
                 "_error")

    def __init__(self, query: ReportQuery, snapshot):
        self.query = query
        self.snapshot = snapshot          # EpochSnapshot pinned at admission
        self.admitted_at = serving_clock()
        self._event = threading.Event()
        self._report: Optional[Report] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Report:
        if not self._event.wait(timeout):
            raise TimeoutError("batched query not answered in time")
        if self._error is not None:
            raise self._error
        return self._report

    def _fulfill(self, report: Report) -> None:
        self._report = report
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self._event.set()


class BatchedReportServer:
    """Admission/batching front over a ``ReportServer`` (idiom:
    examples/serve_lm.py): any thread ``submit()``s single queries; a
    dispatcher thread coalesces them into batches of up to ``max_batch``
    (waiting at most ``max_wait_ms`` after the first admission), then
    answers each batch per pinned snapshot via the compiled plan. A
    query's epoch is fixed the moment it is admitted — batches that span
    an epoch swap stamp each query with its own epoch and staleness."""

    def __init__(self, server, max_batch: int = 4096,
                 max_wait_ms: float = 2.0):
        if not isinstance(server, ReportServer):
            server = ReportServer(server)     # accept a bare engine
        self.server = server
        self.engine = server.engine
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) * 1e-3
        self._queue: List[BatchTicket] = []
        self._cv = threading.Condition()
        self._stopping = False
        self._thread: Optional[threading.Thread] = None
        self._stats_lock = threading.Lock()
        self._batches = 0
        self._queries = 0
        self._max_batch_seen = 0
        self._multi_epoch_batches = 0

    # ---------------------------------------------------------- lifecycle
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stopping = False
        self._thread = threading.Thread(target=self._dispatch, daemon=True,
                                        name="serving.batch")
        self._thread.start()

    def stop(self) -> None:
        """Stop the dispatcher after draining every admitted query."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(5.0)
            self._thread = None
        self._drain()                      # leftovers answered inline

    # ---------------------------------------------------------- admission
    def submit(self, query: ReportQuery) -> BatchTicket:
        """Admit one query: pins the CURRENT epoch and returns a ticket.
        Cheap — a snapshot reference grab and a list append."""
        ticket = BatchTicket(query, self.engine.snapshot())
        with self._cv:
            if self._thread is None and not self._stopping:
                # no dispatcher running: answer synchronously (degraded
                # but correct — used by tests and teardown races)
                pass
            self._queue.append(ticket)
            self._cv.notify()
        if self._thread is None:
            self._drain()
        return ticket

    def stats(self) -> Dict[str, float]:
        with self._stats_lock:
            b, q = self._batches, self._queries
            return {"batches": b, "queries": q,
                    "mean_batch": (q / b) if b else 0.0,
                    "max_batch": self._max_batch_seen,
                    "multi_epoch_batches": self._multi_epoch_batches}

    # --------------------------------------------------------- dispatcher
    def _dispatch(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stopping:
                    self._cv.wait()
                if not self._queue and self._stopping:
                    return
                # coalesce: wait (bounded) for the batch to fill
                deadline = serving_clock() + self.max_wait_s
                while (len(self._queue) < self.max_batch
                       and not self._stopping):
                    left = deadline - serving_clock()
                    if left <= 0 or not self._cv.wait(left):
                        break
                batch = self._queue[:self.max_batch]
                del self._queue[:self.max_batch]
            self._answer(batch)

    def _drain(self) -> None:
        while True:
            with self._cv:
                batch = self._queue[:self.max_batch]
                del self._queue[:self.max_batch]
            if not batch:
                return
            self._answer(batch)

    def _answer(self, batch: List[BatchTicket]) -> None:
        # group by pinned epoch: one plan-execute per snapshot generation
        groups: Dict[int, List[BatchTicket]] = {}
        for t in batch:
            groups.setdefault(t.snapshot.epoch, []).append(t)
        for tickets in groups.values():
            snap = tickets[0].snapshot
            try:
                with self.engine.tracer.span("query.batch") as sp:
                    plan = compile_queries([t.query for t in tickets])
                    rsnap = ReportSnapshot(snap, self.engine.backend)
                    for t, rep in zip(tickets,
                                      plan.execute(rsnap).reports()):
                        t._fulfill(rep)
                    sp.put("queries", len(tickets))
                    sp.put("epoch", snap.epoch)
            except BaseException as exc:   # answer, never wedge a caller
                for t in tickets:
                    if not t.done():
                        t._fail(exc)
        with self._stats_lock:
            self._batches += 1
            self._queries += len(batch)
            self._max_batch_seen = max(self._max_batch_seen, len(batch))
            if len(groups) > 1:
                self._multi_epoch_batches += 1


__all__ = ["KIND_CODES", "ReportQuery", "QueryPlan", "BatchResult",
           "compile_queries", "BatchTicket", "BatchedReportServer"]
