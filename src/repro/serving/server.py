"""Near-real-time report API over the materialized-view engine.

``ReportServer`` is what a BI dashboard talks to: every query is served
from a pinned ``EpochSnapshot`` — O(n_segments) reads of precomputed
aggregate tables, no fact-table scan, no locking against the loading
cluster — and every response carries its epoch and a staleness stamp
(how old the answer's data is, on the CDC event-time clock).

Use ``server.snapshot()`` to pin ONE epoch across several queries (a
multi-query report is then internally consistent: every number comes from
the same point of the delta stream); the convenience methods pin a fresh
epoch per call.

Read-path economics: report payloads are READ-ONLY VIEWS of the epoch's
immutable tables (never per-query copies), and derivations every reader
of an epoch shares — per-view means, the downtime ranking, cumulative
window folds — are computed once per epoch via ``EpochSnapshot.shared``.
A thousand concurrent queries against one epoch allocate next to nothing.
For thousands of queries at once, see ``repro.serving.batch``: the packed
query plan answers a whole heterogeneous batch in one backend dispatch
per view.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.backend import get_backend
from repro.serving.engine import (EpochSnapshot, MaterializedViewEngine,
                                  serving_clock)


@dataclasses.dataclass(frozen=True)
class Report:
    """One query response: data + provenance (epoch, staleness)."""

    view: str
    epoch: int
    staleness_ms: float                      # age of the data served
    rows: int                                # fact rows folded into the
    data: dict                               # epoch (incl. invalid-flagged
                                             # rows dropped from view state)


def _frozen(arr: np.ndarray) -> np.ndarray:
    arr.flags.writeable = False
    return arr


def downtime_rank_keys(down: np.ndarray) -> np.ndarray:
    """uint64 ranking keys for the top-downtime report: ascending key
    order == (downtime DESC, unit ASC) — exactly the order
    ``np.lexsort((arange, -down))`` produces (the pre-batching oracle,
    still asserted in tests).

    The high 32 bits are the downtime as a descending total-order key
    (IEEE-754 bit trick: flip the sign bit of non-negatives, complement
    negatives — float order becomes unsigned integer order — then invert
    for descending); the low 32 bits are the unit id, so every key is
    UNIQUE and any selection algorithm — ``argpartition`` included —
    breaks ties identically. -0.0 is normalized to +0.0 first (the float
    sort treats them equal; their bit patterns are not)."""
    d = down.astype(np.float32) + np.float32(0.0)        # -0.0 -> +0.0
    b = np.ascontiguousarray(d).view(np.uint32).astype(np.uint64)
    asc = np.where(d >= 0, b ^ np.uint64(0x80000000),
                   ~b & np.uint64(0xFFFFFFFF))
    desc = np.uint64(0xFFFFFFFF) - asc
    return (desc << np.uint64(32)) | np.arange(len(d), dtype=np.uint64)


class ReportSnapshot:
    """Query helpers bound to ONE pinned epoch (snapshot isolation: the
    answers cannot change, tear, or block while you hold this).

    All report payload arrays are read-only views of the epoch's
    immutable state (or of per-epoch memoized derivations) — copy before
    mutating."""

    def __init__(self, snap: EpochSnapshot, backend=None):
        self.snap = snap
        self.backend = get_backend(backend)

    @property
    def epoch(self) -> int:
        return self.snap.epoch

    def _report(self, view: str, data: dict) -> Report:
        return Report(view=view, epoch=self.snap.epoch,
                      staleness_ms=self.snap.staleness_ms(),
                      rows=self.snap.rows_folded, data=data)

    # --------------------------------------------- shared epoch derivations
    def _means(self, view: str) -> np.ndarray:
        st = self.snap.view(view)
        return self.snap.shared(("means", view),
                                lambda: _frozen(st.means()))

    def _downtime_rank(self) -> Tuple[np.ndarray, np.ndarray]:
        """(downtime lane, uint64 ranking keys) — once per epoch."""
        st = self.snap.view("downtime_by_equipment")

        def compute():
            down = st.sums[:, 0]
            return down, _frozen(downtime_rank_keys(down))

        return self.snap.shared(("downtime_rank",), compute)

    def _curve(self, view: str) -> np.ndarray:
        """Cumulative windowed fold [S, 1+3L] (row w aggregates windows
        [0, w]) — ONE prefix_fold dispatch per epoch, shared by every
        reader and every batched curve query."""
        st = self.snap.view(view)
        if not st.spec.windowed:
            raise ValueError(f"view {view!r} is not windowed")
        return self.snap.shared(
            ("curve", view),
            lambda: _frozen(self.backend.prefix_fold(st.table)))

    # ------------------------------------------------------- standard reports
    def query(self, view: str) -> Report:
        """Generic per-segment report: count / sum / mean / min / max for
        every lane of ``view``."""
        st = self.snap.view(view)
        data = {"count": st.count, "lanes": st.spec.lanes,
                "sum": st.sums, "mean": self._means(view),
                "min": st.mins, "max": st.maxs}
        return self._report(view, data)

    def kpi_rollup(self) -> np.ndarray:
        """[n_units, 5] KPI sums + count — the exact shape and semantics of
        ``Warehouse.kpi_rollup``, served from the view state in O(n_units)."""
        st = self.snap.view("oee_by_equipment")
        return self.snap.shared(
            ("kpi_rollup",),
            lambda: _frozen(np.concatenate(
                [st.sums, st.count[:, None]], axis=1).astype(np.float32)))

    def oee(self, equipment_id: Optional[int] = None) -> Report:
        """``Warehouse.query_oee`` served incrementally: mean KPIs for one
        unit, or across all units when ``equipment_id`` is None."""
        st = self.snap.view("oee_by_equipment")
        if equipment_id is not None:
            cnt = float(st.count[equipment_id])
            means = (st.sums[equipment_id] / cnt if cnt
                     else np.full(st.spec.n_lanes, np.nan))
        else:
            def all_units():
                c = float(st.count.sum())
                m = (st.sums.sum(axis=0) / c if c
                     else np.full(st.spec.n_lanes, np.nan))
                return c, m
            cnt, means = self.snap.shared(("oee_all",), all_units)
        data = dict(zip(st.spec.lanes, (float(m) for m in means)))
        data["rows"] = cnt
        return self._report("oee_by_equipment", data)

    def top_downtime(self, k: int = 5) -> Report:
        """Top-k downtime causes: units ranked by summed off-segment
        seconds (ties broken by unit id for determinism). Selection is
        ``argpartition`` top-k over the epoch's memoized unique ranking
        keys — O(n + k log k) per query, same order as the old full
        ``lexsort``."""
        down, keys = self._downtime_rank()
        st = self.snap.view("downtime_by_equipment")
        n = len(keys)
        kk = min(k, n)
        if kk < n:
            part = np.argpartition(keys, kk)[:kk]
            order = part[np.argsort(keys[part])]
        else:
            order = np.argsort(keys)
        data = {"unit": order.astype(np.int64),
                "downtime_s": down[order].astype(np.float64),
                "uptime_s": st.sums[order, 1].astype(np.float64)}
        return self._report("downtime_by_equipment", data)

    def production_rate(self) -> Report:
        """Per-window production report: facts/window, summed runtime and
        the window's min/max OEE."""
        st = self.snap.view("production_rate_windows")
        data = {"facts": st.count,
                "runtime_s": st.sums[:, 0],
                "oee_min": st.mins[:, 1],
                "oee_max": st.maxs[:, 1]}
        return self._report("production_rate_windows", data)

    def production_curve(self, view: str = "production_rate_windows"
                         ) -> Report:
        """Cumulative windowed report: row w aggregates windows [0, w] —
        running fact count, runtime, min/max per lane. All S prefixes come
        from ONE associative-scan dispatch per epoch (see
        ``ComputeBackend.prefix_fold``), not S per-window refolds."""
        st = self.snap.view(view)
        cum = self._curve(view)
        L = st.spec.n_lanes
        data = {"count": cum[:, 0], "lanes": st.spec.lanes,
                "sum": cum[:, 1:1 + L],
                "min": cum[:, 1 + L:1 + 2 * L],
                "max": cum[:, 1 + 2 * L:]}
        return self._report(view, data)

    def shift_report(self) -> Report:
        """Per (unit, shift) mean KPIs — the paper's shift report."""
        st = self.snap.view("kpi_by_unit_shift")
        return self._report("kpi_by_unit_shift",
                            {"count": st.count,
                             "mean": self._means("kpi_by_unit_shift"),
                             "lanes": st.spec.lanes})


class ReportServer:
    """The BI front door: pins an epoch per query (or hands out pinned
    ``ReportSnapshot``s for multi-query consistency)."""

    def __init__(self, engine: MaterializedViewEngine):
        self.engine = engine

    def snapshot(self) -> ReportSnapshot:
        return ReportSnapshot(self.engine.snapshot(), self.engine.backend)

    # per-call conveniences (each pins a fresh epoch)
    def query(self, view: str) -> Report:
        return self.snapshot().query(view)

    def kpi_rollup(self) -> np.ndarray:
        return self.snapshot().kpi_rollup()

    def oee(self, equipment_id: Optional[int] = None) -> Report:
        return self.snapshot().oee(equipment_id)

    def top_downtime(self, k: int = 5) -> Report:
        return self.snapshot().top_downtime(k)

    def production_rate(self) -> Report:
        return self.snapshot().production_rate()

    def production_curve(self) -> Report:
        return self.snapshot().production_curve()

    def serve_batch(self, queries) -> "List[Report]":
        """Answer a heterogeneous query batch against ONE pinned epoch in
        one vectorized dispatch per view (see ``repro.serving.batch``)."""
        from repro.serving.batch import compile_queries
        return compile_queries(queries).execute(self.snapshot()).reports()


__all__ = ["Report", "ReportSnapshot", "ReportServer", "downtime_rank_keys"]
