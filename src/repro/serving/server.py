"""Near-real-time report API over the materialized-view engine.

``ReportServer`` is what a BI dashboard talks to: every query is served
from a pinned ``EpochSnapshot`` — O(n_segments) reads of precomputed
aggregate tables, no fact-table scan, no locking against the loading
cluster — and every response carries its epoch and a staleness stamp
(how old the answer's data is, on the CDC event-time clock).

Use ``server.snapshot()`` to pin ONE epoch across several queries (a
multi-query report is then internally consistent: every number comes from
the same point of the delta stream); the convenience methods pin a fresh
epoch per call.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import (EpochSnapshot, MaterializedViewEngine,
                                  serving_clock)


@dataclasses.dataclass(frozen=True)
class Report:
    """One query response: data + provenance (epoch, staleness)."""

    view: str
    epoch: int
    staleness_ms: float                      # age of the data served
    rows: int                                # fact rows folded into the
    data: dict                               # epoch (incl. invalid-flagged
                                             # rows dropped from view state)


class ReportSnapshot:
    """Query helpers bound to ONE pinned epoch (snapshot isolation: the
    answers cannot change, tear, or block while you hold this)."""

    def __init__(self, snap: EpochSnapshot):
        self.snap = snap

    @property
    def epoch(self) -> int:
        return self.snap.epoch

    def _report(self, view: str, data: dict) -> Report:
        return Report(view=view, epoch=self.snap.epoch,
                      staleness_ms=self.snap.staleness_ms(),
                      rows=self.snap.rows_folded, data=data)

    # ------------------------------------------------------- standard reports
    def query(self, view: str) -> Report:
        """Generic per-segment report: count / sum / mean / min / max for
        every lane of ``view``."""
        st = self.snap.view(view)
        means = st.means()
        data = {"count": st.count.copy(), "lanes": st.spec.lanes,
                "sum": st.sums.copy(), "mean": means,
                "min": st.mins.copy(), "max": st.maxs.copy()}
        return self._report(view, data)

    def kpi_rollup(self) -> np.ndarray:
        """[n_units, 5] KPI sums + count — the exact shape and semantics of
        ``Warehouse.kpi_rollup``, served from the view state in O(n_units)."""
        st = self.snap.view("oee_by_equipment")
        return np.concatenate([st.sums, st.count[:, None]],
                              axis=1).astype(np.float32)

    def oee(self, equipment_id: Optional[int] = None) -> Report:
        """``Warehouse.query_oee`` served incrementally: mean KPIs for one
        unit, or across all units when ``equipment_id`` is None."""
        st = self.snap.view("oee_by_equipment")
        if equipment_id is not None:
            cnt = float(st.count[equipment_id])
            means = (st.sums[equipment_id] / cnt if cnt
                     else np.full(st.spec.n_lanes, np.nan))
        else:
            cnt = float(st.count.sum())
            means = (st.sums.sum(axis=0) / cnt if cnt
                     else np.full(st.spec.n_lanes, np.nan))
        data = dict(zip(st.spec.lanes, (float(m) for m in means)))
        data["rows"] = cnt
        return self._report("oee_by_equipment", data)

    def top_downtime(self, k: int = 5) -> Report:
        """Top-k downtime causes: units ranked by summed off-segment
        seconds (ties broken by unit id for determinism)."""
        st = self.snap.view("downtime_by_equipment")
        down = st.sums[:, 0]
        order = np.lexsort((np.arange(len(down)), -down))[:k]
        data = {"unit": order.astype(np.int64),
                "downtime_s": down[order].astype(np.float64),
                "uptime_s": st.sums[order, 1].astype(np.float64)}
        return self._report("downtime_by_equipment", data)

    def production_rate(self) -> Report:
        """Per-window production report: facts/window, summed runtime and
        the window's min/max OEE."""
        st = self.snap.view("production_rate_windows")
        data = {"facts": st.count.copy(),
                "runtime_s": st.sums[:, 0].copy(),
                "oee_min": st.mins[:, 1].copy(),
                "oee_max": st.maxs[:, 1].copy()}
        return self._report("production_rate_windows", data)

    def shift_report(self) -> Report:
        """Per (unit, shift) mean KPIs — the paper's shift report."""
        st = self.snap.view("kpi_by_unit_shift")
        return self._report("kpi_by_unit_shift",
                            {"count": st.count.copy(), "mean": st.means(),
                             "lanes": st.spec.lanes})


class ReportServer:
    """The BI front door: pins an epoch per query (or hands out pinned
    ``ReportSnapshot``s for multi-query consistency)."""

    def __init__(self, engine: MaterializedViewEngine):
        self.engine = engine

    def snapshot(self) -> ReportSnapshot:
        return ReportSnapshot(self.engine.snapshot())

    # per-call conveniences (each pins a fresh epoch)
    def query(self, view: str) -> Report:
        return self.snapshot().query(view)

    def kpi_rollup(self) -> np.ndarray:
        return self.snapshot().kpi_rollup()

    def oee(self, equipment_id: Optional[int] = None) -> Report:
        return self.snapshot().oee(equipment_id)

    def top_downtime(self, k: int = 5) -> Report:
        return self.snapshot().top_downtime(k)

    def production_rate(self) -> Report:
        return self.snapshot().production_rate()


__all__ = ["Report", "ReportSnapshot", "ReportServer"]
