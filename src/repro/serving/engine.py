"""Incremental materialized-view engine — the BI serving layer's core.

Write side: every warehouse load publishes its fact block as a
``FactDelta`` (``StarSchemaWarehouse.attach_serving`` wires the hook). The
maintenance stage drains pending deltas in publication order and folds
each one into every registered view's aggregate state through the compute
backend's ``fold_segments`` op — one fused count/sum/min/max dispatch per
(delta, view), O(delta) work, never O(history).

Read side: **snapshot isolation via epoch publication.** View states are
immutable once published: a fold cycle builds NEW state tables
(``combine_fold`` allocates, the old tables are never written), assembles
them into an ``EpochSnapshot``, and swaps one reference. Readers pin an
epoch by grabbing that reference — thousands of concurrent report queries
never block the fold and can never observe a torn or half-folded state,
no matter how long they hold the snapshot. (The classic double-buffer
mutate-the-back-buffer scheme would tear for readers that out-live two
swaps; since view state is tiny — [n_segments, 1+3L] per view — building
fresh tables per fold costs microseconds and makes every epoch a durable
snapshot.)

Staleness: each delta carries the CDC append event-time stamps of its
records (the same clock the cluster's load-freshness metric uses). When
the fold cycle that makes a record visible swaps its epoch, the engine
records ``swap_time - event_time`` per record — end-to-end *report
staleness*: CDC append -> extract -> transform -> load -> fold -> visible
to queries. Every epoch also carries a watermark event time, so a query
response can stamp how old its data is right now.

Determinism: folds replay bit-for-bit. Segment/value extraction is host
numpy, the per-delta fold is the backend's deterministic halving tree
(numpy and jax produce bitwise-identical tables), and deltas are folded
strictly in publication order with block boundaries fixed by delta
length. Folds are segment-COMPACTED — the tree runs over only the
delta's live segments and scatters into the packed table — which leaves
every per-segment op order unchanged (see ``backend._fold_blocks``), so
compaction is invisible to the determinism contract. ``rebuild`` therefore reproduces the incremental state
byte-identically from the warehouse's committed chunk log — the
recompute-from-scratch oracle the equivalence tests assert against.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.backend import (combine_fold, empty_fold_state, fold_width,
                                get_backend)
from repro.core.metrics import LatencyRecorder
from repro.observability.tracer import NULL_TRACER
from repro.serving.views import ViewSpec


def serving_clock() -> float:
    """The serving layer's clock — the SAME monotonic clock CDC event
    times are stamped on (``ChangeLog.clock``), so staleness and load
    freshness are directly comparable."""
    return time.perf_counter()


_MISS = object()   # memo sentinel (cached values may legitimately be falsy)


@dataclasses.dataclass(frozen=True)
class FactDelta:
    """One published fact block: the unit of incremental maintenance.

    ``routing_epoch`` stamps which key→partition routing epoch the block
    was processed under — observability only. View *segment* ids derive
    from fact columns alone (equipment unit, shift, time window), never
    from partition ids, and the loader's chunk layout uses the stable
    static hash: both are partition-stable by construction, which is what
    lets materialized views fold identically across repartitions."""

    facts: np.ndarray                        # [n, N_FACT] f32
    event_times: Optional[np.ndarray]        # [n] f64 CDC append stamps
    published_at: float                      # serving_clock at publication
    seq: int                                 # warehouse commit sequence
    routing_epoch: Optional[int] = None      # routing epoch stamp (or None)


@dataclasses.dataclass(frozen=True)
class ViewState:
    """One view's aggregate table at one epoch (immutable)."""

    spec: ViewSpec
    table: np.ndarray                        # [S, 1 + 3L] packed, read-only

    @property
    def count(self) -> np.ndarray:
        return self.table[:, 0]

    @property
    def sums(self) -> np.ndarray:
        return self.table[:, 1:1 + self.spec.n_lanes]

    @property
    def mins(self) -> np.ndarray:
        L = self.spec.n_lanes
        return self.table[:, 1 + L:1 + 2 * L]

    @property
    def maxs(self) -> np.ndarray:
        return self.table[:, 1 + 2 * self.spec.n_lanes:]

    def means(self) -> np.ndarray:
        """Per-segment lane means; NaN for empty segments."""
        with np.errstate(invalid="ignore", divide="ignore"):
            return np.where(self.count[:, None] > 0,
                            self.sums / self.count[:, None], np.nan)


@dataclasses.dataclass(frozen=True)
class EpochSnapshot:
    """One published epoch: every view's state at a single consistent
    point of the delta stream. Immutable — pinning it IS the isolation."""

    epoch: int
    states: Mapping[str, ViewState]
    published_at: float                      # swap time (serving clock)
    watermark_event_time: float              # newest CDC event time folded
    rows_folded: int                         # fact rows folded so far
    deltas_folded: int
    # per-epoch memo for derivations every reader of this epoch shares
    # (per-view means, downtime ranking, cumulative window folds): the
    # aggregate state is immutable, so a derivation computed once is valid
    # for the epoch's whole lifetime. Excluded from equality/repr — the
    # cache is an optimization, not state.
    _memo: dict = dataclasses.field(default_factory=dict, repr=False,
                                    compare=False)
    _memo_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def view(self, name: str) -> ViewState:
        return self.states[name]

    def shared(self, key, compute):
        """Compute-once derivation shared by every reader pinning this
        epoch: first caller under ``key`` runs ``compute()``, everyone
        else gets the cached value (double-checked under the epoch's
        lock, so concurrent readers never duplicate the work)."""
        memo = self._memo
        hit = memo.get(key, _MISS)
        if hit is not _MISS:
            return hit
        with self._memo_lock:
            hit = memo.get(key, _MISS)
            if hit is _MISS:
                memo[key] = hit = compute()
            return hit

    def staleness_ms(self, now: Optional[float] = None) -> float:
        """Age of this epoch's data: clock-now minus the newest CDC event
        time visible in it. NaN before anything has been folded."""
        if not np.isfinite(self.watermark_event_time):
            return float("nan")
        return ((now if now is not None else serving_clock())
                - self.watermark_event_time) * 1e3


class MaterializedViewEngine:
    """Registry + maintenance + epoch publication for a set of views.

    Usage::

        engine = MaterializedViewEngine(steelworks_views(20))
        warehouse.attach_serving(engine)      # loads now publish deltas
        engine.start()                        # background maintenance
        snap = engine.snapshot()              # pinned epoch, never tears
        ... engine.stop()                     # folds the remaining backlog
    """

    def __init__(self, specs: Sequence[ViewSpec], backend=None,
                 idle_backoff_s: float = 0.001, scan_fold: bool = False):
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate view names: {names}")
        self.specs: Tuple[ViewSpec, ...] = tuple(specs)
        self.backend = get_backend(backend)
        self.idle_backoff_s = idle_backoff_s
        # scan_fold: fold WINDOWED views through the backend's
        # associative-scan form instead of the unrolled halving tree.
        # Bitwise-identical output (so every determinism/rebuild oracle
        # still holds) but measured slower on CPU hosts — off by default;
        # see docs/BENCHMARKS.md "scan fold" for the numbers.
        self.scan_fold = bool(scan_fold)
        self.staleness_recorder = LatencyRecorder()
        # observability seam: fold/query spans go here (NULL_TRACER until a
        # cluster wires a live StageTracer through); attach_metrics adopts
        # the staleness reservoir into a registry shard
        self.tracer = NULL_TRACER
        self._pending: "deque[FactDelta]" = deque()
        self._q_lock = threading.Lock()      # guards the pending deque
        self._fold_lock = threading.Lock()   # serializes fold cycles
        self._front = EpochSnapshot(
            epoch=0, states={s.name: _frozen_state(s) for s in specs},
            published_at=serving_clock(), watermark_event_time=-np.inf,
            rows_folded=0, deltas_folded=0)
        self._seq = 0
        self._routing_epoch = 0          # newest routing epoch stamped
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -------------------------------------------------------------- write side
    def publish(self, facts: np.ndarray,
                event_times: Optional[np.ndarray] = None,
                routing_epoch: Optional[int] = None) -> int:
        """Enqueue one fact delta (called by the warehouse under its load
        lock, so queue order == commit order). Cheap: a deque append."""
        if not len(facts):
            return self._seq
        with self._q_lock:
            self._seq += 1
            if routing_epoch is not None:
                self._routing_epoch = max(self._routing_epoch, routing_epoch)
            self._pending.append(FactDelta(
                facts=facts,
                event_times=(np.asarray(event_times, np.float64)
                             if event_times is not None else None),
                published_at=serving_clock(), seq=self._seq,
                routing_epoch=routing_epoch))
            return self._seq

    def pending(self) -> int:
        with self._q_lock:
            return len(self._pending)

    # --------------------------------------------------------------- fold cycle
    def fold_pending(self, max_deltas: Optional[int] = None) -> int:
        """Drain pending deltas (publication order) into every view and
        publish ONE new epoch covering all of them. Returns rows folded.
        Serialized: concurrent callers fold disjoint delta batches."""
        with self._fold_lock:
            with self._q_lock:
                take = len(self._pending) if max_deltas is None \
                    else min(max_deltas, len(self._pending))
                deltas = [self._pending.popleft() for _ in range(take)]
            if not deltas:
                return 0
            with self.tracer.span("serving.fold") as sp:
                front = self._front
                tables = {name: st.table
                          for name, st in front.states.items()}
                watermark = front.watermark_event_time
                rows = 0
                for d in deltas:
                    valid = d.facts[:, 9] > 0.5
                    vfacts = d.facts[valid]
                    rows += len(d.facts)
                    for spec in self.specs:
                        fold = (self.backend.fold_segments_scan
                                if self.scan_fold and spec.windowed
                                else self.backend.fold_segments)
                        agg = fold(spec.segments(vfacts),
                                   spec.values(vfacts), spec.n_segments)
                        tables[spec.name] = combine_fold(
                            tables[spec.name], agg)
                    watermark = max(watermark,
                                    float(d.event_times.max())
                                    if d.event_times is not None
                                    and len(d.event_times)
                                    else d.published_at)
                states = {}
                for spec in self.specs:
                    t = tables[spec.name]
                    t.flags.writeable = False
                    states[spec.name] = ViewState(spec, t)
                snap = EpochSnapshot(
                    epoch=front.epoch + 1, states=states,
                    published_at=serving_clock(),
                    watermark_event_time=watermark,
                    rows_folded=front.rows_folded + rows,
                    deltas_folded=front.deltas_folded + len(deltas))
                self._front = snap       # the atomic epoch swap
                # visibility staleness: the swap made these records
                # queryable
                for d in deltas:
                    if d.event_times is not None:
                        self.staleness_recorder.add(
                            snap.published_at - d.event_times)
                sp.put("deltas", len(deltas))
                sp.put("rows", rows)
                sp.put("epoch", snap.epoch)
            return rows

    # --------------------------------------------------------------- read side
    def snapshot(self) -> EpochSnapshot:
        """Pin the current epoch. Never blocks, never tears: the returned
        snapshot is immutable and survives any number of later folds."""
        return self._front

    def staleness(self, drain: bool = False) -> Dict[str, float]:
        """p50/p95/p99 of per-record visibility staleness (CDC append ->
        queryable), measured on the same clock as load freshness."""
        return self.staleness_recorder.percentiles(drain)

    def attach_metrics(self, shard) -> None:
        """Join a registry: the staleness reservoir is adopted (not
        copied) so ``registry.histogram_percentiles("staleness")`` reads
        the live recorder, and the delta backlog becomes a pull gauge."""
        shard.register_histogram("staleness", self.staleness_recorder)
        shard.gauge_fn("pending_deltas", self.pending)
        shard.gauge_fn("serving_epoch", lambda: self._front.epoch)

    def prewarm(self) -> None:
        """Compile the fold buckets a delta can hit (device backends jit
        one kernel per (rows, tree-width, n_lanes) shape). Folds are
        segment-compacted, so the tree width is
        ``min(n_segments, pow2(n_active))`` — warm every row bucket at
        full coverage (which sweeps the width ladder as the bucket grows)
        plus the narrow widths at the largest bucket; a sparse delta shape
        not warmed here compiles a smaller, cheaper tree on first hit.
        Call before measuring or serving live traffic so steady-state
        folds never stall behind compilation; a no-op for host
        backends."""
        if not self.backend.device:
            return
        from repro.core.backend import FOLD_BLOCK
        shapes = {(s.n_segments, s.n_lanes) for s in self.specs}
        for n_segments, n_lanes in shapes:
            m = 8
            while m <= FOLD_BLOCK:
                # full coverage: n_active = min(m, n_segments)
                self.backend.fold_segments(
                    np.arange(m, dtype=np.int64) % n_segments,
                    np.zeros((m, n_lanes), np.float32), n_segments)
                m *= 2
            width = 8
            while width < n_segments:      # sparse widths, largest bucket
                self.backend.fold_segments(
                    np.arange(FOLD_BLOCK, dtype=np.int64) % width,
                    np.zeros((FOLD_BLOCK, n_lanes), np.float32),
                    n_segments)
                width *= 2
        if self.scan_fold:                 # scan-form fold, windowed views
            for spec in self.specs:
                if not spec.windowed:
                    continue
                m = 8
                while m <= FOLD_BLOCK:
                    self.backend.fold_segments_scan(
                        np.arange(m, dtype=np.int64) % spec.n_segments,
                        np.zeros((m, spec.n_lanes), np.float32),
                        spec.n_segments)
                    m *= 2

    def prewarm_read(self, batch_buckets: Sequence[int] = (8, 256, 1024,
                                                           4096)) -> None:
        """Compile the batched read path's dispatch shapes: one
        ``batch_gather_stats`` compile per (view shape, batch bucket) and
        one ``prefix_fold`` compile per windowed view, so the first live
        query batch never stalls behind jit. No-op for host backends."""
        if not self.backend.device:
            return
        for spec in self.specs:
            table = empty_fold_state(spec.n_segments, spec.n_lanes)
            for b in batch_buckets:
                self.backend.batch_gather_stats(
                    table, np.zeros(b, np.int64))
            if spec.windowed:
                self.backend.prefix_fold(table)

    # -------------------------------------------------------------- maintenance
    def start(self) -> None:
        """Run the view-maintenance stage: a daemon thread folding deltas
        as they arrive (the serving analogue of a worker's load stage)."""
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._maintain, daemon=True,
                                        name="serving.fold")
        self._thread.start()

    def _maintain(self) -> None:
        while not self._stop.is_set():
            if self.fold_pending() == 0:
                time.sleep(self.idle_backoff_s)

    def stop(self) -> None:
        """Stop maintenance and fold any remaining backlog (so the final
        epoch covers every published delta)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(5.0)
            self._thread = None
        self.fold_pending()

    def abort(self) -> None:
        """Crash-drill teardown: stop maintenance WITHOUT folding the
        pending backlog — a killed process folds nothing on the way
        down. The abandoned engine's front stays wherever the last
        completed fold left it."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(5.0)
            self._thread = None

    def report(self) -> Dict[str, float]:
        snap = self._front
        out = {"epoch": snap.epoch, "views": len(self.specs),
               "rows_folded": snap.rows_folded,
               "deltas_folded": snap.deltas_folded,
               "pending_deltas": self.pending(),
               "routing_epoch": self._routing_epoch,
               "data_age_ms": round(snap.staleness_ms(), 3)}
        out.update({f"staleness_{k}": v
                    for k, v in self.staleness().items()})
        return out

    # -------------------------------------------------------------- durability
    def export_fold_state(self) -> Dict:
        """Checkpoint capture of the published front: per-view aggregate
        tables + fold counters. Lock-free — ``_front`` is an immutable
        snapshot, and the capture protocol guarantees the front's
        ``deltas_folded`` never exceeds the warehouse commit seq captured
        in the same checkpoint (folds only consume published commits)."""
        front = self._front
        return {
            "tables": {name: np.asarray(st.table)
                       for name, st in front.states.items()},
            "epoch": int(front.epoch),
            "rows_folded": int(front.rows_folded),
            "deltas_folded": int(front.deltas_folded),
            "watermark_event_time": float(front.watermark_event_time),
        }

    def restore_fold_state(self, state: Dict) -> None:
        """Cold-restart restore, before ``attach_serving``/``start``: the
        front becomes the checkpointed epoch and the delta sequence
        resumes at ``deltas_folded`` — the warehouse then replays only
        the chunk-log suffix past it. The restored watermark is a
        previous process's monotonic clock only when event times were
        absent; folded CDC event times (the normal case) carry over
        exactly."""
        states = {}
        for spec in self.specs:
            t = np.ascontiguousarray(np.asarray(state["tables"][spec.name]))
            t.flags.writeable = False
            states[spec.name] = ViewState(spec, t)
        with self._fold_lock:
            with self._q_lock:
                assert not self._pending and self._front.deltas_folded == 0, \
                    "restore_fold_state requires a fresh engine"
                self._front = EpochSnapshot(
                    epoch=int(state["epoch"]), states=states,
                    published_at=serving_clock(),
                    watermark_event_time=float(
                        state["watermark_event_time"]),
                    rows_folded=int(state["rows_folded"]),
                    deltas_folded=int(state["deltas_folded"]))
                self._seq = int(state["deltas_folded"])

    # ------------------------------------------------------------------ oracle
    @classmethod
    def rebuild(cls, specs: Sequence[ViewSpec],
                chunks: Iterable[np.ndarray], backend=None,
                scan_fold: bool = False) -> EpochSnapshot:
        """Recompute-from-scratch oracle: replay a committed chunk log
        (e.g. ``StarSchemaWarehouse.read_view().chunks``) through a fresh
        engine. Same per-delta fold path, same order — the result is
        byte-identical to the incrementally maintained state (with either
        fold form: scan and tree are bitwise-identical)."""
        eng = cls(specs, backend=backend, scan_fold=scan_fold)
        for chunk in chunks:
            eng.publish(chunk)
            eng.fold_pending()
        return eng.snapshot()


def _frozen_state(spec: ViewSpec) -> ViewState:
    table = empty_fold_state(spec.n_segments, spec.n_lanes)
    table.flags.writeable = False
    return ViewState(spec, table)


__all__ = ["FactDelta", "ViewState", "EpochSnapshot",
           "MaterializedViewEngine", "serving_clock", "fold_width"]
