"""BI serving layer: incremental materialized report views with
snapshot-isolated near-real-time queries (the read-side subsystem the
paper's 'near real-time reports previously unavailable' claim is about).

  views   — declarative ``ViewSpec``s (OEE per equipment, per-unit/shift
            KPI rollups, top-N downtime, windowed production rates)
  engine  — ``MaterializedViewEngine``: folds warehouse fact deltas into
            per-view aggregate state via the compute backend's
            ``fold_segments`` op; publishes immutable epochs
  server  — ``ReportServer``: O(n_segments) report queries with epoch +
            staleness stamps
  batch   — batched query plane: packed query plans answering thousands
            of heterogeneous queries in one backend dispatch per view,
            plus the ``BatchedReportServer`` admission front
"""
from repro.serving.batch import (BatchedReportServer,  # noqa: F401
                                 BatchResult, BatchTicket, QueryPlan,
                                 ReportQuery, compile_queries)
from repro.serving.engine import (EpochSnapshot, FactDelta,  # noqa: F401
                                  MaterializedViewEngine, ViewState,
                                  serving_clock)
from repro.serving.server import (Report, ReportServer,  # noqa: F401
                                  ReportSnapshot, downtime_rank_keys)
from repro.serving.views import (ViewSpec,  # noqa: F401
                                 downtime_by_equipment, kpi_by_unit_shift,
                                 oee_by_equipment, production_rate_windows,
                                 steelworks_views)

__all__ = [
    "EpochSnapshot", "FactDelta", "MaterializedViewEngine", "ViewState",
    "serving_clock", "Report", "ReportServer", "ReportSnapshot", "ViewSpec",
    "downtime_by_equipment", "kpi_by_unit_shift", "oee_by_equipment",
    "production_rate_windows", "steelworks_views", "downtime_rank_keys",
    "BatchedReportServer", "BatchResult", "BatchTicket", "QueryPlan",
    "ReportQuery", "compile_queries",
]
