"""Report view specs for the BI serving layer.

A ``ViewSpec`` declares one materialized report view over the fact stream:
how a fact row maps to a *segment* (the group-by key, a small dense int
domain) and which fact columns are its *value lanes*. The view engine
(``repro.serving.engine``) maintains, per view, one packed aggregate table
[n_segments, 1 + 3L] — count | sums | mins | maxs per segment — folded
incrementally from fact deltas through the compute backend's
``fold_segments`` op, so a report query costs O(n_segments), never
O(fact-table).

Segment/value extraction runs on host numpy (cheap integer math on the
delta only); the fold itself is the backend dispatch. Both are
deterministic, which is what makes incremental state replayable
bit-for-bit (see the engine's ``rebuild``).

Fact layout (``repro.core.transformer.FACT_COLUMNS``):
  0 equipment_id, 1 t_start, 2 t_end, 3 availability, 4 performance,
  5 quality, 6 oee, 7 seg_on, 8 seg_off, 9 valid
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ViewSpec:
    """One declarative materialized view: fact block -> (segments, values).

    ``segments(facts)`` returns int64 [n] segment ids; rows mapping outside
    [0, n_segments) are dropped by the fold (identity contribution).
    ``values(facts)`` returns f32 [n, len(lanes)] value lanes.
    """

    name: str
    n_segments: int
    lanes: Tuple[str, ...]
    segments: Callable[[np.ndarray], np.ndarray]
    values: Callable[[np.ndarray], np.ndarray]
    segment_names: Tuple[str, ...] = ()   # optional segment labels
    windowed: bool = False   # segments are ordered time windows: cumulative
                             # prefix reads make sense and the engine may
                             # fold deltas via the scan-form op
    key_aligned: bool = False  # segment id IS the business key (fact col 0):
                               # a sharded plane may place each segment on
                               # the shard that owns its RoutingTable
                               # partition, so folds stay shard-local and
                               # ownership migrates with repartition()

    @property
    def n_lanes(self) -> int:
        return len(self.lanes)


def _cols(facts: np.ndarray, idx) -> np.ndarray:
    return np.ascontiguousarray(facts[:, idx].astype(np.float32))


def oee_by_equipment(n_units: int) -> ViewSpec:
    """The paper's §4 deliverable: per-equipment OEE KPIs. Query-time means
    (sum/count) reproduce ``Warehouse.query_oee``; the raw sums + count
    reproduce ``Warehouse.kpi_rollup``'s [n_units, 5] layout."""
    return ViewSpec(
        name="oee_by_equipment", n_segments=n_units,
        lanes=("availability", "performance", "quality", "oee"),
        segments=lambda f: f[:, 0].astype(np.int64),
        values=lambda f: _cols(f, slice(3, 7)),
        key_aligned=True)


def kpi_by_unit_shift(n_units: int, n_shifts: int = 3,
                      shift_len: float = 4_000.0) -> ViewSpec:
    """KPI rollup per (equipment unit, shift-of-day): segment id is
    ``unit * n_shifts + shift`` with shift derived from the fact's
    production-window start tick."""
    def seg(f: np.ndarray) -> np.ndarray:
        unit = f[:, 0].astype(np.int64)
        shift = (f[:, 1] // np.float32(shift_len)).astype(np.int64) % n_shifts
        return unit * n_shifts + shift
    return ViewSpec(
        name="kpi_by_unit_shift", n_segments=n_units * n_shifts,
        lanes=("availability", "performance", "quality", "oee"),
        segments=seg,
        values=lambda f: _cols(f, slice(3, 7)))


def downtime_by_equipment(n_units: int) -> ViewSpec:
    """Top-N downtime causes: per equipment unit, summed off-segment
    seconds (the Fig. 3 fact-grain split's ``seg_off``) next to uptime —
    query-time sort of the tiny state table gives the top-N report."""
    return ViewSpec(
        name="downtime_by_equipment", n_segments=n_units,
        lanes=("downtime_s", "uptime_s"),
        segments=lambda f: f[:, 0].astype(np.int64),
        values=lambda f: _cols(f, [8, 7]),
        key_aligned=True)


def production_rate_windows(n_windows: int = 32,
                            window_len: float = 2_000.0) -> ViewSpec:
    """Windowed production rate: facts bucketed into time windows by
    production start tick (ring of ``n_windows``); count gives facts per
    window, summed runtime + min/max OEE give the window's health."""
    def seg(f: np.ndarray) -> np.ndarray:
        return (f[:, 1] // np.float32(window_len)).astype(np.int64) \
            % n_windows
    return ViewSpec(
        name="production_rate_windows", n_segments=n_windows,
        lanes=("runtime_s", "oee"),
        segments=seg,
        values=lambda f: _cols(f, [7, 6]),
        windowed=True)


def steelworks_views(n_units: int, n_shifts: int = 3,
                     shift_len: float = 4_000.0, n_windows: int = 32,
                     window_len: float = 2_000.0) -> Tuple[ViewSpec, ...]:
    """The paper's shift-report suite: every standard steelworks view."""
    return (oee_by_equipment(n_units),
            kpi_by_unit_shift(n_units, n_shifts, shift_len),
            downtime_by_equipment(n_units),
            production_rate_windows(n_windows, window_len))


__all__ = ["ViewSpec", "oee_by_equipment", "kpi_by_unit_shift",
           "downtime_by_equipment", "production_rate_windows",
           "steelworks_views"]
