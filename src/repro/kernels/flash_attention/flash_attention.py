"""Flash attention (TPU Pallas): fused streaming-softmax GQA attention.

Grid: (batch, q_heads, q_blocks, kv_blocks) with the kv dimension
``arbitrary`` (sequential) so the online-softmax state (m, l, acc) persists
in VMEM scratch across kv steps. Causal block skipping via ``pl.when`` —
fully-masked (q_block, kv_block) pairs do no compute (the pure-jnp path in
``models.attention.attend_chunked`` cannot skip; this kernel is where the
2x causal slack of the baseline roofline goes to die).

BlockSpec tiling (VMEM): q [1,1,Bq,D], k/v [1,1,Bk,D], out [1,1,Bq,D];
scores live at [Bq,Bk] f32. MXU alignment: Bq/Bk multiples of 128, D in
{64, 128}. Validated against ref.py in interpret mode (CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_kv: int,
                  n_kv_blocks: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal skip: the pair contributes only if some query can see some key
    run = ((qb + 1) * block_q - 1 >= kb * block_kv) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)              # [Bq, D]
        k = k_ref[0, 0].astype(jnp.float32)              # [Bk, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qb * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            kpos = kb * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(kb == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] -> [B, Hq, Sq, D].
    GQA: Hq must be a multiple of Hkv."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0 and sq % block_q == 0 and skv % block_kv == 0
    g = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    n_q = sq // block_q
    n_kv = skv // block_kv

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_kv=block_kv, n_kv_blocks=n_kv)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
