"""Pure-jnp oracle for the flash_attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: float | None = None
                  ) -> jax.Array:
    """q: [B, Hq, S, D]; k, v: [B, Hkv, S, D]."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(b, hq, sq, d).astype(q.dtype)
