"""Jitted public wrapper: picks the Pallas kernel (TPU) or interpret mode
(CPU validation), with the jnp oracle available as a fallback.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def mha(q, k, v, *, causal=True, scale=None, block_q=128, block_kv=128):
    """[B, Hq, S, D] x [B, Hkv, S, D] -> [B, Hq, S, D]."""
    on_tpu = jax.default_backend() == "tpu"
    return flash_attention(q, k, v, causal=causal, scale=scale,
                           block_q=block_q, block_kv=block_kv,
                           interpret=not on_tpu)


__all__ = ["mha", "flash_attention", "attention_ref"]
