"""Pallas TPU kernels for the perf-critical compute layers. Each package
holds <name>.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper picking
pallas-on-TPU / interpret-on-CPU) and ref.py (pure-jnp oracle)."""
