"""Public wrapper used by the ``pallas`` compute backend
(repro.core.backend.PallasBackend)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hash_join.hash_join import hash_join_kernel


def hash_join(query_keys, keys_tbl, vals_tbl, txn_tbl, block_q: int = 256):
    n = query_keys.shape[0]
    pad = (-n) % block_q
    if pad:
        query_keys = jnp.concatenate(
            [query_keys, jnp.full((pad,), -2, query_keys.dtype)])
    on_tpu = jax.default_backend() == "tpu"
    vals, found, txn = hash_join_kernel(
        query_keys, keys_tbl, vals_tbl, txn_tbl, block_q=block_q,
        interpret=not on_tpu)
    if pad:
        vals, found, txn = vals[:n], found[:n], txn[:n]
    return vals, found, txn


__all__ = ["hash_join", "hash_join_kernel"]
