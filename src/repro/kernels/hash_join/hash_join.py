"""Streaming hash-join probe kernel (TPU Pallas) — the In-memory Table
Updater / Data Transformer join of DOD-ETL.

TPU adaptation of a CPU/GPU hash probe: random gathers are hostile to the
VPU, so each linear-probe step is expressed as a ONE-HOT MATMUL against the
VMEM-resident table (queries x slots @ slots x width on the MXU). For the
paper's cache sizes (thousands of master rows — per-business-key filtered
slices) the whole table tile fits VMEM and the MXU turns the gather into
dense compute, which is exactly the hardware-adaptation story of DESIGN.md.

Grid: (query_blocks,). Table blocked over slots as a second sequential grid
dim when it exceeds one tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MAX_PROBES = 16


def _hash32(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _hash_join_kernel(q_ref, keys_ref, vals_ref, txn_ref,
                      out_vals_ref, out_found_ref, out_txn_ref, *,
                      n_slots: int, block_q: int):
    q = q_ref[...]                                        # [Bq] i32
    keys = keys_ref[...]                                  # [n_slots] i32
    vals = vals_ref[...]                                  # [n_slots, W] f32
    txn = txn_ref[...]                                    # [n_slots] i32
    h = (_hash32(q) % jnp.uint32(n_slots)).astype(jnp.int32)

    found = jnp.zeros((block_q,), jnp.bool_)
    done = jnp.zeros((block_q,), jnp.bool_)
    acc_v = jnp.zeros((block_q, vals.shape[1]), jnp.float32)
    acc_t = jnp.zeros((block_q,), jnp.int32)
    slot_iota = jax.lax.broadcasted_iota(jnp.int32, (block_q, n_slots), 1)

    for p in range(MAX_PROBES):
        cand = (h + p) % n_slots                          # [Bq]
        onehot = (slot_iota == cand[:, None])             # [Bq, n_slots]
        k_at = jnp.sum(jnp.where(onehot, keys[None, :], 0), axis=1)
        hit = (k_at == q) & (~done)
        empty = (k_at == -1) & (~done)
        # MXU gather: one-hot @ table
        sel = (onehot & hit[:, None]).astype(jnp.float32)
        acc_v = acc_v + jax.lax.dot_general(
            sel, vals, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_t = acc_t + jnp.sum(
            jnp.where(onehot & hit[:, None], txn[None, :], 0), axis=1)
        found = found | hit
        done = done | hit | empty

    out_vals_ref[...] = acc_v
    out_found_ref[...] = found
    out_txn_ref[...] = acc_t


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def hash_join_kernel(query_keys: jax.Array, keys_tbl: jax.Array,
                     vals_tbl: jax.Array, txn_tbl: jax.Array, *,
                     block_q: int = 256, interpret: bool = True):
    """query_keys: [N] i32; keys_tbl: [S] i32; vals_tbl: [S, W] f32;
    txn_tbl: [S] i32. Returns (vals [N, W] f32, found [N] bool, txn [N])."""
    n = query_keys.shape[0]
    n_slots, w = vals_tbl.shape
    assert n % block_q == 0, (n, block_q)

    kernel = functools.partial(_hash_join_kernel, n_slots=n_slots,
                               block_q=block_q)
    return pl.pallas_call(
        kernel,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((n_slots,), lambda i: (0,)),
            pl.BlockSpec((n_slots, w), lambda i: (0, 0)),
            pl.BlockSpec((n_slots,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, w), lambda i: (i, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, w), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.bool_),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(query_keys.astype(jnp.int32), keys_tbl.astype(jnp.int32),
      vals_tbl, txn_tbl.astype(jnp.int32))
