"""Oracle: the pure-jnp probe in repro.core.cache (identical contract, with
int64 txn downcast to i32 for the kernel comparison)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.cache import lookup_ref


def hash_join_ref(query_keys, keys_tbl, vals_tbl, txn_tbl):
    vals, found, txn = lookup_ref(query_keys.astype(jnp.int32),
                                  keys_tbl.astype(jnp.int32),
                                  vals_tbl, txn_tbl.astype(jnp.int32))
    return vals, found, txn.astype(jnp.int32)
