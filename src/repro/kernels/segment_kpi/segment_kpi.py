"""Fact-grain splitting + OEE KPI kernel (TPU Pallas) — the Data
Transformer's numeric core (paper Fig. 3 + §4 KPIs), fused:

  per record: interval intersection (production window x equipment status),
  availability / performance / quality / OEE, fact packing — then a
  per-equipment segmented reduction (sum of KPIs + counts) via one-hot
  matmul, so the OLAP rollup leaves the kernel already aggregated.

Grid: (record_blocks,) parallel; the per-unit accumulator is a second
output reduced across blocks by the caller (associative sum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6
N_FACT = 10


def _kpi_kernel(prod_ref, eq_ref, q_ref, facts_ref, agg_ref, *,
                n_units: int, block: int):
    prod = prod_ref[...]                                  # [B, 8]
    eq = eq_ref[...]                                      # [B, 8] joined rows
    qrow = q_ref[...]                                     # [B, 8]

    t_start, t_end = prod[:, 3], prod[:, 4]
    qty, speed = prod[:, 5], prod[:, 6]
    e_start, e_end = eq[:, 3], eq[:, 4]
    status, max_speed, planned = eq[:, 5], eq[:, 6], eq[:, 7]
    defects, scrap = qrow[:, 4], qrow[:, 6]

    inter_lo = jnp.maximum(t_start, e_start)
    inter_hi = jnp.minimum(t_end, e_end)
    overlap = jnp.maximum(inter_hi - inter_lo, 0.0)
    duration = jnp.maximum(t_end - t_start, EPS)
    seg_on = jnp.where(status > 0.5, overlap, 0.0)
    seg_off = duration - seg_on

    availability = jnp.clip(seg_on / jnp.maximum(planned, EPS), 0.0, 1.0)
    performance = jnp.clip(qty / jnp.maximum(max_speed * duration, EPS),
                           0.0, 1.0)
    good = jnp.maximum(qty - defects - scrap, 0.0)
    quality = jnp.clip(good / jnp.maximum(qty, EPS), 0.0, 1.0)
    oee = availability * performance * quality

    valid = (eq[:, 1] >= 0) & (qrow[:, 1] >= 0)
    facts = jnp.stack([prod[:, 1], t_start, t_end, availability,
                       performance, quality, oee, seg_on, seg_off,
                       valid.astype(jnp.float32)], axis=-1)
    facts_ref[...] = facts

    # segmented rollup: one-hot(equipment) @ [kpis, 1] on the MXU
    unit = prod[:, 1].astype(jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (block, n_units), 1)
    onehot = ((iota == unit[:, None]) & valid[:, None]).astype(jnp.float32)
    kpis = jnp.stack([availability, performance, quality, oee,
                      jnp.ones_like(oee)], axis=-1)      # [B, 5]
    agg_ref[0] = jax.lax.dot_general(
        onehot, kpis, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # [n_units, 5]


def _rollup_kernel(facts_ref, agg_ref, *, n_units: int, block: int):
    facts = facts_ref[...]                                # [B, N_FACT]
    unit = facts[:, 0].astype(jnp.int32)
    valid = facts[:, 9] > 0.5
    iota = jax.lax.broadcasted_iota(jnp.int32, (block, n_units), 1)
    onehot = ((iota == unit[:, None]) & valid[:, None]).astype(jnp.float32)
    kpis = jnp.concatenate(
        [facts[:, 3:7], jnp.ones((block, 1), jnp.float32)], axis=-1)
    agg_ref[0] = jax.lax.dot_general(
        onehot, kpis, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # [n_units, 5]


def _fold_kernel(packed_ref, out_ref, *, n_segments: int, n_lanes: int,
                 block: int):
    """Serving-layer delta fold: per segment, count + sum + min + max of
    every value lane, fused in one pass over the block.

    ``packed`` rows are [seg_id | lane_0 .. lane_{L-1}] f32 (seg as f32 —
    exact below 2^24; a negative seg marks a padding row that contributes
    the identity). count + sums ride the MXU as one one-hot matmul against
    [1 | lanes]; min/max are masked VPU reductions per lane.
    """
    packed = packed_ref[...]                              # [B, 1+L]
    seg = packed[:, 0].astype(jnp.int32)
    vals = packed[:, 1:]                                  # [B, L]

    iota = jax.lax.broadcasted_iota(jnp.int32, (block, n_segments), 1)
    hit = iota == seg[:, None]                            # [B, S] bool
    onehot = hit.astype(jnp.float32)
    ones = jnp.ones((block, 1), jnp.float32)
    cnt_sums = jax.lax.dot_general(                       # [S, 1+L]
        onehot, jnp.concatenate([ones, vals], axis=-1),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    mins = []
    maxs = []
    for j in range(n_lanes):                              # static lane loop
        lane = jnp.broadcast_to(vals[:, j:j + 1], (block, n_segments))
        mins.append(jnp.min(jnp.where(hit, lane, jnp.inf), axis=0))
        maxs.append(jnp.max(jnp.where(hit, lane, -jnp.inf), axis=0))
    out_ref[0] = jnp.concatenate(
        [cnt_sums, jnp.stack(mins, axis=-1), jnp.stack(maxs, axis=-1)],
        axis=-1)                                          # [S, 1+3L]


def _gather_kernel(table_ref, idx_ref, out_ref, *, n_segments: int,
                   n_lanes: int, block: int):
    """Batched read-path gather: for each query row, pick one segment's
    packed fold stats out of the full [S, 1+3L] view table and derive the
    lane means — the whole batch in one kernel pass.

    count + sums ride the MXU as a one-hot matmul (exact: each one-hot row
    selects a single finite table row); min/max lanes use masked VPU
    reductions instead, because the table's empty-segment identities are
    ±inf and ``0 * inf`` would poison a matmul gather with NaNs."""
    table = table_ref[...]                                # [S, 1+3L]
    idx = idx_ref[...][:, 0].astype(jnp.int32)            # [B]
    L = n_lanes

    iota = jax.lax.broadcasted_iota(jnp.int32, (block, n_segments), 1)
    hit = iota == idx[:, None]                            # [B, S] bool
    onehot = hit.astype(jnp.float32)
    cnt_sums = jax.lax.dot_general(                       # [B, 1+L]
        onehot, table[:, :1 + L],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    mins = []
    maxs = []
    for j in range(L):                                    # static lane loop
        mincol = jnp.broadcast_to(table[:, 1 + L + j][None, :],
                                  (block, n_segments))
        maxcol = jnp.broadcast_to(table[:, 1 + 2 * L + j][None, :],
                                  (block, n_segments))
        mins.append(jnp.min(jnp.where(hit, mincol, jnp.inf), axis=1))
        maxs.append(jnp.max(jnp.where(hit, maxcol, -jnp.inf), axis=1))

    cnt = cnt_sums[:, :1]
    means = jnp.where(cnt > 0, cnt_sums[:, 1:] / cnt, jnp.nan)
    out_ref[...] = jnp.concatenate(
        [cnt_sums, jnp.stack(mins, axis=-1), jnp.stack(maxs, axis=-1),
         means], axis=-1)                                 # [B, 1+4L]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gather_stats_kernel(table: jax.Array, idx: jax.Array, *,
                        block: int = 256, interpret: bool = True):
    """table [S, 1+3L] packed fold stats; idx [N, 1] f32 segment ids
    (exact below 2^24), N % block == 0, every id in [0, S). Returns
    [N, 1+4L]: [count | sums | mins | maxs | means] per query row."""
    n = idx.shape[0]
    s, w = table.shape
    n_lanes = (w - 1) // 3
    assert n % block == 0
    nb = n // block
    width = 1 + 4 * n_lanes
    kernel = functools.partial(_gather_kernel, n_segments=s,
                               n_lanes=n_lanes, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((s, w), lambda i: (0, 0)),       # full table
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((block, width), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, width), jnp.float32)],
        interpret=interpret,
    )(table, idx)[0]


@functools.partial(jax.jit,
                   static_argnames=("n_segments", "block", "interpret"))
def fold_segments_kernel(packed: jax.Array, *, n_segments: int = 32,
                         block: int = 256, interpret: bool = True):
    """packed [N, 1+L] f32 (seg id lane + L value lanes), N % block == 0.
    Returns [blocks, n_segments, 1+3L]: per-block packed fold tables —
    caller combines across blocks (count/sum add, min min, max max)."""
    n, w = packed.shape
    n_lanes = w - 1
    assert n % block == 0
    nb = n // block
    width = 1 + 3 * n_lanes
    kernel = functools.partial(_fold_kernel, n_segments=n_segments,
                               n_lanes=n_lanes, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block, w), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, n_segments, width),
                                lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, n_segments, width),
                                        jnp.float32)],
        interpret=interpret,
    )(packed)[0]


@functools.partial(jax.jit, static_argnames=("n_units", "block", "interpret"))
def segment_rollup_kernel(facts: jax.Array, *, n_units: int = 32,
                          block: int = 256, interpret: bool = True):
    """Standalone per-unit KPI rollup over already-built fact rows
    [N, N_FACT] f32 (col 0 = unit, col 9 = valid flag): one-hot matmul on
    the MXU, same discipline as the fused ``segment_kpi_kernel`` epilogue.
    Returns agg [blocks, n_units, 5] — caller sums over blocks."""
    n = facts.shape[0]
    assert n % block == 0
    nb = n // block
    kernel = functools.partial(_rollup_kernel, n_units=n_units, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block, N_FACT), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, n_units, 5), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, n_units, 5), jnp.float32)],
        interpret=interpret,
    )(facts)[0]


@functools.partial(jax.jit, static_argnames=("n_units", "block", "interpret"))
def segment_kpi_kernel(prod: jax.Array, eq_rows: jax.Array,
                       q_rows: jax.Array, *, n_units: int = 32,
                       block: int = 256, interpret: bool = True):
    """prod/eq_rows/q_rows: [N, 8] f32 (production payloads + joined master
    rows; a row with col1 < 0 marks a join miss). Returns (facts [N, 10],
    agg [blocks, n_units, 5]) — caller sums agg over blocks."""
    n = prod.shape[0]
    assert n % block == 0
    nb = n // block
    kernel = functools.partial(_kpi_kernel, n_units=n_units, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block, 8), lambda i: (i, 0)),
            pl.BlockSpec((block, 8), lambda i: (i, 0)),
            pl.BlockSpec((block, 8), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, N_FACT), lambda i: (i, 0)),
            pl.BlockSpec((1, n_units, 5), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, N_FACT), jnp.float32),
            jax.ShapeDtypeStruct((nb, n_units, 5), jnp.float32),
        ],
        interpret=interpret,
    )(prod, eq_rows, q_rows)
