"""Oracle: per-record KPI math identical to repro.core.transformer plus the
per-unit rollup in plain jnp (segment_sum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-6


def segment_kpi_ref(prod, eq_rows, q_rows, *, n_units: int = 32):
    t_start, t_end = prod[:, 3], prod[:, 4]
    qty = prod[:, 5]
    e_start, e_end = eq_rows[:, 3], eq_rows[:, 4]
    status, max_speed, planned = eq_rows[:, 5], eq_rows[:, 6], eq_rows[:, 7]
    defects, scrap = q_rows[:, 4], q_rows[:, 6]

    overlap = jnp.maximum(jnp.minimum(t_end, e_end) -
                          jnp.maximum(t_start, e_start), 0.0)
    duration = jnp.maximum(t_end - t_start, EPS)
    seg_on = jnp.where(status > 0.5, overlap, 0.0)
    seg_off = duration - seg_on
    availability = jnp.clip(seg_on / jnp.maximum(planned, EPS), 0.0, 1.0)
    performance = jnp.clip(qty / jnp.maximum(max_speed * duration, EPS),
                           0.0, 1.0)
    good = jnp.maximum(qty - defects - scrap, 0.0)
    quality = jnp.clip(good / jnp.maximum(qty, EPS), 0.0, 1.0)
    oee = availability * performance * quality
    valid = (eq_rows[:, 1] >= 0) & (q_rows[:, 1] >= 0)
    facts = jnp.stack([prod[:, 1], t_start, t_end, availability,
                       performance, quality, oee, seg_on, seg_off,
                       valid.astype(jnp.float32)], axis=-1)
    kpis = jnp.stack([availability, performance, quality, oee,
                      jnp.ones_like(oee)], axis=-1)
    kpis = jnp.where(valid[:, None], kpis, 0.0)
    agg = jax.ops.segment_sum(kpis, prod[:, 1].astype(jnp.int32),
                              num_segments=n_units)
    return facts, agg
