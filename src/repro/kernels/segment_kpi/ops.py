"""Public wrappers: pad to the kernel block size, combine the per-block
partials ON DEVICE (no host sync — the results stay jax arrays, so the
pallas backend's ``FactBlock``s remain device-resident until the
warehouse-load boundary).

``segment_kpi`` is the pallas backend's ``transform_and_rollup`` core:
one fused kernel emits the fact rows AND the per-unit KPI aggregate, so
the hot path never re-uploads the block for a separate rollup dispatch.
``fold_segments`` receives the serving layer's SEGMENT-COMPACTED deltas
(``n_segments`` here is the compacted tree width, not the view's full
segment count — see ``repro.core.backend._fold_blocks``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment_kpi.segment_kpi import (fold_segments_kernel,
                                                   gather_stats_kernel,
                                                   segment_kpi_kernel,
                                                   segment_rollup_kernel)


def segment_kpi(prod, eq_rows, q_rows, *, n_units: int = 32,
                block: int = 256):
    """Fused fact build + per-unit KPI rollup: returns (facts [N, 10],
    agg [n_units, 5]), both device-resident (agg's cross-block sum is a
    device op). Rows whose joined master rows are marked missing
    (col 1 < 0) — and pad rows, whose unit id is -1 — contribute nothing
    to the aggregate."""
    n = prod.shape[0]
    pad = (-n) % block
    if pad:
        padrow = jnp.full((pad, 8), -1.0, jnp.float32)
        prod = jnp.concatenate([prod, padrow])
        eq_rows = jnp.concatenate([eq_rows, padrow])
        q_rows = jnp.concatenate([q_rows, padrow])
    on_tpu = jax.default_backend() == "tpu"
    facts, agg = segment_kpi_kernel(prod, eq_rows, q_rows, n_units=n_units,
                                    block=block, interpret=not on_tpu)
    return facts[:n], agg.sum(axis=0)


def segment_rollup(facts, *, n_units: int = 32, block: int = 256):
    """Per-unit KPI rollup of fact rows [N, 10]; pads with invalid rows."""
    n = facts.shape[0]
    pad = (-n) % block
    if pad:
        facts = jnp.concatenate(
            [facts, jnp.zeros((pad, facts.shape[1]), jnp.float32)])
    on_tpu = jax.default_backend() == "tpu"
    agg = segment_rollup_kernel(facts, n_units=n_units, block=block,
                                interpret=not on_tpu)
    return agg.sum(axis=0)


def fold_segments(packed, *, n_segments: int = 32, block: int = 256):
    """Serving-layer delta fold of packed rows [N, 1+L] (seg id + value
    lanes): count/sum/min/max per segment, one fused kernel dispatch.
    Pads with seg = -1 identity rows; combines the per-block tables."""
    n, w = packed.shape
    L = w - 1
    pad = (-n) % block
    if pad:
        padrow = jnp.concatenate(
            [jnp.full((pad, 1), -1.0, jnp.float32),
             jnp.zeros((pad, L), jnp.float32)], axis=1)
        packed = jnp.concatenate([packed, padrow])
    on_tpu = jax.default_backend() == "tpu"
    agg = fold_segments_kernel(packed, n_segments=n_segments, block=block,
                               interpret=not on_tpu)     # [nb, S, 1+3L]
    return jnp.concatenate(
        [agg[:, :, :1 + L].sum(axis=0),
         agg[:, :, 1 + L:1 + 2 * L].min(axis=0),
         agg[:, :, 1 + 2 * L:].max(axis=0)], axis=-1)


def gather_stats(table, idx, *, block: int = 256):
    """Batched point-query gather against a packed [S, 1+3L] fold table:
    returns [len(idx), 1+4L] ([count | sums | mins | maxs | means]) in one
    kernel dispatch. ``idx`` int segment ids in [0, S); pads the batch to
    a block multiple with id 0 (valid row, sliced off after)."""
    idx = jnp.asarray(idx, jnp.float32)[:, None]          # [N, 1]
    n = idx.shape[0]
    pad = (-n) % block
    if pad:
        idx = jnp.concatenate([idx, jnp.zeros((pad, 1), jnp.float32)])
    on_tpu = jax.default_backend() == "tpu"
    out = gather_stats_kernel(table, idx, block=block,
                              interpret=not on_tpu)
    return out[:n]


__all__ = ["fold_segments", "fold_segments_kernel", "gather_stats",
           "gather_stats_kernel", "segment_kpi", "segment_kpi_kernel",
           "segment_rollup", "segment_rollup_kernel"]
