"""Oracle: the chunked-form reference lives in repro.models.gla (validated
against a step-by-step recurrence in tests); this re-exports it in the
kernel's [BH, S, d] layout with a per-row bonus vector (u=0 == no bonus).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.gla import gla_chunk as _gla_chunk_bshd


def gla_ref(q, k, v, log_w, u=None, *, inclusive=False, chunk=64):
    """q,k,log_w: [BH, S, dk]; v: [BH, S, dv]; u: [BH, dk] or None."""
    bh, s, dk = q.shape
    if u is None:
        u = jnp.zeros((bh, dk), q.dtype)   # zero bonus == no bonus

    def one(qr, kr, vr, lwr, ur):
        out, _ = _gla_chunk_bshd(
            qr[None, :, None, :], kr[None, :, None, :], vr[None, :, None, :],
            lwr[None, :, None, :], u=ur[None], inclusive=inclusive,
            chunk=chunk, ratio_dtype=jnp.float32)
        return out[0, :, 0]

    return jax.vmap(one)(q, k, v, log_w, u)
