"""Chunked gated-linear-recurrence kernel (TPU Pallas).

One kernel serves RWKV6 (per-channel decay, lag-1 state read + bonus u) and
Mamba2/SSD (scalar-per-head decay broadcast over dk, inclusive state read).

Grid: (batch*heads, n_chunks); the chunk dimension is ``arbitrary`` so the
running state S [dk, dv] persists in f32 VMEM scratch across chunks. Per
chunk everything is VMEM-resident: q/k/v/log_w blocks [C, d*], the masked
decay-ratio tensor [C, C] per dk lane is formed lane-blocked to bound VMEM.

This is the on-demand stream processor of the model plane: O(T) processing
of an unbounded token stream with a constant-size in-memory state — the
same shape as the paper's ETL pipeline (stream + small cache), which is why
the two share a roofline story.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _gla_kernel(q_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                chunk: int, inclusive: bool, use_u: bool):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[0].astype(jnp.float32)          # [C, dk]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # [C, dv]
    lw = lw_ref[0].astype(jnp.float32)        # [C, dk]
    S = s_ref[...]                            # [dk, dv] f32

    L = jnp.cumsum(lw, axis=0)
    Lq = L if inclusive else L - lw
    lag = 0 if inclusive else 1
    t = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    pair_mask = t >= (i + lag)

    # inter-chunk: (q . exp(Lq)) @ S
    inter = jax.lax.dot_general(q * jnp.exp(Lq), S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # intra-chunk: A[t,i] = sum_d q_td k_id exp(Lq_t,d - L_i,d), masked
    diff = Lq[:, None, :] - L[None, :, :]                 # [C, C, dk]
    diff = jnp.where(pair_mask[:, :, None], diff, NEG_INF)
    A = jnp.sum(q[:, None, :] * k[None, :, :] * jnp.exp(diff), axis=-1)
    intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    out = inter + intra
    if use_u:
        u = u_ref[0].astype(jnp.float32)                  # [1, dk] -> [dk]
        dot = jnp.sum(q * u * k, axis=-1)                 # [C]
        out = out + dot[:, None] * v

    # state update: S <- exp(L_C) * S + sum_i k_i exp(L_C - L_i) v_i
    Ltot = L[-1:, :]                                      # [1, dk]
    k_dec = k * jnp.exp(Ltot - L)
    s_ref[...] = jnp.exp(Ltot[0])[:, None] * S + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("inclusive", "chunk", "interpret"))
def gla_chunk_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                     log_w: jax.Array, u: jax.Array | None = None, *,
                     inclusive: bool = False, chunk: int = 64,
                     interpret: bool = True) -> jax.Array:
    """q,k,log_w: [BH, S, dk]; v: [BH, S, dv]; u: [BH, dk] or None.
    Returns out [BH, S, dv] (batch*heads flattened by the ops wrapper)."""
    bh, s, dk = q.shape
    dv = v.shape[-1]
    assert s % chunk == 0
    n = s // chunk
    use_u = u is not None
    if u is None:
        u = jnp.zeros((bh, dk), q.dtype)

    kernel = functools.partial(_gla_kernel, chunk=chunk,
                               inclusive=inclusive, use_u=use_u)
    return pl.pallas_call(
        kernel,
        grid=(bh, n),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, dv), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, dk), lambda bi, ci: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda bi, ci: (bi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), v.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, log_w, u)
