"""Public wrapper: [B, S, H, d] layout in/out, flattening (B, H) for the
kernel grid; interpret mode off TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gla_chunk.gla_chunk import gla_chunk_kernel


def gla(q, k, v, log_w, u=None, *, inclusive=False, chunk=64):
    """q,k,log_w: [B, S, H, dk]; v: [B, S, H, dv]; u: [H, dk] or None."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, -1)
    uf = None if u is None else jnp.tile(u, (b, 1))
    on_tpu = jax.default_backend() == "tpu"
    out = gla_chunk_kernel(fold(q), fold(k), fold(v), fold(log_w), uf,
                           inclusive=inclusive, chunk=chunk,
                           interpret=not on_tpu)
    return out.reshape(b, h, s, dv).transpose(0, 2, 1, 3)


__all__ = ["gla", "gla_chunk_kernel"]
