"""Self-healing control plane: failure detection, supervised restart,
credit-based backpressure and the autonomous elastic scaling loop
(ROADMAP item 4 — the paper's premise is an *always-on* pipeline that
keeps reports fresh through load spikes and worker churn without a
human in the loop).

One ``ControlPlane`` thread runs two cadences against a live
``ConcurrentCluster``:

* **Supervision** (every ``tick_s``): each worker's stage loops publish
  monotonic heartbeats (``WorkerRuntime.beat``) — a stage that stops
  beating past ``heartbeat_deadline_s`` makes its worker *suspect*. A
  suspect gets one in-band control ping (a ``_Ping`` on the worker's
  control queue, acked by the ingest loop); if the heartbeats are still
  stale after ``ping_grace_s`` the worker is *confirmed* failed — this
  catches crashes (a dead stage thread never beats again) AND hangs /
  stragglers (a wedged thread beats never, a straggler beats late),
  which ``fail_workers()`` by itself cannot. Confirmation drives the
  existing revoke/quiesce/transfer/grant machinery through the forced
  path (``ConcurrentCluster.replace_worker`` / ``evict_workers``): the
  broker fences the evicted consumer group so a zombie thread that
  later wakes cannot move offsets, and the replacement re-hydrates
  through the same substrate recovery uses (compacted-topic cache
  dump + watermarks via the grant path, adopted replicated buffers).

* **Policy** (every ``policy_interval_s``): the controller samples
  ``health()`` — freshness percentiles, backlog, commit lag, per-worker
  load — applies hysteresis (K consecutive out-of-band samples) and a
  cooldown between actions, then autonomously calls ``scale_to`` /
  ``repartition``. Every executed decision is traced as a
  ``control.decide`` span and crosses the ``control.decide`` fault seam
  so drills can kill the controller mid-decision.

Supervised restart: a confirmed-failed worker is replaced with
exponential backoff + deterministic jitter; ``restart.pre_hydrate``
trips before each attempt so drills can fail restarts repeatedly; after
``max_consecutive_restarts`` consecutive failures a circuit breaker
opens (no more restarts until ``reset_breaker()``), and the confirmed
worker is still evicted so the survivors keep the stream alive in
degraded mode — serving keeps answering from the last epoch with its
honest staleness stamps.

Credit-based backpressure lives in ``CreditLedger`` (one per worker
runtime): ingest *takes* credits before a fetch (never blocking — a
zero grant just skips the fetch, so the ledger cannot deadlock by
construction) and the load stage *refunds* at commit/retire time. A
stalled downstream stops refunding, the ledger drains, ingest stops
fetching and the CDC extraction loop backs off — explicit flow control
end to end, replacing the implicit bounded-queue coupling.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Dict, List, Optional

from repro.durability.faults import (CONTROL_DECIDE, RESTART_PRE_HYDRATE,
                                     InjectedCrash)


class QuiesceTimeout(RuntimeError):
    """A coordinator deadline expired: a quiesce, revoke/grant/reroute
    ack, or worker join did not complete in time. Typed so callers can
    distinguish a wedged worker from a programming error."""


class QuiesceTimeoutWarning(UserWarning):
    """Emitted when ``WorkerRuntime.join`` returns with stage threads
    still alive — the caller's stop is complete but a wedged thread
    remains (counted in ``worker.join_timeouts``)."""


class CreditLedger:
    """Per-worker flow-control credits, denominated in records.

    Invariants (asserted by tests):
    * ``available + outstanding == capacity`` at every instant;
    * ``spent - refunded == outstanding`` (conservation);
    * ``take`` never blocks and never grants more than ``available``,
      so no schedule of stalls can deadlock the ledger — a starved
      ingest simply idles until the load stage refunds.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self.available = int(capacity)
        self.spent = 0
        self.refunded = 0
        self._lock = threading.Lock()

    def take(self, upto: int) -> int:
        """Grant up to ``upto`` credits (possibly 0). Non-blocking."""
        if upto <= 0:
            return 0
        with self._lock:
            grant = min(int(upto), self.available)
            self.available -= grant
            self.spent += grant
            return grant

    def refund(self, n: int) -> None:
        if n <= 0:
            return
        with self._lock:
            self.refunded += int(n)
            self.available = min(self.capacity, self.available + int(n))

    @property
    def outstanding(self) -> int:
        return self.capacity - self.available

    def exhausted(self) -> bool:
        return self.available <= 0


@dataclasses.dataclass
class _Ping:
    """Supervisor -> worker liveness probe, applied (and acked) by the
    ingest loop at its control-drain point like every other control
    message."""
    kind: str = "ping"
    ack: threading.Event = dataclasses.field(default_factory=threading.Event)


@dataclasses.dataclass
class ControlConfig:
    """Tunables for the control plane. Defaults are conservative enough
    for a cold ``jax`` backend (first dispatches JIT-compile for
    seconds); tests and benchmarks on the numpy backend tighten them to
    keep drills sub-second."""
    tick_s: float = 0.05                 # supervision cadence
    # --- failure detection
    heartbeat_deadline_s: float = 2.0    # stage silence before suspect
    ping_grace_s: float = 0.5            # suspect -> confirmed window
    warmup_s: float = 3.0                # post-start grace (cold JIT)
    # --- supervised restart
    restart: bool = True
    restart_backoff_s: float = 0.25      # base of the exponential backoff
    restart_backoff_max_s: float = 5.0
    restart_jitter_s: float = 0.1        # deterministic (crc32) jitter span
    max_consecutive_restarts: int = 3    # breaker opens after this many
    # --- scaling policy
    scaling: bool = True
    policy_interval_s: float = 0.25      # health() sampling cadence
    hysteresis_samples: int = 3          # consecutive out-of-band samples
    cooldown_s: float = 2.0              # min seconds between actions
    min_workers: int = 1
    max_workers: int = 8
    backlog_high_per_worker: int = 2000  # scale up above this
    backlog_low_per_worker: int = 100    # scale down below this
    scale_down: bool = True              # allow autonomous scale-down
    scale_down_hysteresis_mult: int = 4  # extra hysteresis for shrinking
    repartition: bool = True
    imbalance_threshold: float = 1.75    # max/mean per-worker lag ratio
    imbalance_min_backlog: int = 500     # ignore imbalance of a tiny lag
    evict_lock_timeout_s: float = 1.0    # forced-eviction commit-lock wait
    evict_join_timeout_s: float = 2.0    # forced-eviction thread-join wait


class ControlPlane:
    """Supervisor + controller thread for one ``ConcurrentCluster``.

    Attach via ``ConcurrentCluster(pipe, control=ControlConfig(...))``
    (or ``control=True`` for defaults); the cluster starts/stops it with
    its own lifecycle. All state is owned by the single control thread;
    snapshot readers see GIL-atomic field reads only.
    """

    def __init__(self, cluster, cfg: Optional[ControlConfig] = None):
        self.cluster = cluster
        self.cfg = cfg or ControlConfig()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.crashed = False             # an InjectedCrash killed the loop
        # supervision state (control-thread-owned)
        self._suspects: Dict[str, Dict] = {}
        self.suspect_names: List[str] = []   # snapshot-readable copy
        # restart/backoff state
        self.consecutive_restart_failures = 0
        self.restart_attempts = 0
        self.breaker_open = False
        self._next_restart_at = 0.0
        self.last_backoff_s = 0.0
        # policy state
        self._high_streak = 0
        self._low_streak = 0
        self._imb_streak = 0
        self._cooldown_until = 0.0
        self._last_policy_at = 0.0
        # decision log (bounded) + last-eviction marker for drills
        self.decisions: List[Dict] = []
        self.last_eviction: Optional[Dict] = None
        self.evictions = 0
        self.restarts = 0
        self.restart_failures = 0
        self.scale_ups = 0
        self.scale_downs = 0
        self.repartitions = 0
        shard = cluster.pipe.metrics.shard("control")
        self._c_pings = shard.counter("control.pings")
        self._c_evictions = shard.counter("control.evictions")
        self._c_restarts = shard.counter("control.restarts")
        self._c_restart_failures = shard.counter("control.restart_failures")
        self._c_decisions = shard.counter("control.decisions")
        self._c_scale_ups = shard.counter("control.scale_ups")
        self._c_scale_downs = shard.counter("control.scale_downs")
        self._c_repartitions = shard.counter("control.repartitions")
        self._c_errors = shard.counter("control.errors")
        shard.gauge_fn("breaker_open", lambda: int(self.breaker_open))
        shard.gauge_fn("suspects", lambda: len(self.suspect_names))
        shard.gauge_fn("degraded", lambda: int(self.degraded()))

    # -------------------------------------------------------------- lifecycle
    def start(self) -> None:
        if self._thread is not None:
            return
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="control.plane")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(5.0)
            self._thread = None

    def _run(self) -> None:
        try:
            while not self._stop.wait(self.cfg.tick_s):
                self._tick(time.perf_counter())
        except InjectedCrash:
            # control.decide drill: the controller dies mid-decision.
            # The data plane is unaffected — decisions are executed
            # atomically through coordinator actions, so a crash before
            # the action leaves the cluster exactly as it was.
            self.crashed = True

    # ------------------------------------------------------------ degradation
    def degraded(self) -> bool:
        """Serving continues from the last epoch (honest staleness
        stamps) but the pipeline is impaired: a breaker is open, a
        worker is suspect/confirmed, or some live ledger is exhausted
        (downstream stall throttling extraction)."""
        if self.breaker_open or self.suspect_names:
            return True
        for rt in list(self.cluster.runtimes.values()):
            if not rt.dead and rt.credits.exhausted():
                return True
        return False

    # ------------------------------------------------------------ supervision
    def _tick(self, now: float) -> None:
        try:
            self._supervise(now)
        except InjectedCrash:
            raise
        except Exception:
            self._c_errors.inc()
        if self.cfg.scaling and now - self._last_policy_at \
                >= self.cfg.policy_interval_s:
            self._last_policy_at = now
            try:
                self._policy(now)
            except InjectedCrash:
                raise
            except Exception:
                self._c_errors.inc()

    def _supervise(self, now: float) -> None:
        cfg = self.cfg
        for name, rt in list(self.cluster.runtimes.items()):
            if rt.dead or not rt.hb:
                self._suspects.pop(name, None)
                continue
            if rt.started_at is None or now - rt.started_at < cfg.warmup_s:
                continue
            stale = [s for s, t in rt.hb.items()
                     if now - t > cfg.heartbeat_deadline_s]
            if not stale:
                self._suspects.pop(name, None)
                continue
            st = self._suspects.get(name)
            if st is None:
                ping = _Ping()
                rt.control.put(ping)
                self._c_pings.inc()
                self._suspects[name] = {"since": now, "ping": ping,
                                        "stale": stale}
            elif now - st["since"] >= cfg.ping_grace_s:
                # confirmed: the ping either never acked (ingest wedged)
                # or acked while a non-ingest stage stayed silent — both
                # are a failed worker, not a blip
                self._confirm(name, rt, stale, st, now)
        self.suspect_names = sorted(self._suspects)

    def _confirm(self, name: str, rt, stale: List[str], st: Dict,
                 now: float) -> None:
        cfg = self.cfg
        if now < self._next_restart_at:
            return                       # backing off a failed restart
        restart = cfg.restart and not self.breaker_open
        self._decide("evict" + ("+restart" if restart else ""), now,
                     worker=name, stale=stale,
                     ping_acked=st["ping"].ack.is_set())
        try:
            if restart:
                self.restart_attempts += 1
                # seam: the replacement is about to re-hydrate (cache
                # dump from compacted topics + buffer adoption)
                self.cluster.pipe.fault.trip(RESTART_PRE_HYDRATE)
                self.cluster.replace_worker(
                    name, lock_timeout=cfg.evict_lock_timeout_s,
                    join_timeout=cfg.evict_join_timeout_s)
                self.restarts += 1
                self._c_restarts.inc()
                self.consecutive_restart_failures = 0
            else:
                survivors = [n for n in self.cluster.alive_workers()
                             if n != name]
                if not survivors:
                    return               # nothing to fail over to: stay
                                         # suspect, serving runs degraded
                self.cluster.evict_workers(
                    [name], lock_timeout=cfg.evict_lock_timeout_s,
                    join_timeout=cfg.evict_join_timeout_s)
        except InjectedCrash:
            self._restart_failed(now)
            return
        except Exception:
            self._restart_failed(now)
            self._c_errors.inc()
            return
        self.evictions += 1
        self._c_evictions.inc()
        self.last_eviction = {"worker": name, "at_s": time.perf_counter(),
                              "suspect_since_s": st["since"],
                              "stale_stages": stale,
                              "restarted": restart}
        self._suspects.pop(name, None)

    def _restart_failed(self, now: float) -> None:
        """Exponential backoff with deterministic jitter; breaker after
        N consecutive failures."""
        cfg = self.cfg
        self.restart_failures += 1
        self._c_restart_failures.inc()
        self.consecutive_restart_failures += 1
        k = self.consecutive_restart_failures
        jitter = (zlib.crc32(f"restart:{self.restart_attempts}".encode())
                  % 1000) / 1000.0 * cfg.restart_jitter_s
        self.last_backoff_s = min(cfg.restart_backoff_max_s,
                                  cfg.restart_backoff_s * (2 ** (k - 1))
                                  ) + jitter
        self._next_restart_at = now + self.last_backoff_s
        self._log_decision({"action": "restart_backoff", "at_s": now,
                            "failures": k, "backoff_s": self.last_backoff_s})
        if k >= cfg.max_consecutive_restarts:
            self.breaker_open = True
            self._log_decision({"action": "breaker_open", "at_s": now,
                                "failures": k})

    def reset_breaker(self) -> None:
        """Operator action (docs/OPERATIONS.md): close the breaker and
        let supervised restarts resume."""
        self.breaker_open = False
        self.consecutive_restart_failures = 0
        self._next_restart_at = 0.0

    # ----------------------------------------------------------------- policy
    def _policy(self, now: float) -> None:
        cfg = self.cfg
        h = self.cluster.health()
        backlog = (h["backlog"]["operational_lag"]
                   + h["backlog"]["buffered"])
        alive = [n for n, w in h["workers"].items() if w["alive"]]
        n_alive = max(1, len(alive))
        per_worker = backlog / n_alive
        # per-worker owned commit lag (imbalance signal), derived from
        # the same snapshot so ownership and lag agree
        lag_by_worker = {n: 0 for n in alive}
        for topic, lags in h["commit_lag"].items():
            for name in alive:
                for p in h["workers"][name]["partitions"]:
                    lag_by_worker[name] += lags.get(p, 0)
        lag_vals = [lag_by_worker[n] for n in alive]
        mean_lag = sum(lag_vals) / n_alive
        imbalance = (max(lag_vals) / mean_lag) if mean_lag > 0 else 1.0

        self._high_streak = (self._high_streak + 1
                             if per_worker > cfg.backlog_high_per_worker
                             else 0)
        self._low_streak = (self._low_streak + 1
                            if per_worker < cfg.backlog_low_per_worker
                            else 0)
        self._imb_streak = (self._imb_streak + 1
                            if (imbalance > cfg.imbalance_threshold
                                and backlog >= cfg.imbalance_min_backlog)
                            else 0)
        if now < self._cooldown_until:
            return
        sample = {"backlog": backlog, "per_worker": round(per_worker, 1),
                  "imbalance": round(imbalance, 3), "alive": len(alive),
                  "freshness_p95_ms": h["freshness"].get("p95_ms")}

        if self._high_streak >= cfg.hysteresis_samples \
                and len(alive) < cfg.max_workers:
            self._decide("scale_up", now, **sample)
            self.cluster.scale_to(len(alive) + 1)
            self.scale_ups += 1
            self._c_scale_ups.inc()
            self._acted(now)
        elif self._imb_streak >= cfg.hysteresis_samples and cfg.repartition:
            self._decide("repartition", now, **sample)
            self.cluster.repartition()
            self.repartitions += 1
            self._c_repartitions.inc()
            self._acted(now)
        elif cfg.scale_down and len(alive) > cfg.min_workers \
                and self._low_streak >= (cfg.hysteresis_samples
                                         * cfg.scale_down_hysteresis_mult):
            self._decide("scale_down", now, **sample)
            self.cluster.scale_to(len(alive) - 1)
            self.scale_downs += 1
            self._c_scale_downs.inc()
            self._acted(now)

    def _acted(self, now: float) -> None:
        self._cooldown_until = time.perf_counter() + self.cfg.cooldown_s
        self._high_streak = self._low_streak = self._imb_streak = 0

    # ------------------------------------------------------------ bookkeeping
    def _decide(self, action: str, now: float, **detail) -> None:
        """Record + trace a decision, then cross the ``control.decide``
        fault seam (a drill may kill the controller right here — before
        the action executes, so the data plane stays consistent)."""
        self._log_decision({"action": action, "at_s": now, **detail})
        self._c_decisions.inc()
        with self.cluster.pipe.tracer.span("control.decide") as sp:
            sp.put("action", action)
        self.cluster.pipe.fault.trip(CONTROL_DECIDE)

    def _log_decision(self, entry: Dict) -> None:
        self.decisions.append(entry)
        if len(self.decisions) > 256:
            del self.decisions[:64]

    def snapshot(self) -> Dict:
        """Control-plane section of the health snapshot. Lock-free:
        every field is one GIL-atomic read of control-thread state."""
        credits = {}
        dead_lettered = 0
        for name, rt in list(self.cluster.runtimes.items()):
            dead_lettered += len(rt.worker.dead_letter)
            if not rt.dead:
                credits[name] = {"available": rt.credits.available,
                                 "outstanding": rt.credits.outstanding}
        return {
            "enabled": True,
            "crashed": self.crashed,
            "degraded": self.degraded(),
            "breaker_open": self.breaker_open,
            "suspects": list(self.suspect_names),
            "evictions": self.evictions,
            "restarts": self.restarts,
            "restart_failures": self.restart_failures,
            "restart_attempts": self.restart_attempts,
            "dead_lettered": dead_lettered,
            "scale_ups": self.scale_ups,
            "scale_downs": self.scale_downs,
            "repartitions": self.repartitions,
            "decisions": len(self.decisions),
            "last_decision": self.decisions[-1] if self.decisions else None,
            "last_eviction": self.last_eviction,
            "credits": credits,
        }


__all__ = ["CreditLedger", "ControlConfig", "ControlPlane",
           "QuiesceTimeout", "QuiesceTimeoutWarning"]
