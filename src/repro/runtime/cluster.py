"""Cluster runtimes for the paper's distributed experiments (§3.1
"distributed, parallel"; §4.1.3 fault tolerance).

Two runtimes share the same Stream Processor workers:

``ConcurrentCluster`` — the real one. Every worker runs on its own executor
threads (numpy/jax release the GIL inside the hot ops, so worker steps
genuinely overlap), with the per-worker ingest -> transform -> load stages
decoupled by bounded hand-off queues. A coordinator owns the
``PartitionAssignment`` and performs *incremental* rebalances: only moved
partitions quiesce; healthy workers keep processing their retained
partitions throughout a failover or elastic resize. Exactly-once handoff
comes from the broker's position/commit split (fetch advances read
positions; commits land after warehouse load, under the worker's commit
lock), and §4.1.3's failure injection — kill workers mid-run under load —
loses no records and duplicates none. Every loaded record reports its
end-to-end freshness (load time minus the CDC append event-time stamp),
aggregated as p50/p95/p99.

``SimulatedCluster`` — the legacy modeled runtime: one thread executes all
workers serially per round and cluster time-per-round = max over workers
(a barrier model), with straggler/backup-task injection. Kept for the
deterministic round-based experiments; consistency results in both
runtimes are REAL (facts re-validated against a single-worker oracle).

Failure injection reproduces §4.1.3: killed workers trigger coordinator
rebalance -> cache-reset dumps on survivors -> throughput drop larger than
the node loss (the paper's observed 57% vs 40%).
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
import warnings
from typing import Dict, Iterable, List, Optional, Set, Union

import numpy as np

from repro.configs.dod_etl import ETLConfig
from repro.core.cdc import ChangeLog, SourceDatabase
from repro.core.metrics import LatencyRecorder, percentiles_ms
from repro.core.pipeline import DODETLPipeline, StreamProcessorWorker
from repro.core.records import RecordBatch
from repro.durability.faults import (COMMIT_POST, HEARTBEAT_MISS,
                                     INGEST_FETCH, LOAD_PRE_COMMIT,
                                     REPARTITION_MID, TRANSFORM_DONE,
                                     InjectedCrash)
from repro.observability.health import build_cluster_health
from repro.runtime.control import (ControlConfig, ControlPlane, CreditLedger,
                                   QuiesceTimeout, QuiesceTimeoutWarning)


@dataclasses.dataclass
class RoundStats:
    round_idx: int
    records: int
    worker_wall_s: Dict[str, float]
    cluster_wall_s: float          # max worker time (barrier model)
    cache_redump_s: float = 0.0
    n_workers: int = 0

    @property
    def rate(self) -> float:
        return self.records / self.cluster_wall_s if self.cluster_wall_s else 0.0


class SimulatedCluster:
    def __init__(self, pipeline: DODETLPipeline, *,
                 straggler_prob: float = 0.0,
                 straggler_slowdown: float = 3.0,
                 backup_tasks: bool = True,
                 seed: int = 0):
        self.pipe = pipeline
        self.rng = np.random.default_rng(seed)
        self.straggler_prob = straggler_prob
        self.straggler_slowdown = straggler_slowdown
        self.backup_tasks = backup_tasks
        self.history: List[RoundStats] = []
        self.stragglers_mitigated = 0

    def run_round(self, max_records_per_partition: Optional[int] = None
                  ) -> RoundStats:
        pipe = self.pipe
        for w in pipe.workers:
            w.pump_master(pipe.master_topic_map["equipment"], w.equipment)
            w.pump_master(pipe.master_topic_map["quality"], w.quality)
        walls: Dict[str, float] = {}
        records = 0
        for w in pipe.workers:
            t0 = time.perf_counter()
            for topic in pipe.operational_topics:
                records += w.process_operational(topic,
                                                 max_records_per_partition)
            wall = time.perf_counter() - t0
            # straggler model: occasionally a worker runs slow (paper's
            # 'low latency' requirement -> mitigation via backup execution)
            if self.rng.random() < self.straggler_prob:
                slow = wall * self.straggler_slowdown
                if self.backup_tasks:
                    # speculative backup on the least-loaded peer: pay the
                    # duplicate work, bound the tail at ~2x median
                    wall = min(slow, 2.0 * wall + 1e-9)
                    self.stragglers_mitigated += 1
                else:
                    wall = slow
            walls[w.name] = wall
        stats = RoundStats(
            round_idx=len(self.history), records=records,
            worker_wall_s=walls,
            cluster_wall_s=max(walls.values()) if walls else 0.0,
            n_workers=len(pipe.workers))
        self.history.append(stats)
        return stats

    def fail_workers(self, names: List[str]) -> float:
        """Inject §4.1.3's mid-run failure. Returns cache re-dump seconds
        (charged to the next round's wall time)."""
        redump = self.pipe.fail_workers(names)
        if self.history:
            self.history[-1].cache_redump_s += redump
        return redump

    def scale_to(self, n_workers: int) -> float:
        """Elastic resize (paper §3.2 'cluster scales up or down')."""
        pipe = self.pipe
        cur = len(pipe.workers)
        if n_workers < cur:
            return self.fail_workers(
                [w.name for w in pipe.workers[n_workers:]])
        if n_workers > cur:
            return pipe.add_workers(n_workers - cur)
        return 0.0

    def throughput(self, last_n: int = 5) -> float:
        h = self.history[-last_n:]
        rec = sum(s.records for s in h)
        wall = sum(s.cluster_wall_s + s.cache_redump_s for s in h)
        return rec / wall if wall else 0.0


# ===================================================================== real
# concurrency below: the genuinely parallel runtime (ConcurrentCluster)

# shared with the serving layer so freshness and report staleness are the
# same estimator on the same clock (repro.core.metrics)
_percentiles_ms = percentiles_ms


@dataclasses.dataclass
class _Work:
    """Ingest -> transform hand-off: one coalesced fetch (uncommitted)."""
    topic: str
    batch: RecordBatch
    counts: Dict[int, int]


@dataclasses.dataclass
class _Transformed:
    """Transform -> load hand-off: a device-resident ``FactBlock`` awaiting
    the atomic load+commit. The transform stage never blocks on the
    dispatch — the block materializes to host in the LOAD stage (the
    step's single device sync), so device compute and the async D2H copy
    overlap this worker's load-side host work (queue commits, partition
    split, buffer accounting) instead of serializing behind it.

    ``batch``/``block`` carry only the transformable records; ``dead``
    (usually None) carries poison records the transform stage isolated —
    the load stage quarantines them to the worker's dead-letter buffer
    and still commits their offsets (quarantined == handled)."""
    topic: str
    batch: RecordBatch
    counts: Dict[int, int]
    block: object                   # repro.core.backend.FactBlock (or None
                                    # when every record in the batch was
                                    # poison)
    dead: object = None             # RecordBatch of quarantined records


@dataclasses.dataclass
class _Control:
    """Coordinator -> worker control-plane message (applied by the ingest
    stage at its loop head, never mid-fetch)."""
    kind: str                       # "revoke" | "grant" | "reroute"
    partitions: Set[int]
    ack: threading.Event = dataclasses.field(default_factory=threading.Event)
    fetched_at_ack: int = 0         # revoke: in-flight quiesce horizon
    redump_s: float = 0.0           # grant/reroute: cache-migration cost
    tables: tuple = ()              # reroute: incoming routing tables
    stats: object = None            # grant/reroute: CacheMigrationStats


class WorkerRuntime:
    """One Stream Processor node's executor: three stage threads (ingest,
    transform, load) around a ``StreamProcessorWorker``, decoupled by
    bounded hand-off queues.

      ingest    pumps master topics into the worker caches, then fetches
                operational partitions (advancing broker READ positions,
                committing nothing) and hands each coalesced batch off;
      transform one backend dispatch per hand-off batch (GIL released in
                the numeric core, so transforms of different workers
                genuinely overlap);
      load      the ONLY mutating stage: under the worker's commit lock it
                buffers late records, loads facts, commits offsets and
                records freshness samples — one atomic unit, so a kill
                (which takes the same lock) can never observe a record
                half-accounted.

    Retry of buffered late records runs in the load stage too (pop -> probe
    -> load -> re-buffer under the commit lock), preserving the same
    atomicity for the §3.2 unsynchronized-consistency path.
    """

    _QUEUE_POLL_S = 0.05

    def __init__(self, worker: StreamProcessorWorker, pipe: DODETLPipeline,
                 max_records_per_partition: Optional[int] = None):
        self.worker = worker
        self.pipe = pipe
        self.cap = max_records_per_partition
        depth = max(1, pipe.cfg.handoff_depth)
        self.transform_q: "queue_mod.Queue[_Work]" = queue_mod.Queue(depth)
        self.load_q: "queue_mod.Queue[_Transformed]" = queue_mod.Queue(depth)
        self.control: "queue_mod.Queue[_Control]" = queue_mod.Queue()
        self.commit_lock = threading.Lock()
        self.cache_lock = threading.Lock()
        self.stop = threading.Event()
        self.dead = False
        self.fetched = 0             # hand-offs produced (ingest thread)
        self.completed = 0           # hand-offs retired  (load thread)
        self.records_done = 0
        # record-level flow accounting, one writer per field: the ingest
        # stage bounds every fetch by the late buffer's *headroom*
        # (capacity - buffered - in-flight), so even a 100%-late cold-start
        # backlog can never overflow the buffer and drop records
        self.records_fetched = 0     # ingest thread
        self.records_retired = 0     # load thread
        self.retry_inflight = 0      # load thread: records popped by a
                                     # retry sweep, not yet re-buffered
        self.records_dropped_ingest = 0      # shutdown-path drops only
        self.records_dropped_transform = 0
        self.items_dropped_ingest = 0        # ditto, item granularity
        self.items_dropped_transform = 0
        self.latency = LatencyRecorder()
        # credit-based backpressure: ingest takes before every fetch,
        # load refunds at retire time. Non-blocking by construction.
        self.credits = CreditLedger(pipe.cfg.credit_capacity)
        # stage heartbeats (perf_counter of each loop's last iteration):
        # the control plane's failure-detection input. Plain dict writes
        # (GIL-atomic) — ages surface as pull-mode gauges below.
        self.hb: Dict[str, float] = {}
        self.started_at: Optional[float] = None
        self._threads: List[threading.Thread] = []
        # observability: spans go to the pipeline's tracer (NULL_TRACER by
        # default — zero-overhead seam); the runtime shares the worker's
        # metrics shard, registers its freshness reservoir there (one read
        # path, no second sample copy) and exposes queue depths as
        # pull-mode gauges the hot path never touches
        self.tracer = pipe.tracer
        shard = pipe.metrics.shard(worker.name)
        self.mshard = shard
        shard.register_histogram("freshness", self.latency)
        shard.gauge_fn("transform_q_depth", self.transform_q.qsize)
        shard.gauge_fn("load_q_depth", self.load_q.qsize)
        shard.gauge_fn("in_flight", self.in_flight)
        shard.gauge_fn("credits_available", lambda: self.credits.available)
        for stage in ("ingest", "transform", "load"):
            shard.gauge_fn(f"heartbeat_age.{stage}",
                           lambda s=stage: self.heartbeat_age(s))

    # ---------------------------------------------------------------- state
    @property
    def alive(self) -> bool:
        return bool(self._threads) and not self.dead and not self.stop.is_set()

    def in_flight(self) -> int:
        return (self.fetched - self.completed - self.items_dropped_ingest
                - self.items_dropped_transform)

    def beat(self, stage: str) -> None:
        """Stage-loop heartbeat: every loop iterates at poll cadence even
        when idle, so a silent stage is hung or dead, never just bored.
        Also a fault seam — a ``hang`` scheduled at ``heartbeat.miss``
        freezes whichever stage thread reaches the ordinal (the grey
        failure the supervisor exists to detect)."""
        self.hb[stage] = time.perf_counter()
        self.pipe.fault.trip(HEARTBEAT_MISS)

    def heartbeat_age(self, stage: str) -> float:
        t = self.hb.get(stage)
        return time.perf_counter() - t if t is not None else -1.0

    def start(self) -> None:
        self.started_at = time.perf_counter()
        for stage in ("ingest", "transform", "load"):
            self.hb[stage] = self.started_at
        for fn, tag in ((self._ingest_loop, "ingest"),
                        (self._transform_loop, "transform"),
                        (self._load_loop, "load")):
            t = threading.Thread(target=fn, daemon=True,
                                 name=f"{self.worker.name}.{tag}")
            t.start()
            self._threads.append(t)

    def join(self, timeout: float = 5.0) -> List[str]:
        """Join the stage threads within one shared ``timeout`` budget.
        Threads still alive afterwards are *wedged* (hung in a fetch, a
        dispatch, or a fault-injected freeze): their names are returned,
        a ``QuiesceTimeoutWarning`` is emitted and ``worker.join_timeouts``
        counts them — a stop that strands a thread must never read as a
        clean success. The thread list is cleared either way; a wedged
        daemon thread can only no-op from here (its runtime is flagged
        dead and its consumer group is fenced by forced eviction)."""
        deadline = time.perf_counter() + timeout
        wedged: List[str] = []
        for t in self._threads:
            t.join(max(0.0, deadline - time.perf_counter()))
            if t.is_alive():
                wedged.append(t.name)
        self._threads = []
        if wedged:
            self.mshard.counter("worker.join_timeouts").inc(len(wedged))
            warnings.warn(
                f"{self.worker.name}: stage thread(s) still alive after "
                f"{timeout:.1f}s join: {', '.join(wedged)}",
                QuiesceTimeoutWarning, stacklevel=2)
        return wedged

    # ---------------------------------------------------------- stage plumbing
    def _put(self, q: "queue_mod.Queue", item) -> bool:
        while not self.stop.is_set():
            try:
                q.put(item, timeout=self._QUEUE_POLL_S)
                return True
            except queue_mod.Full:
                continue
        return False

    def _get(self, q: "queue_mod.Queue"):
        try:
            return q.get(timeout=self._QUEUE_POLL_S)
        except queue_mod.Empty:
            return None

    # ----------------------------------------------------------- stage: ingest
    def _apply_control(self) -> None:
        while True:
            try:
                msg = self.control.get_nowait()
            except queue_mod.Empty:
                return
            w = self.worker
            nbk = self.pipe.cfg.n_business_keys
            if msg.kind == "ping":
                # supervisor liveness probe: an ack proves the ingest
                # loop still drains controls (heartbeat freshness proves
                # the rest — see ControlPlane._supervise)
                msg.ack.set()
            elif msg.kind == "revoke":
                w.partitions = [p for p in w.partitions
                                if p not in msg.partitions]
                msg.fetched_at_ack = self.fetched
                msg.ack.set()
            elif msg.kind == "grant":
                with self.cache_lock:
                    # SURGICAL cache migration (replaces the reset-
                    # everything trigger): retain rows for still-owned
                    # keys, dump only the gained key ranges. In-flight
                    # work for just-revoked partitions may still probe the
                    # cache, so moved-away rows are dropped lazily — here,
                    # at the next key-set change, never mid-revoke.
                    prev = w.assigned_business_keys(nbk)
                    w.partitions = sorted(set(w.partitions) | msg.partitions)
                    msg.stats = w.migrate_caches(
                        self.pipe.master_topic_map, nbk, prev)
                    msg.redump_s = msg.stats.dump_s
                msg.ack.set()
            elif msg.kind == "reroute":
                with self.cache_lock:
                    # routing-epoch migration, phase 1: grow the key
                    # filter to the union of live + incoming epochs and
                    # migrate the caches surgically BEFORE the coordinator
                    # switches publishers to the new epoch, so no record
                    # ever arrives at a worker missing its master rows
                    prev = w.assigned_business_keys(nbk)
                    w.set_pending_tables(msg.tables)
                    msg.stats = w.migrate_caches(
                        self.pipe.master_topic_map, nbk, prev)
                    msg.redump_s = msg.stats.dump_s
                msg.ack.set()

    def _buffer_headroom(self) -> int:
        """Records we may still fetch without risking a late-buffer drop
        even if EVERY in-flight record turns out late."""
        in_flight = (self.records_fetched - self.records_retired
                     - self.records_dropped_ingest
                     - self.records_dropped_transform)
        return (self.pipe.cfg.buffer_capacity - len(self.worker.buffer)
                - in_flight - self.retry_inflight)

    def _ingest_loop(self) -> None:
        # InjectedCrash (a BaseException) kills just this stage thread —
        # the in-process analogue of the node dying mid-stage; the drill
        # waits on fault.tripped and abandons the cluster
        try:
            self._ingest_body()
        except InjectedCrash:
            return

    def _ingest_body(self) -> None:
        pipe, w = self.pipe, self.worker
        while not self.stop.is_set():
            self.beat("ingest")
            self._apply_control()
            with self.cache_lock:
                w.pump_master(pipe.master_topic_map["equipment"], w.equipment)
                w.pump_master(pipe.master_topic_map["quality"], w.quality)
            got = 0
            for topic in pipe.operational_topics:
                if self.stop.is_set():
                    break
                # backpressure, two ledgers: a fetch may return up to cap
                # records from EVERY owned partition, so the per-partition
                # cap must keep the worst case within the late buffer's
                # headroom — flooring it at 1 here would over-fetch and
                # let a 100%-late batch overflow the buffer (dropping
                # committed records for good). On top of that sits the
                # explicit credit ledger: credits are TAKEN here (never
                # blocking) and refunded by the load stage at retire time,
                # so a stalled downstream drains the ledger and ingest
                # simply stops fetching (and the extractor backs off).
                nparts = max(1, len(w.partitions))
                cap = self._buffer_headroom() // nparts
                if cap < 1:
                    break            # let retries drain the buffer first
                if self.cap is not None:
                    cap = min(cap, self.cap)
                grant = self.credits.take(cap * nparts)
                per_cap = grant // nparts
                if per_cap < 1:
                    self.credits.refund(grant)
                    break            # starved: wait for load-side refunds
                with self.tracer.span("ingest.fetch") as sp:
                    batch, counts = w.fetch_operational(topic, per_cap)
                    if not counts:
                        sp.drop()        # keep idle polling out of traces
                    else:
                        sp.put("records", len(batch))
                self.credits.refund(grant - len(batch))  # unused grant
                if counts:
                    self.records_fetched += len(batch)
                    pipe.fault.trip(INGEST_FETCH)   # fetched, uncommitted
                    self.fetched += 1
                    if not self._put(self.transform_q,
                                     _Work(topic, batch, counts)):
                        self.items_dropped_ingest += 1   # shutdown only
                        self.records_dropped_ingest += len(batch)
                        self.credits.refund(len(batch))
                    got += len(batch)
            if not got:
                time.sleep(pipe.cfg.idle_backoff_s)

    # -------------------------------------------------------- stage: transform
    def _transform_loop(self) -> None:
        try:
            self._transform_body()
        except InjectedCrash:
            return

    def _transform_body(self) -> None:
        device = self.worker.backend.device
        while True:
            self.beat("transform")
            item = self._get(self.transform_q)
            if item is None:
                if self.stop.is_set():
                    return
                continue
            # hold the cache lock only long enough to pin an immutable
            # snapshot; the dispatch itself runs lock-free, so the ingest
            # stage's master pumps overlap the numeric core instead of
            # queueing behind every dispatch
            with self.tracer.span("transform.dispatch") as sp:
                with self.cache_lock:
                    eq = self.worker.equipment.snapshot_view(device)
                    qu = self.worker.quality.snapshot_view(device)
                good, block, dead = self._transform_quarantine(
                    item.batch, eq, qu)
                sp.put("records", len(item.batch))
            self.pipe.fault.trip(TRANSFORM_DONE)   # transformed, unloaded
            if not self._put(self.load_q,
                             _Transformed(item.topic, good, item.counts,
                                          block, dead=dead)):
                self.items_dropped_transform += 1        # shutdown only
                self.records_dropped_transform += len(item.batch)
                self.credits.refund(len(item.batch))

    def _transform_quarantine(self, batch: RecordBatch, eq, qu):
        """ONE fused transform+rollup dispatch, NO host sync: the block
        is handed to the load stage device-resident, with the D2H copy
        enqueued asynchronously behind the compute.

        Poison handling: a transform that raises a plain ``Exception``
        (never ``InjectedCrash`` — drills must still kill the thread) is
        re-probed by bisection to isolate the records that
        deterministically fail. Good records keep their original order
        and proceed; poison records ride the hand-off in ``dead`` and
        are quarantined (offsets still committed) by the load stage —
        the worker never crash-loops on a bad record. Returns
        ``(good_batch, block_or_None, dead_batch_or_None)``."""
        tf = self.worker.transformer
        try:
            return batch, tf.transform_block(batch, eq, qu
                                             ).start_host_copy(), None
        except InjectedCrash:
            raise
        except Exception:
            pass
        good_idx: List[np.ndarray] = []
        dead_idx: List[np.ndarray] = []
        stack = [np.arange(len(batch))]
        while stack:
            idx = stack.pop()
            try:
                tf.transform_block(batch.take(idx), eq, qu)   # probe
                good_idx.append(idx)
            except InjectedCrash:
                raise
            except Exception:
                if len(idx) == 1:
                    dead_idx.append(idx)
                else:
                    mid = len(idx) // 2
                    stack.append(idx[mid:])
                    stack.append(idx[:mid])
        gsel = (np.sort(np.concatenate(good_idx)) if good_idx
                else np.zeros(0, np.int64))
        dsel = (np.sort(np.concatenate(dead_idx)) if dead_idx
                else np.zeros(0, np.int64))
        good = batch.take(gsel)
        dead = batch.take(dsel)
        block = (tf.transform_block(good, eq, qu).start_host_copy()
                 if len(good) else None)
        return good, block, (dead if len(dead) else None)

    # ------------------------------------------------------------- stage: load
    def _load_and_record(self, batch: RecordBatch, block) -> int:
        """Commit-lock-held helper: materialize the device block (the
        step's ONE host↔device round trip — the async copy started at
        dispatch time has usually landed by now), buffer lates, load
        facts + fused rollup, sample freshness. Returns records loaded."""
        w = self.worker
        facts, found = block.to_host()
        w.buffer.push(batch.filter(~found))
        good = facts[found]
        # join-level cache accounting (same counters the sequential worker
        # feeds): hits joined now, misses went to the late buffer. Counted
        # from the already-materialized host mask — no extra device sync.
        w._c_hits.inc(len(good))
        w._c_misses.inc(len(batch) - len(good))
        if not len(good):
            return 0
        log = self.pipe.source.log
        ev = log.event_times(batch.lsn[found])
        # event times ride into the warehouse so an attached serving layer
        # can stamp per-record report staleness on the same CDC clock
        w.warehouse.load_partitioned(
            good, self.pipe.cfg.n_partitions, event_times=ev,
            rollup=block.rollup_host(),
            routing_epoch=self.pipe.current_routing().epoch)
        self.latency.add(log.clock() - ev)
        self.records_done += len(good)
        return len(good)

    def _retry_sweep(self) -> None:
        w = self.worker
        with self.commit_lock:
            if self.dead or not len(w.buffer):
                return
            # publish the pop to the ingest stage's headroom accounting
            # BEFORE shrinking the buffer, so a concurrent fetch can't
            # claim the slots these records still occupy logically
            self.retry_inflight = len(w.buffer)
            limit = (self.cap * max(1, len(w.partitions))
                     if self.cap else None)
            ready = w.buffer.pop_ready(w.transformer.watermark(), limit)
            if len(ready):
                device = w.backend.device
                with self.cache_lock:
                    eq = w.equipment.snapshot_view(device)
                    qu = w.quality.snapshot_view(device)
                # the retry path meets poison records too (a poison
                # record that was merely *late* first) — same quarantine
                good, block, dead = self._transform_quarantine(
                    ready, eq, qu)
                if dead is not None:
                    w.dead_letter.push(dead, reason="transform-poison")
                    w._c_dead.inc(len(dead))
                if block is not None:
                    self._load_and_record(good, block)
            self.retry_inflight = 0

    def _load_loop(self) -> None:
        try:
            self._load_body()
        except InjectedCrash:
            return

    def _load_body(self) -> None:
        while True:
            self.beat("load")
            item = self._get(self.load_q)
            if item is None:
                if self.stop.is_set() and self.transform_q.empty():
                    return
                self._retry_sweep()       # idle: drain watermark-ready lates
                continue
            n_dead = len(item.dead) if item.dead is not None else 0
            n_total = len(item.batch) + n_dead
            with self.commit_lock:
                if not self.dead:
                    with self.tracer.span("load.commit") as sp:
                        done = (self._load_and_record(item.batch, item.block)
                                if item.block is not None else 0)
                        if item.dead is not None:
                            # poison quarantine: park the records, count
                            # them, and STILL commit their offsets below
                            # — a quarantined record is handled, never
                            # replayed into the same crash
                            self.worker.dead_letter.push(
                                item.dead, reason="transform-poison")
                            self.worker._c_dead.inc(n_dead)
                        # loaded, offsets NOT committed — the window where
                        # a crash leaves at-least-once exposure that
                        # recovery's warehouse rollback turns back into
                        # exactly-once
                        self.pipe.fault.trip(LOAD_PRE_COMMIT)
                        for p, c in item.counts.items():
                            self.worker.queue.commit(self.worker.group,
                                                     item.topic, p, c)
                        self.pipe.fault.trip(COMMIT_POST)
                        sp.put("records", done)
                # retire AFTER the lates are buffered: between push and
                # retirement the records are double-counted (buffer AND
                # in-flight), which errs on the safe side of headroom
                self.records_retired += n_total
                # completed is bumped LAST, still under the lock: a
                # coordinator quiescing on it (under this lock) is
                # guaranteed to also observe the item's offset commits —
                # bumping it first let a rebalance read a stale committed
                # offset and replay a whole partition at its new owner
                self.completed += 1
            # refund the full fetch (lates/quarantined included: they
            # left the in-flight window — lates are buffer-bounded, not
            # credit-bounded)
            self.credits.refund(n_total)
            self._retry_sweep()


class ConcurrentCluster:
    """Coordinator + concurrent worker runtimes (the paper's §3.1 cluster,
    executed for real). Owns the ``PartitionAssignment``; rebalances and
    failovers are incremental — only moved partitions quiesce, healthy
    workers never stop processing their retained partitions.

    Usage::

        pipe = DODETLPipeline(cfg, source, n_workers=4)
        cluster = ConcurrentCluster(pipe)     # poll_cdc=True: extraction
        cluster.start()                       # thread tails the change log
        ... feed source / wait ...
        cluster.run_until_idle()
        report = cluster.report()             # throughput + p50/p95/p99
        cluster.stop_all()
    """

    def __init__(self, pipe: DODETLPipeline, *,
                 max_records_per_partition: Optional[int] = None,
                 poll_cdc: bool = True, serving=None,
                 recovery=None, checkpoint_every_s: Optional[float] = None,
                 control: Union[None, bool, ControlConfig] = None):
        self.pipe = pipe
        self.cap = max_records_per_partition
        self.poll_cdc = poll_cdc
        # coordinator actions (failover, eviction, resize, repartition)
        # serialize here: the autonomous control plane and user calls may
        # now race, and the rebalance machinery assumes one driver.
        # Reentrant — scale_to legitimately nests fail_workers.
        self._coord_lock = threading.RLock()
        # self-healing control plane (supervision + autonomous scaling):
        # opt-in via `control=True` (defaults) or a ControlConfig
        self.control: Optional[ControlPlane] = None
        if control:
            self.control = ControlPlane(
                self, control if isinstance(control, ControlConfig)
                else ControlConfig())
        # durability: a RecoveryCoordinator makes `checkpoint()` journal
        # consistent snapshots; `checkpoint_every_s` adds a periodic
        # checkpointer thread alongside the stage threads
        self.recovery = recovery
        self.checkpoint_every_s = checkpoint_every_s
        self._ckpt_thread: Optional[threading.Thread] = None
        self._stop_ckpt = threading.Event()
        # optional BI serving stage: a MaterializedViewEngine (or a
        # ReportServer / BatchedReportServer wrapping one) whose
        # maintenance thread runs with the cluster; worker load stages
        # publish fact deltas to it via the warehouse hook, and cluster
        # reports include its epoch/staleness (+ batch-front stats when a
        # batching front is attached)
        self.serving_front = serving if hasattr(serving, "submit") else None
        self.serving = getattr(serving, "engine", serving)
        if self.serving is not None:
            pipe.warehouse.attach_serving(self.serving)
            # serving joins the pipeline's observability plane: fold/query
            # spans land on the same tracer, the staleness reservoir on
            # the pipeline registry's "serving" shard
            self.serving.tracer = pipe.tracer
            self.serving.attach_metrics(pipe.metrics.shard("serving"))
            # sharded serving plane (ShardedViewEngine): align shard
            # ownership with the pipeline's live routing epoch and give
            # the warehouse its per-shard sub-logs; repartition() keeps
            # both in sync via _reown_shard_plane
            if hasattr(self.serving, "reown"):
                self.serving.reown(pipe.current_routing())
                pipe.warehouse.attach_shards(self.serving.ownership)
        self.runtimes: Dict[str, WorkerRuntime] = {
            w.name: WorkerRuntime(w, pipe, max_records_per_partition)
            for w in pipe.workers}
        self.assignment = pipe.assignment
        self.redump_s_total = 0.0
        self.last_rebalance_stats = None     # CacheMigrationStats of the
        self.last_migration: Dict = {}       # last grant wave / repartition
        self._extract_thread: Optional[threading.Thread] = None
        self._stop_extract = threading.Event()
        self._next_worker_idx = len(pipe.workers)
        self._t_start: Optional[float] = None

    # --------------------------------------------------------------- lifecycle
    def start(self) -> None:
        self._t_start = time.perf_counter()
        if self.serving is not None:
            self.serving.start()         # view-maintenance stage
        if self.serving_front is not None:
            self.serving_front.start()   # batched-query admission front
        for rt in self.runtimes.values():
            rt.start()
        if self.poll_cdc:
            self._extract_thread = threading.Thread(
                target=self._extract_loop, daemon=True, name="cdc.extract")
            self._extract_thread.start()
        if self.recovery is not None and self.checkpoint_every_s:
            self._ckpt_thread = threading.Thread(
                target=self._ckpt_loop, daemon=True, name="durability.ckpt")
            self._ckpt_thread.start()
        if self.control is not None:
            self.control.start()

    def _ckpt_loop(self) -> None:
        while not self._stop_ckpt.wait(self.checkpoint_every_s):
            try:
                self.checkpoint()
            except InjectedCrash:
                return               # checkpoint-write crash drill

    def checkpoint(self) -> Optional[int]:
        """Journal one consistent snapshot of the whole data plane (see
        ``RecoveryCoordinator.capture``). The live workers' commit locks
        are passed in name order — a fixed acquisition order, so a
        concurrent rebalance (which takes one lock at a time) can never
        deadlock against a capture. No-op once a fault has tripped: a
        dead process journals nothing on the way down."""
        if self.recovery is None or self.pipe.fault.tripped.is_set():
            return None
        locks = [rt.commit_lock for _, rt in sorted(self.runtimes.items())
                 if not rt.dead]
        with self.pipe.tracer.span("checkpoint.step") as sp:
            step = self.recovery.checkpoint(self.pipe, engine=self.serving,
                                            extra_locks=locks)
            sp.put("step", step)
        return step

    def _credits_exhausted(self) -> bool:
        """True when EVERY live worker's credit ledger is drained — the
        end-to-end backpressure signal: downstream has stopped refunding,
        so extraction publishing more would only grow broker backlog."""
        rts = [rt for rt in list(self.runtimes.values()) if not rt.dead]
        return bool(rts) and all(rt.credits.exhausted() for rt in rts)

    def _extract_loop(self) -> None:
        tracker = self.pipe.tracker
        idle = self.pipe.cfg.idle_backoff_s
        while not self._stop_extract.is_set():
            if self._credits_exhausted():
                time.sleep(0.005)        # stalled downstream throttles
                continue                 # extraction, not just fetching
            if tracker.poll_all() == 0:
                time.sleep(idle)

    def stop_all(self) -> None:
        if self.control is not None:
            self.control.stop()    # before the heartbeats it watches stop
        self._stop_extract.set()
        self._stop_ckpt.set()
        for rt in self.runtimes.values():
            rt.stop.set()
        if self._extract_thread is not None:
            self._extract_thread.join(5.0)
            self._extract_thread = None
        if self._ckpt_thread is not None:
            self._ckpt_thread.join(5.0)
            self._ckpt_thread = None
        for rt in self.runtimes.values():
            rt.join()
        if self.serving_front is not None:
            self.serving_front.stop()    # drains admitted queries first
        if self.serving is not None:
            self.serving.stop()          # folds the remaining delta backlog

    def abandon(self) -> None:
        """Crash-drill teardown: stop every thread WITHOUT the graceful
        drain ``stop_all`` performs — no queued hand-off is loaded, no
        offset committed, no delta backlog folded, no checkpoint written.
        What a kill -9 leaves behind, minus the process exit: the journal
        and broker/warehouse objects are simply abandoned, and recovery
        starts from fresh objects + the journal (tests assert the result
        matches an uninterrupted run byte-for-byte)."""
        if self.control is not None:
            self.control.stop()
        self._stop_extract.set()
        self._stop_ckpt.set()
        for rt in self.runtimes.values():
            with rt.commit_lock:     # atomic vs an in-progress load+commit
                rt.dead = True       # load stage loads/commits nothing more
            rt.stop.set()
        if self._extract_thread is not None:
            self._extract_thread.join(5.0)
            self._extract_thread = None
        if self._ckpt_thread is not None:
            self._ckpt_thread.join(5.0)
            self._ckpt_thread = None
        for rt in self.runtimes.values():
            rt.join()
        if self.serving_front is not None:
            self.serving_front.stop()
        if self.serving is not None:
            self.serving.abort()         # stop folding, KEEP the backlog

    # ---------------------------------------------------------------- metrics
    def health(self) -> Dict:
        """One consistent ``ClusterHealth`` snapshot — per-worker
        throughput/backlog, freshness & staleness percentiles, commit lag
        per topic/partition, cache retention, checkpoint age, merged
        counters. Lock-free and safe to poll while rebalances,
        repartitions and checkpoints run (see observability.health)."""
        return build_cluster_health(self)

    def alive_workers(self) -> List[str]:
        return [n for n, rt in self.runtimes.items() if not rt.dead]

    def records_done(self) -> int:
        return sum(rt.records_done for rt in self.runtimes.values())

    def freshness(self, drain: bool = False) -> Dict[str, float]:
        merged = [rt.latency.merged(drain) for rt in self.runtimes.values()]
        return _percentiles_ms(np.concatenate(merged) if merged
                               else np.zeros(0))

    def report(self) -> Dict[str, float]:
        wall = (time.perf_counter() - self._t_start) if self._t_start else 0.0
        done = self.records_done()
        out = {"records": done, "wall_s": round(wall, 4),
               "records_s": round(done / wall) if wall > 0 else 0,
               "n_workers": len(self.alive_workers()),
               "redump_s": round(self.redump_s_total, 4)}
        out.update(self.freshness())
        if self.serving is not None:
            out["serving"] = self.serving.report()
            if self.serving_front is not None:
                out["serving"].update(
                    {f"batch_{k}": v
                     for k, v in self.serving_front.stats().items()})
        return out

    # ------------------------------------------------------------ idle waiting
    def _operational_lag(self) -> int:
        q = self.pipe.queue
        lag = 0
        group_of = {n: rt.worker.group for n, rt in self.runtimes.items()}
        for topic in self.pipe.operational_topics:
            hw = [q.topics[topic].high_watermark(p)
                  for p in range(q.topics[topic].cfg.n_partitions)]
            for p, owner in self.assignment.assignment.items():
                lag += max(0, hw[p] - q.committed(group_of[owner], topic, p))
        return lag

    def _extraction_lag(self) -> int:
        log = self.pipe.source.log
        return sum(max(0, log.next_lsn - l.offset)
                   for l in self.pipe.tracker.listeners)

    def _idle_buffered(self) -> Optional[int]:
        """None while any work is in flight; otherwise the total number of
        late-buffered records observed at a provably quiescent instant.
        Taking each worker's commit lock excludes the one blind spot plain
        counters have: a retry sweep that has popped buffered records but
        not yet loaded them."""
        if self.poll_cdc and self._extraction_lag() > 0:
            return None
        buffered = 0
        for rt in self.runtimes.values():
            if rt.dead:
                continue
            with rt.commit_lock:
                if rt.in_flight() > 0 or not rt.transform_q.empty() \
                        or not rt.load_q.empty():
                    return None
                buffered += len(rt.worker.buffer)
        if self._operational_lag() != 0:
            return None
        return buffered

    def idle(self) -> bool:
        """True when there is provably nothing left to do right now."""
        return self._idle_buffered() is not None

    def run_until_idle(self, timeout: float = 120.0,
                       stall_s: float = 2.0) -> int:
        """Block until the stream is drained (lag 0, no in-flight work,
        empty late buffers) or no progress has been made for ``stall_s``
        (e.g. buffered records whose master data never arrives — the
        paper's watermark semantics say those WAIT, so a stall is a clean
        exit, not an error). Returns total records loaded."""
        t0 = time.perf_counter()
        last = (-1, -1)
        last_change = t0
        while time.perf_counter() - t0 < timeout:
            buffered = self._idle_buffered()
            state = (self.records_done(), buffered)
            if state != last:
                last, last_change = state, time.perf_counter()
            if buffered is not None:
                if buffered == 0:
                    return self.records_done()
                if time.perf_counter() - last_change > stall_s:
                    return self.records_done()   # watermark-stalled lates
            time.sleep(0.01)
        return self.records_done()

    # ----------------------------------------------------- coordinator actions
    def _quiesce(self, rt: WorkerRuntime, horizon: int,
                 timeout: float = 10.0) -> None:
        """Wait until every hand-off fetched before ``horizon`` has retired.
        The worker keeps processing; only the coordinator waits. Reading
        ``completed`` under the worker's commit lock guarantees the retired
        items' offset commits are visible before the coordinator moves on
        to the offset transfer."""
        t0 = time.perf_counter()
        while not rt.dead:
            with rt.commit_lock:
                done = (rt.completed + rt.items_dropped_ingest
                        + rt.items_dropped_transform)
            if done >= horizon:
                return
            if time.perf_counter() - t0 > timeout:
                raise QuiesceTimeout(
                    f"quiesce timeout for {rt.worker.name}")
            time.sleep(0.002)

    def _rebalance_to(self, alive: List[str],
                      weights: Optional[np.ndarray] = None) -> float:
        """Incremental rebalance: revoke moved partitions from their live
        owners, quiesce ONLY those workers' in-flight windows, transfer
        committed offsets, then grant — which fires the §3.2 cache trigger
        on the new owners, now SURGICAL: survivors retain rows for keys
        they keep and dump only the gained ranges. ``weights`` (per-
        partition observed load) makes the sticky LPT assignment balance
        load, not just partition counts. Healthy workers never stop
        consuming the partitions they keep."""
        pipe = self.pipe
        with pipe.tracer.span("repartition.rebalance") as sp:
            redump = self._rebalance_body(alive, weights)
            sp.put("workers", len(alive))
        pipe.metrics.shard("coordinator").counter(
            "pipeline.rebalances").inc()
        return redump

    def _rebalance_body(self, alive: List[str],
                        weights: Optional[np.ndarray] = None) -> float:
        pipe = self.pipe
        old_owner = dict(self.assignment.assignment)
        old_group = {n: rt.worker.group for n, rt in self.runtimes.items()}
        self.assignment.rebalance(alive, weights)
        moved: Dict[str, List[int]] = {}
        grants: Dict[str, List[int]] = {}
        for p, new_w in self.assignment.assignment.items():
            ow = old_owner.get(p)
            if ow == new_w:
                continue
            if ow is not None:
                moved.setdefault(ow, []).append(p)
            grants.setdefault(new_w, []).append(p)

        # phase 1: revoke from live old owners, quiesce their in-flight work
        pending = []
        for ow, parts in moved.items():
            rt = self.runtimes.get(ow)
            if rt is None or rt.dead:
                continue
            msg = _Control("revoke", set(parts))
            rt.control.put(msg)
            pending.append((rt, msg))
        for rt, msg in pending:
            if not msg.ack.wait(10.0):
                raise QuiesceTimeout(
                    f"revoke ack timeout for {rt.worker.name}")
            self._quiesce(rt, msg.fetched_at_ack)

        # phase 2: exactly-once offset handoff for every moved partition
        q = pipe.queue
        for p, new_w in self.assignment.assignment.items():
            ow = old_owner.get(p)
            if ow is None or ow == new_w:
                continue
            og = old_group.get(ow)
            ng = self.runtimes[new_w].worker.group
            for topic in pipe.operational_topics:
                committed = q.committed(og, topic, p)
                own = q.committed(ng, topic, p)
                if committed > own:
                    q.commit(ng, topic, p, committed - own)
                q.rewind(og, topic, p)    # abandon the old read-ahead

        # phase 3: grant (surgical cache migration on changed key sets)
        from repro.core.pipeline import CacheMigrationStats
        redump = 0.0
        stats = CacheMigrationStats()
        pending = []
        for nw, parts in grants.items():
            msg = _Control("grant", set(parts))
            self.runtimes[nw].control.put(msg)
            pending.append((self.runtimes[nw], msg))
        for rt, msg in pending:
            if not msg.ack.wait(10.0):
                raise QuiesceTimeout(
                    f"grant ack timeout for {rt.worker.name}")
            redump += msg.redump_s
            if msg.stats is not None:
                stats = stats.merge(msg.stats)
        self.redump_s_total += redump
        self.last_rebalance_stats = stats
        self._redistribute_buffers()
        return redump

    def _redistribute_buffers(self) -> None:
        """Re-home buffered late records to their partitions' CURRENT
        owners under the CURRENT routing epoch (the paper's replicated
        buffer store makes them reachable by any worker). Without this, a
        record buffered by a worker that then loses the record's partition
        — or whose business key was routed away by an epoch change —
        would starve forever: its probes run against a cache that no
        longer holds the record's business keys."""
        from repro.core.partitioning import isin_sorted
        orphans: List[RecordBatch] = []
        for rt in self.runtimes.values():
            if rt.dead:
                continue
            with rt.commit_lock:
                held = rt.worker.buffer.drain()
            if len(held):
                orphans.append(held)
        if not orphans:
            return
        merged = RecordBatch.concat(orphans)
        parts = self.pipe.current_routing().partition_of(
            merged.business_key).astype(np.int64)
        for name, rt in self.runtimes.items():
            if rt.dead:
                continue
            owned = np.asarray(sorted(
                p for p, w in self.assignment.assignment.items()
                if w == name), np.int64)
            if not len(owned):
                continue
            mine = merged.filter(isin_sorted(owned, parts))
            if len(mine):
                with rt.commit_lock:
                    rt.worker.buffer.push(mine)

    def fail_workers(self, names: Iterable[str]) -> float:
        """§4.1.3 failure injection under load: fail-stop the named workers
        (their consumed-but-uncommitted hand-offs are discarded — the broker
        re-serves those records to the partitions' new owners from the
        committed offsets), reassign their partitions incrementally, adopt
        their replicated late buffers. Returns cache re-dump seconds."""
        return self._remove_workers(list(names), forced=False)

    def evict_workers(self, names: Iterable[str], *,
                      lock_timeout: float = 1.0,
                      join_timeout: float = 2.0) -> float:
        """Forced eviction for hung/straggler workers (the control
        plane's confirmed-failure path). Unlike ``fail_workers`` it must
        not block on the victim: the commit lock is taken with a timeout
        (a wedged load stage may never release it), the stage threads
        get a bounded join (wedged ones are surfaced by
        ``WorkerRuntime.join`` and left to no-op as daemons), and the
        victim's consumer group is FENCED at the broker so a zombie
        thread that wakes later cannot move offsets that now belong to a
        survivor. Returns cache re-dump seconds."""
        return self._remove_workers(list(names), forced=True,
                                    lock_timeout=lock_timeout,
                                    join_timeout=join_timeout)

    def _remove_workers(self, names: List[str], *, forced: bool,
                        lock_timeout: float = 1.0,
                        join_timeout: float = 2.0) -> float:
        with self._coord_lock:
            dead_rts = []
            for n in names:
                rt = self.runtimes[n]
                if forced:
                    # hang-tolerant: a load stage wedged INSIDE its
                    # commit critical section would deadlock a plain
                    # `with`; flag the runtime dead regardless (a bool
                    # write is GIL-atomic) — the group fence below keeps
                    # any zombie commit out either way
                    got = rt.commit_lock.acquire(timeout=lock_timeout)
                    rt.dead = True
                    if got:
                        rt.commit_lock.release()
                else:
                    with rt.commit_lock:   # atomic vs the load stage
                        rt.dead = True
                rt.stop.set()
                dead_rts.append(rt)
            for rt in dead_rts:
                if forced:
                    rt.join(join_timeout)
                    self.pipe.queue.fence_group(rt.worker.group)
                else:
                    rt.join()
            alive = [n for n in self.runtimes if not self.runtimes[n].dead]
            if not alive:
                raise RuntimeError("all workers failed")
            self.pipe.workers = [w for w in self.pipe.workers
                                 if w.name not in names]
            # replicated-buffer adoption: a survivor inherits the dead
            # workers' late records before the rebalance; `_rebalance_to`
            # then re-homes every buffered record to its partition's new
            # owner (only committed records ever enter a buffer, so this
            # cannot duplicate anything the broker will re-serve)
            target = self.runtimes[alive[0]]
            for rt in dead_rts:
                orphan = rt.worker.buffer.drain()
                if len(orphan):
                    with target.commit_lock:
                        target.worker.buffer.push(orphan)
            return self._rebalance_to(alive)

    def _spawn_worker(self) -> str:
        """Create + start one fresh worker runtime (no partitions yet —
        the caller rebalances). The runtimes dict is replaced, not
        mutated, so lock-free iterators (health polls, idle checks)
        never observe a resize mid-iteration."""
        name = f"w{self._next_worker_idx}"
        self._next_worker_idx += 1
        w = self.pipe._new_worker(
            name, self.pipe.workers[0].transformer.join_depth
            if self.pipe.workers else 1)
        w.partitions = []
        self.pipe.workers.append(w)
        rt = WorkerRuntime(w, self.pipe, self.cap)
        self.runtimes = {**self.runtimes, name: rt}
        if self._t_start is not None:
            rt.start()
        return name

    def scale_to(self, n_workers: int) -> float:
        """Elastic resize (paper §3.2 'cluster scales up or down') without
        stopping the running stream."""
        with self._coord_lock:
            alive = self.alive_workers()
            if n_workers < len(alive):
                return self.fail_workers(alive[n_workers:])
            if n_workers == len(alive):
                return 0.0
            new_names = [self._spawn_worker()
                         for _ in range(n_workers - len(alive))]
            return self._rebalance_to(alive + new_names)

    def replace_worker(self, name: str, *,
                       lock_timeout: float = 1.0,
                       join_timeout: float = 2.0) -> str:
        """Supervised restart: forcibly evict ``name`` and bring up a
        fresh replacement in the SAME rebalance wave, so the grant path
        re-hydrates the newcomer (cache dump from the compacted master
        topics sets its watermarks; `_remove_workers` hands it — or a
        survivor — the evicted buffer, and `_redistribute_buffers`
        re-homes every late record). Spawning before evicting also
        keeps the last-worker case legal: the rebalance always has a
        live grant target. Returns the replacement's name."""
        with self._coord_lock:
            new_name = self._spawn_worker()
            self._remove_workers([name], forced=True,
                                 lock_timeout=lock_timeout,
                                 join_timeout=join_timeout)
            return new_name

    # -------------------------------------------------- adaptive repartition
    def retire_epochs(self) -> bool:
        """Retire routing epochs whose records are fully committed; when
        any retire, re-home buffered lates so none starves at a worker
        about to release the retired epoch's key ranges."""
        pipe = self.pipe
        group_of = {n: rt.worker.group for n, rt in self.runtimes.items()}
        retired = False
        for t in pipe.operational_topics:
            committed = {
                p: pipe.queue.committed(group_of[owner], t, p)
                for p, owner in self.assignment.assignment.items()
                if owner in group_of}
            retired |= pipe.queue.topics[t].retire_epochs(committed)
        if retired:
            self._redistribute_buffers()
        return retired

    def _initial_cache_rows(self) -> int:
        """Pre-migration cache rows across live workers — the retention
        baseline (see ``pipeline.migration_summary``)."""
        return sum(rt.worker.equipment.n_rows + rt.worker.quality.n_rows
                   for rt in self.runtimes.values() if not rt.dead)

    def _reroute_all(self, new_table):
        """Phase 1+2 of an epoch migration: every live worker acks a
        ``reroute`` control (key filter grown to live∪incoming epochs,
        caches migrated surgically) BEFORE publishers switch to the new
        epoch. Returns the merged migration stats."""
        from repro.core.pipeline import CacheMigrationStats
        pipe = self.pipe
        stats = CacheMigrationStats()
        with pipe.tracer.span("repartition.prepare") as sp:
            pending = []
            for name, rt in self.runtimes.items():
                if rt.dead:
                    continue
                msg = _Control("reroute", set(), tables=(new_table,))
                rt.control.put(msg)
                pending.append((rt, msg))
            for rt, msg in pending:
                if not msg.ack.wait(10.0):
                    raise QuiesceTimeout(
                        f"reroute ack timeout for {rt.worker.name}")
                stats = stats.merge(msg.stats)
            sp.put("workers", len(pending))
        self.redump_s_total += stats.dump_s
        with pipe.tracer.span("repartition.epoch_switch") as sp:
            for t in pipe.operational_topics:
                pipe.queue.topics[t].set_routing(new_table)
            sp.put("epoch", new_table.epoch)
        self._reown_shard_plane(new_table)
        return stats

    def _reown_shard_plane(self, new_table) -> None:
        """Sharded serving plane: remap view-segment and warehouse-row
        shard ownership to the new routing epoch, surgically (only moved
        segments/chunks migrate — the mesh twin of the workers' surgical
        cache migration above). No-op for an unsharded engine."""
        eng = self.serving
        if eng is None or not hasattr(eng, "reown"):
            return
        with self.pipe.tracer.span("repartition.shard_reown") as sp:
            stats = eng.reown(new_table)
            wstats = self.pipe.warehouse.reown_shards(eng.ownership)
            sp.put("segments_moved", stats["segments_moved"])
            sp.put("warehouse_rows_moved", wstats["rows_moved"])

    def _finish_migration(self, cur, stats, initial_rows) -> Dict:
        from repro.core.pipeline import migration_summary
        if self.last_rebalance_stats is not None:
            stats = stats.merge(self.last_rebalance_stats)
        moved = cur.moved_fraction(
            self.pipe.current_routing(),
            np.arange(self.pipe.cfg.n_business_keys))
        self.last_migration = migration_summary(
            self.pipe.current_routing().epoch, moved, stats, initial_rows)
        return self.last_migration

    def repartition(self) -> Dict:
        """Adaptive skew-aware repartition WITHOUT stopping the stream:

        1. the strategy turns the broker's observed per-partition /
           per-key publish load into a new routing epoch;
        2. every live worker gets a ``reroute`` control: its key filter
           grows to the union of live + incoming epochs and its caches
           migrate surgically (gained ranges dumped, everything still
           owned retained) — all BEFORE any record routes under the new
           epoch;
        3. publishers switch atomically (per-partition horizons recorded,
           so the old epoch drains and retires);
        4. partition ownership rebalances by observed load through the
           PR-2 machinery (revoke → quiesce-under-commit-lock → offset
           transfer → surgical grant) and buffers re-home.

        Returns migration stats (also kept as ``last_migration``)."""
        with self._coord_lock:
            return self._repartition_body()

    def _repartition_body(self) -> Dict:
        from repro.core.pipeline import CacheMigrationStats
        pipe = self.pipe
        self.retire_epochs()
        initial_rows = self._initial_cache_rows()
        part_loads, keys, counts = pipe.observed_loads()
        cur = pipe.current_routing()
        new_table = pipe.strategy.rebalanced_table(cur, part_loads,
                                                   (keys, counts))
        stats = CacheMigrationStats()
        if new_table.epoch != cur.epoch:
            stats = self._reroute_all(new_table)
            # mid-repartition crash seam: publishers already route by the
            # new epoch, ownership not yet rebalanced (same window the
            # sequential coordinator exposes)
            pipe.fault.trip(REPARTITION_MID)
        # load-aware ownership rebalance: undrained backlog (old-epoch
        # placement) + expected future arrivals under the new epoch
        weights = pipe.backlog_weights()
        if len(keys):
            np.add.at(weights,
                      pipe.current_routing().partition_of(keys), counts)
        self._rebalance_to(self.alive_workers(), weights)
        pipe.metrics.shard("coordinator").counter(
            "pipeline.repartitions").inc()
        return self._finish_migration(cur, stats, initial_rows)

    def scale_partitions(self, n_partitions: int) -> Dict:
        """Elastic partition scale event: operational topics grow to
        ``n_partitions`` empty partitions, the strategy produces the
        scaled routing table (a consistent-hash ring moves only ~1/n of
        the key space; the static modulus reshuffles nearly all of it),
        workers pre-migrate, publishers switch, ownership rebalances."""
        with self._coord_lock:
            pipe = self.pipe
            assert n_partitions >= self.assignment.n_partitions
            initial_rows = self._initial_cache_rows()
            cur = pipe.current_routing()
            new_table = pipe.strategy.scaled_table(cur, n_partitions)
            for t in pipe.operational_topics:
                pipe.queue.topics[t].expand(n_partitions)
            self.assignment.grow(n_partitions)
            stats = self._reroute_all(new_table)
            self._rebalance_to(self.alive_workers())
            return self._finish_migration(cur, stats, initial_rows)
