"""Simulated elastic cluster for the paper's distributed experiments.

One real CPU executes all workers, so *wall-clock parallelism is modeled,
not real*: each round executes every worker's real JAX work serially and
records per-worker wall time; cluster time-per-round = max over workers
(+ straggler inflation), which is what a real cluster's barrier would
observe. Consistency results are REAL (the fault-tolerance experiment's
zero-error check re-validates every fact against a single-worker oracle).

Failure injection reproduces §4.1.3: killed workers trigger coordinator
rebalance -> cache-reset dumps on survivors -> throughput drop larger than
the node loss (the paper's observed 57% vs 40%).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.dod_etl import ETLConfig
from repro.core.cdc import SourceDatabase
from repro.core.pipeline import DODETLPipeline


@dataclasses.dataclass
class RoundStats:
    round_idx: int
    records: int
    worker_wall_s: Dict[str, float]
    cluster_wall_s: float          # max worker time (barrier model)
    cache_redump_s: float = 0.0
    n_workers: int = 0

    @property
    def rate(self) -> float:
        return self.records / self.cluster_wall_s if self.cluster_wall_s else 0.0


class SimulatedCluster:
    def __init__(self, pipeline: DODETLPipeline, *,
                 straggler_prob: float = 0.0,
                 straggler_slowdown: float = 3.0,
                 backup_tasks: bool = True,
                 seed: int = 0):
        self.pipe = pipeline
        self.rng = np.random.default_rng(seed)
        self.straggler_prob = straggler_prob
        self.straggler_slowdown = straggler_slowdown
        self.backup_tasks = backup_tasks
        self.history: List[RoundStats] = []
        self.stragglers_mitigated = 0

    def run_round(self, max_records_per_partition: Optional[int] = None
                  ) -> RoundStats:
        pipe = self.pipe
        for w in pipe.workers:
            w.pump_master(pipe.master_topic_map["equipment"], w.equipment)
            w.pump_master(pipe.master_topic_map["quality"], w.quality)
        walls: Dict[str, float] = {}
        records = 0
        for w in pipe.workers:
            t0 = time.perf_counter()
            for topic in pipe.operational_topics:
                records += w.process_operational(topic,
                                                 max_records_per_partition)
            wall = time.perf_counter() - t0
            # straggler model: occasionally a worker runs slow (paper's
            # 'low latency' requirement -> mitigation via backup execution)
            if self.rng.random() < self.straggler_prob:
                slow = wall * self.straggler_slowdown
                if self.backup_tasks:
                    # speculative backup on the least-loaded peer: pay the
                    # duplicate work, bound the tail at ~2x median
                    wall = min(slow, 2.0 * wall + 1e-9)
                    self.stragglers_mitigated += 1
                else:
                    wall = slow
            walls[w.name] = wall
        stats = RoundStats(
            round_idx=len(self.history), records=records,
            worker_wall_s=walls,
            cluster_wall_s=max(walls.values()) if walls else 0.0,
            n_workers=len(pipe.workers))
        self.history.append(stats)
        return stats

    def fail_workers(self, names: List[str]) -> float:
        """Inject §4.1.3's mid-run failure. Returns cache re-dump seconds
        (charged to the next round's wall time)."""
        redump = self.pipe.fail_workers(names)
        if self.history:
            self.history[-1].cache_redump_s += redump
        return redump

    def scale_to(self, n_workers: int) -> float:
        """Elastic resize (paper §3.2 'cluster scales up or down')."""
        pipe = self.pipe
        cur = len(pipe.workers)
        if n_workers < cur:
            return self.fail_workers(
                [w.name for w in pipe.workers[n_workers:]])
        if n_workers > cur:
            return pipe.add_workers(n_workers - cur)
        return 0.0

    def throughput(self, last_n: int = 5) -> float:
        h = self.history[-last_n:]
        rec = sum(s.records for s in h)
        wall = sum(s.cluster_wall_s + s.cache_redump_s for s in h)
        return rec / wall if wall else 0.0
