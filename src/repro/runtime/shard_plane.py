"""Sharded multi-device warehouse & serving plane (ROADMAP item 1).

Splits the materialized-view fold state and the star-schema warehouse
across ``n_shards`` serving shards — one jax mesh device each when a
1-D ``("shards",)`` mesh is attached to the backend (see
``ComputeBackend.set_mesh`` / ``repro.launch.mesh.make_shard_mesh``),
host-simulated otherwise. Ownership derives from the PR-5
``RoutingTable``: a contiguous range of routing partitions maps to each
shard, and a business key's shard is the shard of its routed partition,
so ``repartition()`` epochs remap shard ownership the same way they
remap worker ownership (surgically — only moved segments migrate,
mirroring the PR-5 cache migration).

Why sharding is by SEGMENT COLUMN, not by delta rows: the fold tree's
float adds are associative only in exact arithmetic — splitting a
delta's *rows* across shards would change each segment's combine order
and break the repo's bitwise determinism contract. Instead every shard
folds the FULL delta with every segment it does not own masked to the
``-1`` identity (``ComputeBackend.fold_segments_sharded``). The fold
tree is elementwise per segment column, so each owned column is bitwise
identical to the single-device fold and each foreign column stays at
the exact ``empty_fold_state`` identity forever. Segment extraction is
host integer math on the delta; the masked folds are the device
dispatches — on a mesh, one ``shard_map`` dispatch per row block with
NO collectives (zero cross-device traffic on the hot write path).

Cross-shard reads merge shard-local tables two ways, both exact:

* ``owner_gather`` — pure row selection (segment ``s`` comes from
  ``tables[owner[s]]``), unconditionally bitwise-identical to the
  single-device table. This is the authoritative merge the published
  ``EpochSnapshot`` front uses.
* ``tree_reduce`` — explicit pairwise-halving ``combine_fold`` over the
  shard tables (the ``jax.lax``-psum-shaped merge topology). Foreign
  columns contribute exact identities (+0.0 adds, ±inf min/max), so on
  the non-negative KPI domain this is bitwise-equal to ``owner_gather``
  (asserted in tests; ``x + 0.0`` would flip a ``-0.0`` sum, which is
  why owner-gather, not the reduction, is the authoritative path).

Ownership of a view's segments:

* ``spec.key_aligned`` (oee/downtime by equipment): segment id IS the
  business key, so the owner is the shard of the key's routed partition
  — these views migrate on ``repartition()``.
* otherwise (unit×shift, time windows): a static contiguous split of
  the segment domain, independent of routing epochs — never migrates.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.backend import combine_fold, empty_fold_state
from repro.core.partitioning import RoutingTable
from repro.observability.registry import global_registry
from repro.serving.engine import (EpochSnapshot, MaterializedViewEngine,
                                  ViewState, serving_clock)
from repro.serving.views import ViewSpec

_PLANE_SEQ = itertools.count()


# --------------------------------------------------------------- ownership
class ShardOwnership:
    """Frozen mapping of routing partitions / business keys / view
    segments to serving shards for ONE routing epoch.

    Partition -> shard is the contiguous range split
    ``p * n_shards // n_partitions`` (the mesh analogue of the worker
    assignment); key -> shard goes through ``router.partition_of`` so a
    repartition that re-homes a key re-homes its shard too.
    """

    def __init__(self, n_shards: int, router: RoutingTable,
                 specs: Sequence[ViewSpec]):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.router = router
        self.specs = tuple(specs)
        self._seg_owners: Dict[str, np.ndarray] = {}
        for spec in self.specs:
            self._seg_owners[spec.name] = self._owners_for(spec)

    def shard_of_partitions(self, parts: np.ndarray) -> np.ndarray:
        parts = np.asarray(parts, np.int64)
        return parts * self.n_shards // self.router.n_partitions

    def shard_of_keys(self, keys: np.ndarray) -> np.ndarray:
        return self.shard_of_partitions(
            self.router.partition_of(np.asarray(keys, np.int64)))

    def _owners_for(self, spec: ViewSpec) -> np.ndarray:
        S = spec.n_segments
        if spec.key_aligned:
            owners = self.shard_of_keys(np.arange(S, dtype=np.int64))
        else:
            owners = np.arange(S, dtype=np.int64) * self.n_shards // S
        owners = np.ascontiguousarray(owners, dtype=np.int64)
        owners.flags.writeable = False
        return owners

    def seg_owners(self, view: str) -> np.ndarray:
        """[n_segments] int64: owning shard of each segment of ``view``."""
        return self._seg_owners[view]

    def owned_segments(self, view: str) -> np.ndarray:
        """[n_shards] int64: how many of the view's segments each shard
        owns — the imbalance signal health() exposes."""
        return np.bincount(self._seg_owners[view],
                           minlength=self.n_shards).astype(np.int64)

    def with_router(self, router: RoutingTable) -> "ShardOwnership":
        return ShardOwnership(self.n_shards, router, self.specs)


# ----------------------------------------------------------------- merges
def owner_gather(shard_tables: Sequence[np.ndarray],
                 owners: np.ndarray) -> np.ndarray:
    """Authoritative cross-shard merge: segment ``s``'s row is selected
    from its OWNER's table — pure indexing, no arithmetic, so the result
    is unconditionally bitwise-identical to the single-device table."""
    stacked = np.stack(shard_tables)
    return np.ascontiguousarray(
        stacked[np.asarray(owners, np.int64),
                np.arange(stacked.shape[1], dtype=np.int64)])


def tree_reduce(shard_tables: Sequence[np.ndarray]) -> np.ndarray:
    """Explicit pairwise-halving reduction over shard-local tables (the
    collective-shaped merge topology): ``ceil(log2(K))`` rounds of
    ``combine_fold``. Foreign segment columns hold exact identities, so
    each owned column combines with +0.0 / ±inf only."""
    tabs = list(shard_tables)
    if not tabs:
        raise ValueError("tree_reduce of zero shard tables")
    while len(tabs) > 1:
        tabs = [combine_fold(tabs[i], tabs[i + 1])
                if i + 1 < len(tabs) else tabs[i]
                for i in range(0, len(tabs), 2)]
    return tabs[0]


# ---------------------------------------------------------------- snapshot
@dataclasses.dataclass(frozen=True)
class ShardedEpochSnapshot(EpochSnapshot):
    """An ``EpochSnapshot`` whose ``states`` hold the owner-gathered
    (merged, single-device-identical) tables, carrying the shard-local
    tables + ownership it was merged from. Readers that know about
    shards (the batched gather router, checkpoints, health) use the
    extra fields; every existing reader sees a plain epoch."""

    shard_states: Mapping[str, Tuple[np.ndarray, ...]] = \
        dataclasses.field(default_factory=dict)
    seg_owners: Mapping[str, np.ndarray] = \
        dataclasses.field(default_factory=dict)
    n_shards: int = 1


# ------------------------------------------------------------------ engine
class ShardedViewEngine(MaterializedViewEngine):
    """Drop-in ``MaterializedViewEngine`` whose fold state lives in
    ``n_shards`` shard-local tables (one mesh device each when the
    backend has a matching mesh attached).

    * write path: one ``fold_segments_sharded`` per (delta, view) —
      device-local masked folds, zero cross-shard traffic;
    * publish: owner-gather merge into a ``ShardedEpochSnapshot`` whose
      merged tables are bitwise-identical to the unsharded engine's, so
      the entire read stack (reports, batched plans, prefix folds,
      ``rebuild`` oracles) works unchanged;
    * ``reown(router)``: surgical ownership remap on repartition — only
      segments whose owner changed move between shard tables;
    * durability: ``export_fold_state`` additionally captures the
      per-shard tables + ownership so recovery works on a mesh.
    """

    def __init__(self, specs: Sequence[ViewSpec], n_shards: int,
                 router: Optional[RoutingTable] = None, backend=None,
                 idle_backoff_s: float = 0.001, scan_fold: bool = False):
        if scan_fold:
            raise ValueError(
                "ShardedViewEngine folds through the halving tree only "
                "(the opt-in write-side scan form has no sharded twin)")
        super().__init__(specs, backend=backend,
                         idle_backoff_s=idle_backoff_s, scan_fold=False)
        router = router if router is not None \
            else RoutingTable.static(max(int(n_shards), 1))
        self.ownership = ShardOwnership(n_shards, router, self.specs)
        self.n_shards = self.ownership.n_shards
        # shard-local master tables: replaced functionally per fold
        # (combine_fold returns new arrays), guarded by _fold_lock
        self._shard_tables: Dict[str, List[np.ndarray]] = {
            s.name: [empty_fold_state(s.n_segments, s.n_lanes)
                     for _ in range(self.n_shards)]
            for s in self.specs}
        # shard.* counters on the process-global registry (one read path
        # with the backend dispatch counters; health() merges them)
        mshard = global_registry().shard(
            f"shard_plane#{next(_PLANE_SEQ)}")
        self._c_fold_rows = [
            mshard.counter(f"shard.fold_rows.{k}")
            for k in range(self.n_shards)]
        self._c_merge_bytes = mshard.counter("shard.merge.bytes")
        self._c_merge_dispatches = mshard.counter("shard.merge.dispatches")
        self._c_reowns = mshard.counter("shard.reowns")
        self._c_moved = mshard.counter("shard.reown.segments_moved")
        self._front = self._publish_front(
            epoch=0, watermark=-np.inf, rows_folded=0, deltas_folded=0)

    # ----------------------------------------------------------- publication
    def _publish_front(self, *, epoch: int, watermark: float,
                       rows_folded: int, deltas_folded: int
                       ) -> ShardedEpochSnapshot:
        """Owner-gather every view's shard tables into one merged epoch
        (called under _fold_lock except for the constructor's empty
        epoch). Counts the merge traffic honestly: one gather 'dispatch'
        per view, merged-table bytes crossing the shard boundary."""
        states = {}
        shard_states = {}
        seg_owners = {}
        for spec in self.specs:
            owners = self.ownership.seg_owners(spec.name)
            tabs = tuple(self._shard_tables[spec.name])
            merged = owner_gather(tabs, owners)
            merged.flags.writeable = False
            states[spec.name] = ViewState(spec, merged)
            shard_states[spec.name] = tabs
            seg_owners[spec.name] = owners
            self._c_merge_dispatches.inc()
            self._c_merge_bytes.inc(merged.nbytes)
        return ShardedEpochSnapshot(
            epoch=epoch, states=states, published_at=serving_clock(),
            watermark_event_time=watermark, rows_folded=rows_folded,
            deltas_folded=deltas_folded, shard_states=shard_states,
            seg_owners=seg_owners, n_shards=self.n_shards)

    # ------------------------------------------------------------ fold cycle
    def fold_pending(self, max_deltas: Optional[int] = None) -> int:
        """Sharded twin of the base fold cycle: same delta order, same
        watermark/staleness bookkeeping, but every (delta, view) fold is
        one ``fold_segments_sharded`` producing all shard-local deltas,
        combined shard-locally. Publishes ONE merged epoch."""
        with self._fold_lock:
            with self._q_lock:
                take = len(self._pending) if max_deltas is None \
                    else min(max_deltas, len(self._pending))
                deltas = [self._pending.popleft() for _ in range(take)]
            if not deltas:
                return 0
            with self.tracer.span("serving.fold") as sp:
                front = self._front
                watermark = front.watermark_event_time
                rows = 0
                K = self.n_shards
                for d in deltas:
                    valid = d.facts[:, 9] > 0.5
                    vfacts = d.facts[valid]
                    rows += len(d.facts)
                    for spec in self.specs:
                        owners = self.ownership.seg_owners(spec.name)
                        seg = spec.segments(vfacts)
                        stacked = self.backend.fold_segments_sharded(
                            seg, spec.values(vfacts), spec.n_segments,
                            owners, K)
                        tabs = self._shard_tables[spec.name]
                        for k in range(K):
                            tabs[k] = combine_fold(tabs[k], stacked[k])
                        if len(seg):
                            in_range = (seg >= 0) & (seg < spec.n_segments)
                            per_shard = np.bincount(
                                owners[seg[in_range]], minlength=K)
                            for k in range(K):
                                self._c_fold_rows[k].inc(int(per_shard[k]))
                    watermark = max(watermark,
                                    float(d.event_times.max())
                                    if d.event_times is not None
                                    and len(d.event_times)
                                    else d.published_at)
                snap = self._publish_front(
                    epoch=front.epoch + 1, watermark=watermark,
                    rows_folded=front.rows_folded + rows,
                    deltas_folded=front.deltas_folded + len(deltas))
                self._front = snap       # the atomic epoch swap
                for d in deltas:
                    if d.event_times is not None:
                        self.staleness_recorder.add(
                            snap.published_at - d.event_times)
                sp.put("deltas", len(deltas))
                sp.put("rows", rows)
                sp.put("epoch", snap.epoch)
            return rows

    # ------------------------------------------------------------ reads
    def tree_reduced_table(self, view: str) -> np.ndarray:
        """The explicit cross-shard tree-reduce read of one view (the
        merge topology a mesh collective would run): pairwise-halving
        ``combine_fold`` over the front's shard-local tables. Equal to
        the owner-gathered front on the KPI domain (asserted in tests)."""
        front = self._front
        tabs = front.shard_states[view]
        self._c_merge_dispatches.inc(max(0, len(tabs) - 1))
        self._c_merge_bytes.inc(sum(t.nbytes for t in tabs[1:]))
        return tree_reduce(tabs)

    # ------------------------------------------------------------ reown
    def reown(self, router: RoutingTable) -> Dict[str, int]:
        """Surgical shard-ownership remap for a new routing epoch
        (mirrors PR-5 cache migration): only key-aligned views can move,
        and within them only the segments whose owner shard actually
        changed are copied to the new owner (old slot reset to the
        identity). Merged state is invariant — the same rows live on
        different shards. Republishes the front (same epoch/counters)
        so checkpoints and the batched gather router see the new
        placement immediately."""
        with self._fold_lock:
            old = self.ownership
            new = old.with_router(router)
            moved_total = 0
            views_changed = 0
            for spec in self.specs:
                ow_old = old.seg_owners(spec.name)
                ow_new = new.seg_owners(spec.name)
                moved = np.nonzero(ow_old != ow_new)[0]
                if not len(moved):
                    continue
                views_changed += 1
                moved_total += len(moved)
                tabs = self._shard_tables[spec.name]
                src = owner_gather(tabs, ow_old)   # pre-move residents
                ident = empty_fold_state(spec.n_segments, spec.n_lanes)
                touched = set(ow_old[moved].tolist()) \
                    | set(ow_new[moved].tolist())
                for k in touched:
                    t = tabs[k].copy()
                    lost = moved[ow_old[moved] == k]
                    gained = moved[ow_new[moved] == k]
                    t[lost] = ident[lost]
                    t[gained] = src[gained]
                    tabs[k] = t
            self.ownership = new
            self._c_reowns.inc()
            self._c_moved.inc(moved_total)
            front = self._front
            self._front = self._publish_front(
                epoch=front.epoch, watermark=front.watermark_event_time,
                rows_folded=front.rows_folded,
                deltas_folded=front.deltas_folded)
            return {"segments_moved": int(moved_total),
                    "views_changed": int(views_changed),
                    "routing_epoch": int(router.epoch)}

    # ------------------------------------------------------------ durability
    def export_fold_state(self) -> Dict:
        """Base export (merged tables + counters, lock-free on the
        immutable front) plus the per-shard tables and the ownership
        they were folded under — a checkpoint taken on a mesh restores
        onto a mesh."""
        front = self._front
        state = super().export_fold_state()
        state["shard"] = {
            "n_shards": int(front.n_shards),
            "routing_epoch": int(self.ownership.router.epoch),
            "tables": {name: np.stack(tabs)
                       for name, tabs in front.shard_states.items()},
            "seg_owners": {name: np.asarray(own)
                           for name, own in front.seg_owners.items()},
        }
        return state

    def restore_fold_state(self, state: Dict) -> None:
        """Restore the merged front (authoritative, same as the base
        engine), then place shard-local tables: directly from the
        checkpoint when its ownership matches this engine's (same shard
        count and per-view owners), otherwise re-derived exactly from
        the merged tables under CURRENT ownership (owned columns from
        the merged table, foreign columns identity). The re-derivation
        handles restoring a mesh checkpoint onto a different shard
        count/routing epoch — and restoring a single-device checkpoint
        onto a mesh — without any bitwise drift."""
        super().restore_fold_state(state)
        shard = state.get("shard")
        with self._fold_lock:
            usable = (shard is not None
                      and int(shard.get("n_shards", -1)) == self.n_shards)
            if usable:
                for spec in self.specs:
                    own = np.asarray(shard["seg_owners"][spec.name],
                                     np.int64)
                    if not np.array_equal(
                            own, self.ownership.seg_owners(spec.name)):
                        usable = False
                        break
            for spec in self.specs:
                merged = np.asarray(state["tables"][spec.name], np.float32)
                owners = self.ownership.seg_owners(spec.name)
                if usable:
                    stacked = np.asarray(shard["tables"][spec.name],
                                         np.float32)
                    tabs = [np.ascontiguousarray(stacked[k])
                            for k in range(self.n_shards)]
                else:
                    ident = empty_fold_state(spec.n_segments, spec.n_lanes)
                    tabs = [np.where(owners[:, None] == k, merged, ident)
                            for k in range(self.n_shards)]
                self._shard_tables[spec.name] = tabs
            front = self._front
            self._front = self._publish_front(
                epoch=front.epoch, watermark=front.watermark_event_time,
                rows_folded=front.rows_folded,
                deltas_folded=front.deltas_folded)

    # ---------------------------------------------------------- observability
    def mesh_report(self) -> Dict:
        """The health() ``mesh`` block: shard counts, per-shard fold rows
        and owned segments (the imbalance signal the ControlPlane's
        observation vector consumes), merge traffic, reown history."""
        fold_rows = [c.value for c in self._c_fold_rows]
        mean = sum(fold_rows) / max(1, len(fold_rows))
        owned = {spec.name: self.ownership.owned_segments(
            spec.name).tolist() for spec in self.specs}
        return {
            "n_shards": self.n_shards,
            "device_mesh": (self.backend.mesh is not None
                            and self.backend.mesh.devices.size
                            == self.n_shards),
            "routing_epoch": int(self.ownership.router.epoch),
            "fold_rows": fold_rows,
            "fold_rows_imbalance": round(max(fold_rows) / mean, 4)
            if mean > 0 else 1.0,
            "owned_segments": owned,
            "merge": {"bytes": self._c_merge_bytes.value,
                      "dispatches": self._c_merge_dispatches.value},
            "reowns": self._c_reowns.value,
            "segments_moved": self._c_moved.value,
        }

    def attach_metrics(self, shard) -> None:
        super().attach_metrics(shard)
        shard.gauge_fn("shard.n_shards", lambda: self.n_shards)
        shard.gauge_fn(
            "shard.fold_rows_imbalance",
            lambda: self.mesh_report()["fold_rows_imbalance"])


__all__ = ["ShardOwnership", "ShardedEpochSnapshot", "ShardedViewEngine",
           "owner_gather", "tree_reduce"]
