"""Shared latency instrumentation: thread-safe sample recording and the
percentile summary used by both the write path (end-to-end load freshness,
``repro.runtime.cluster``) and the read path (report staleness,
``repro.serving.engine``). One definition so the two metrics stay
comparable — the serving layer's staleness is measured on the same clock
and aggregated by the same estimator as the pipeline's freshness.

``LatencyRecorder`` is a BOUNDED reservoir: under sustained load an
undrained recorder no longer grows without limit. Up to ``capacity``
samples are kept verbatim (the non-overflow path is byte-identical to
the old concatenate-everything behavior); past that, the reservoir
down-samples DETERMINISTICALLY — it keeps every ``stride``-th sample of
the arrival sequence, doubling the stride each time the store would
overflow — so two identical runs summarize identical sample subsets (no
RNG), the kept subset stays uniformly spread over the whole recording
window, and memory is O(capacity) forever.
"""
from __future__ import annotations

import threading
from typing import Dict, List

import numpy as np


def percentiles_ms(samples: np.ndarray) -> Dict[str, float]:
    """p50/p95/p99 of latency samples given in SECONDS, reported in ms."""
    if not len(samples):
        return {"p50_ms": float("nan"), "p95_ms": float("nan"),
                "p99_ms": float("nan"), "n": 0}
    p50, p95, p99 = np.percentile(samples, [50, 95, 99])
    return {"p50_ms": round(float(p50) * 1e3, 3),
            "p95_ms": round(float(p95) * 1e3, 3),
            "p99_ms": round(float(p99) * 1e3, 3), "n": int(len(samples))}


class LatencyRecorder:
    """Latency samples appended by one or more hot-path threads and read by
    a coordinator — a lock guards the chunk list, never the numpy math.

    Bounded: at most ~``capacity`` samples are stored. While the lifetime
    sample count stays at or under ``capacity`` every sample is kept and
    ``merged()``/``percentiles()`` are exact (the pinned legacy behavior);
    beyond that the estimator runs over a deterministic every-``stride``-th
    subsample of the arrival sequence. ``total_seen`` counts every sample
    ever offered; ``percentiles()['n']`` counts the samples the estimate
    was computed from.
    """

    def __init__(self, capacity: int = 1 << 16):
        if capacity < 2:
            raise ValueError(f"capacity must be >= 2, got {capacity}")
        self.capacity = int(capacity)
        self.total_seen = 0
        self._chunks: List[np.ndarray] = []
        self._stored = 0
        self._stride = 1          # keep arrivals whose global index % stride == 0
        self._phase = 0           # arrival index modulo stride of the next sample
        self._lock = threading.Lock()

    def add(self, samples: np.ndarray) -> None:
        if not len(samples):
            return
        arr = np.asarray(samples, np.float64).ravel()
        with self._lock:
            self.total_seen += len(arr)
            if self._stride > 1:
                first = (-self._phase) % self._stride
                self._phase = (self._phase + len(arr)) % self._stride
                arr = arr[first::self._stride]
            if len(arr):
                self._chunks.append(arr)
                self._stored += len(arr)
            while self._stored > self.capacity:
                self._halve_locked()

    def _halve_locked(self) -> None:
        # Stored samples sit at arrival indices 0, s, 2s, ...; keeping
        # every 2nd leaves exactly the indices divisible by 2s, so the
        # invariant "kept == arrivals with index % stride == 0" is exact.
        merged = np.concatenate(self._chunks)
        kept = np.ascontiguousarray(merged[::2])
        self._chunks = [kept]
        self._stored = len(kept)
        self._stride *= 2
        self._phase = self.total_seen % self._stride

    def merged(self, drain: bool = False) -> np.ndarray:
        with self._lock:
            chunks = self._chunks
            if drain:
                self._chunks = []
                self._stored = 0
                self._stride = 1
                self._phase = 0
            else:
                chunks = list(chunks)
        if not chunks:
            return np.zeros(0, np.float64)
        return np.concatenate(chunks)

    def percentiles(self, drain: bool = False) -> Dict[str, float]:
        return percentiles_ms(self.merged(drain))

    @property
    def stored(self) -> int:
        """Samples currently held (<= capacity)."""
        with self._lock:
            return self._stored


__all__ = ["LatencyRecorder", "percentiles_ms"]
