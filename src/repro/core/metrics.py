"""Shared latency instrumentation: thread-safe sample recording and the
percentile summary used by both the write path (end-to-end load freshness,
``repro.runtime.cluster``) and the read path (report staleness,
``repro.serving.engine``). One definition so the two metrics stay
comparable — the serving layer's staleness is measured on the same clock
and aggregated by the same estimator as the pipeline's freshness.
"""
from __future__ import annotations

import threading
from typing import Dict, List

import numpy as np


def percentiles_ms(samples: np.ndarray) -> Dict[str, float]:
    """p50/p95/p99 of latency samples given in SECONDS, reported in ms."""
    if not len(samples):
        return {"p50_ms": float("nan"), "p95_ms": float("nan"),
                "p99_ms": float("nan"), "n": 0}
    p50, p95, p99 = np.percentile(samples, [50, 95, 99])
    return {"p50_ms": round(float(p50) * 1e3, 3),
            "p95_ms": round(float(p95) * 1e3, 3),
            "p99_ms": round(float(p99) * 1e3, 3), "n": int(len(samples))}


class LatencyRecorder:
    """Latency samples appended by one or more hot-path threads and read by
    a coordinator — a lock guards the chunk list, never the numpy math."""

    def __init__(self):
        self._chunks: List[np.ndarray] = []
        self._lock = threading.Lock()

    def add(self, samples: np.ndarray) -> None:
        if len(samples):
            with self._lock:
                self._chunks.append(np.asarray(samples, np.float64))

    def merged(self, drain: bool = False) -> np.ndarray:
        with self._lock:
            chunks = self._chunks
            if drain:
                self._chunks = []
            else:
                chunks = list(chunks)
        if not chunks:
            return np.zeros(0, np.float64)
        return np.concatenate(chunks)

    def percentiles(self, drain: bool = False) -> Dict[str, float]:
        return percentiles_ms(self.merged(drain))


__all__ = ["LatencyRecorder", "percentiles_ms"]
