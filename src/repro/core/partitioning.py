"""Key partitioning (paper §3.1.1): master topics partition by *row key*
(so the latest-per-key compaction reconstructs a table snapshot);
operational topics partition by *business key* (the Stream Processor's
parallelism unit — each partition's lifecycle stays on one worker / one
data shard).

The same helper drives the MoE expert dispatch (a token is a message, the
router's expert choice is its business key): ``assign_positions`` in
``repro.models.moe`` is the capacity-bounded variant of this assignment.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

_MIX = np.uint64(0x9E3779B97F4A7C15)


def hash_key(keys: np.ndarray) -> np.ndarray:
    """Deterministic 64-bit mix (splitmix64 finalizer-style)."""
    x = keys.astype(np.uint64) * _MIX
    x ^= x >> np.uint64(31)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(29)
    return x


def partition_of(keys: np.ndarray, n_partitions: int) -> np.ndarray:
    return (hash_key(keys) % np.uint64(n_partitions)).astype(np.int32)


def split_by_partition(keys: np.ndarray, n_partitions: int
                       ) -> List[np.ndarray]:
    part = partition_of(keys, n_partitions)
    return [np.nonzero(part == p)[0] for p in range(n_partitions)]


def isin_sorted(sorted_keys: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Vectorized membership of ``values`` in a SORTED unique key array via
    binary search (the shared idiom behind worker business-key filtering and
    compacted-snapshot filtering). Returns a bool mask over ``values``."""
    if not len(sorted_keys):
        return np.zeros(len(values), bool)
    idx = np.minimum(np.searchsorted(sorted_keys, values),
                     len(sorted_keys) - 1)
    return sorted_keys[idx] == values


def partition_bounds(keys: np.ndarray, n_partitions: int):
    """Stable single-gather bucketing by partition. Returns (order, bounds):
    rows of partition p are ``order[bounds[p]:bounds[p+1]]`` — the one
    algorithm behind both queue publish and warehouse load splitting."""
    parts = partition_of(keys, n_partitions)
    order = np.argsort(parts, kind="stable")
    bounds = np.searchsorted(parts[order], np.arange(n_partitions + 1))
    return order, bounds


class PartitionAssignment:
    """business-key partitions -> worker assignment with rebalancing
    (paper §3.2: on failure/scale events the coordinator reassigns and the
    cache-reset trigger fires for workers whose key set changed)."""

    def __init__(self, n_partitions: int, workers: Sequence[str]):
        self.n_partitions = n_partitions
        self.assignment: Dict[int, str] = {}
        self.rebalance(list(workers))

    def rebalance(self, workers: List[str]) -> Dict[str, List[int]]:
        """Round-robin reassign. Returns {worker: changed_partitions} so the
        pipeline can fire In-memory cache reset triggers."""
        if not workers:
            raise ValueError("no workers alive")
        old = dict(self.assignment)
        for p in range(self.n_partitions):
            self.assignment[p] = workers[p % len(workers)]
        changed: Dict[str, List[int]] = {w: [] for w in workers}
        for p, w in self.assignment.items():
            if old.get(p) != w:
                changed.setdefault(w, []).append(p)
        return changed

    def partitions_of(self, worker: str) -> List[int]:
        return sorted(p for p, w in self.assignment.items() if w == worker)

    def worker_of(self, partition: int) -> str:
        return self.assignment[partition]
