"""Key partitioning (paper §3.1.1): master topics partition by *row key*
(so the latest-per-key compaction reconstructs a table snapshot);
operational topics partition by *business key* (the Stream Processor's
parallelism unit — each partition's lifecycle stays on one worker / one
data shard).

Partitioning is a pluggable, *adaptive* subsystem:

* a ``RoutingTable`` is an immutable, versioned key→partition mapping
  (its version is the **routing epoch**; ``Topic`` carries the current
  table plus the still-draining historical ones, so records published
  under epoch E stay readable while the coordinator migrates to E+1);
* a ``PartitionStrategy`` produces routing tables: ``static`` is the
  paper's bare ``hash % n``; ``consistent`` is a virtual-node hash ring
  whose scale events move only ~1/n of the key space; ``skew`` splits
  hot business-key hash ranges and merges cold ones from observed load,
  so a Zipf-skewed workload (a few hot equipment units emitting most
  events) spreads across partitions instead of pinning one worker;
* ``PartitionAssignment`` maps partitions → workers with a *sticky,
  load-aware* rebalance (greedy LPT preferring the current owner), so a
  scale event moves ~1/n_workers of the key space instead of the ~all
  that round-robin reassignment moved.

The same hashing discipline drives the MoE expert dispatch (a token is a
message, the router's expert choice is its business key):
``assign_positions`` in ``repro.models.moe`` is the capacity-bounded
variant of this assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_MIX = np.uint64(0x9E3779B97F4A7C15)
_UINT64_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)


def hash_key(keys: np.ndarray) -> np.ndarray:
    """Deterministic 64-bit mix (splitmix64 finalizer-style)."""
    with np.errstate(over="ignore"):
        x = np.asarray(keys).astype(np.uint64) * _MIX
        x ^= x >> np.uint64(31)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(29)
    return x


def partition_of(keys: np.ndarray, n_partitions: int) -> np.ndarray:
    return (hash_key(keys) % np.uint64(n_partitions)).astype(np.int32)


def isin_sorted(sorted_keys: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Vectorized membership of ``values`` in a SORTED unique key array via
    binary search (the shared idiom behind worker business-key filtering and
    compacted-snapshot filtering). Returns a bool mask over ``values``."""
    if not len(sorted_keys):
        return np.zeros(len(values), bool)
    idx = np.minimum(np.searchsorted(sorted_keys, values),
                     len(sorted_keys) - 1)
    return sorted_keys[idx] == values


# ===================================================================== routing
@dataclasses.dataclass(frozen=True)
class RoutingTable:
    """Immutable, versioned key→partition mapping (one routing epoch).

    Two representations share one vectorized lookup:

    * ``kind="mod"`` — the static hash: ``hash_key(k) % n_partitions``
      (byte-identical to the pre-adaptive behavior; the default);
    * ``kind="points"`` — a sorted array of uint64 *points* over the hash
      space with an owner partition per point. A key belongs to the first
      point ≥ its hash (wrapping), which expresses both a consistent-hash
      ring (points = virtual nodes) and a range table (points = range
      upper bounds, last = 2^64−1).
    """

    epoch: int
    kind: str                              # "mod" | "points"
    n_partitions: int
    points: Optional[np.ndarray] = None    # uint64 [R] sorted, read-only
    owners: Optional[np.ndarray] = None    # int32  [R], read-only

    @staticmethod
    def static(n_partitions: int, epoch: int = 0) -> "RoutingTable":
        return RoutingTable(epoch=epoch, kind="mod", n_partitions=n_partitions)

    @staticmethod
    def from_points(points: np.ndarray, owners: np.ndarray,
                    n_partitions: int, epoch: int) -> "RoutingTable":
        order = np.argsort(points, kind="stable")
        points = np.ascontiguousarray(points[order])
        owners = np.ascontiguousarray(owners[order].astype(np.int32))
        points.flags.writeable = False
        owners.flags.writeable = False
        return RoutingTable(epoch=epoch, kind="points",
                            n_partitions=n_partitions,
                            points=points, owners=owners)

    def partition_of(self, keys: np.ndarray) -> np.ndarray:
        if self.kind == "mod":
            return partition_of(keys, self.n_partitions)
        h = hash_key(keys)
        idx = np.searchsorted(self.points, h, side="left")
        return self.owners[idx % len(self.points)]

    def moved_fraction(self, other: "RoutingTable",
                       keys: np.ndarray) -> float:
        """Fraction of ``keys`` whose partition differs under ``other`` —
        the migration cost of an epoch change."""
        if not len(keys):
            return 0.0
        return float(np.mean(self.partition_of(keys)
                             != other.partition_of(keys)))


def partition_bounds(keys: np.ndarray, n_partitions: int,
                     router: Optional[RoutingTable] = None):
    """Stable single-gather bucketing by partition. Returns (order, bounds):
    rows of partition p are ``order[bounds[p]:bounds[p+1]]`` — the one
    algorithm behind queue publish and warehouse load splitting. With a
    ``router`` the bucketing follows that routing epoch; without one it is
    the stable static hash (the loader keeps using the static layout so
    chunk row order is invariant to routing epochs — see ``loader``)."""
    parts = (partition_of(keys, n_partitions) if router is None
             else router.partition_of(keys))
    order = np.argsort(parts, kind="stable")
    bounds = np.searchsorted(parts[order], np.arange(n_partitions + 1))
    return order, bounds


# ================================================================== strategies
class PartitionStrategy:
    """Produces routing tables. Stateless: observed load comes in as
    arguments (the broker's per-partition/per-key publish counters), the
    new epoch comes out as an immutable table."""

    name = "static"

    def initial_table(self, n_partitions: int) -> RoutingTable:
        return RoutingTable.static(n_partitions)

    def scaled_table(self, table: RoutingTable,
                     n_partitions: int) -> RoutingTable:
        """Table for a changed partition count (elastic scale event)."""
        return RoutingTable.static(n_partitions, epoch=table.epoch + 1)

    def rebalanced_table(self, table: RoutingTable,
                         partition_loads: Optional[np.ndarray] = None,
                         key_loads: Optional[Tuple[np.ndarray, np.ndarray]]
                         = None) -> RoutingTable:
        """Adapt to observed load. Default: static hash cannot adapt."""
        return table


class StaticHashStrategy(PartitionStrategy):
    """The paper's bare ``hash_key % n_partitions``."""


class ConsistentHashStrategy(PartitionStrategy):
    """Hash ring with ``virtual_nodes`` points per partition: when the
    partition count changes, only the arcs claimed by the new (or removed)
    partitions' points move — ~1/n_partitions of the key space instead of
    the ~(1 − 1/n) a modulus reshuffle moves."""

    name = "consistent"
    _VNODE_SHIFT = np.uint64(20)       # vnode ids stable across scale events

    def __init__(self, virtual_nodes: int = 64):
        self.virtual_nodes = virtual_nodes

    def _ring(self, n_partitions: int, epoch: int) -> RoutingTable:
        v = self.virtual_nodes
        ids = ((np.arange(n_partitions, dtype=np.uint64)[:, None]
                << self._VNODE_SHIFT)
               | np.arange(v, dtype=np.uint64)[None, :])
        points = hash_key(ids.reshape(-1))
        owners = np.repeat(np.arange(n_partitions, dtype=np.int32), v)
        return RoutingTable.from_points(points, owners, n_partitions, epoch)

    def initial_table(self, n_partitions: int) -> RoutingTable:
        return self._ring(n_partitions, 0)

    def scaled_table(self, table: RoutingTable,
                     n_partitions: int) -> RoutingTable:
        return self._ring(n_partitions, table.epoch + 1)


class SkewAwareStrategy(PartitionStrategy):
    """Range table over the hash space, adapted from observed load: the
    hottest partition's heaviest range is split at its load-weighted
    median and the cooler half handed to the coldest partition, until the
    partition-load imbalance (max/mean) drops under ``imbalance_target``
    or no split can improve it (a single business key is atomic — the
    paper's unit of worker affinity — so one key hotter than the mean is
    the floor). Adjacent ranges with one owner merge back, and only moved
    ranges change key→partition mapping, so cache migration stays
    surgical."""

    name = "skew"

    def __init__(self, imbalance_target: float = 1.15,
                 max_ranges_per_partition: int = 8,
                 max_splits: int = 256):
        self.imbalance_target = imbalance_target
        self.max_ranges_per_partition = max_ranges_per_partition
        self.max_splits = max_splits

    def initial_table(self, n_partitions: int) -> RoutingTable:
        return self._equal_ranges(n_partitions, 0)

    def scaled_table(self, table: RoutingTable,
                     n_partitions: int) -> RoutingTable:
        return self._equal_ranges(n_partitions, table.epoch + 1)

    @staticmethod
    def _equal_ranges(n_partitions: int, epoch: int) -> RoutingTable:
        step = (1 << 64) // n_partitions         # Python ints: no overflow
        pts = [(i + 1) * step - 1 for i in range(n_partitions)]
        pts[-1] = (1 << 64) - 1
        points = np.array(pts, dtype=np.uint64)
        owners = np.arange(n_partitions, dtype=np.int32)
        return RoutingTable.from_points(points, owners, n_partitions, epoch)

    def rebalanced_table(self, table, partition_loads=None, key_loads=None):
        if key_loads is None:
            return table
        keys, counts = key_loads
        keys = np.asarray(keys, np.int64)
        counts = np.asarray(counts, np.float64)
        if not len(keys) or counts.sum() <= 0:
            return table
        n = table.n_partitions
        if table.kind == "mod":
            base = self._equal_ranges(n, table.epoch)
            points = base.points.copy()
            owners = base.owners.copy()
        else:
            points = table.points.copy()
            owners = table.owners.copy()

        hk = hash_key(keys)
        order = np.argsort(hk, kind="stable")
        h, w = hk[order], counts[order]

        changed = False
        frozen = np.zeros(n, bool)     # partitions that cannot be improved
        for _ in range(self.max_splits):
            ridx = np.searchsorted(points, h, side="left")
            range_load = np.bincount(ridx, weights=w, minlength=len(points))
            part_load = np.zeros(n)
            np.add.at(part_load, owners, range_load)
            mean = part_load.sum() / n
            if mean <= 0 or not (~frozen).any():
                break
            hot = int(np.where(frozen, -1.0, part_load).argmax())
            cold = int(part_load.argmin())
            if part_load[hot] <= self.imbalance_target * mean or cold == hot:
                break
            hot_ranges = np.nonzero(owners == hot)[0]
            r = int(hot_ranges[range_load[hot_ranges].argmax()])
            sel = np.nonzero(ridx == r)[0]
            uniq = np.unique(h[sel])
            if len(uniq) >= 2 and \
                    len(points) < n * self.max_ranges_per_partition:
                # load-weighted median split inside the hot range: the
                # lower piece (≈ half the range's load) goes to the
                # coldest partition, but never more than its deficit
                cum = np.cumsum(w[sel])
                give = min(cum[-1] / 2.0, mean - part_load[cold])
                j = int(np.searchsorted(cum, max(give, w[sel][0])))
                j = min(j, len(sel) - 1)
                cut = h[sel][j]
                if cut >= uniq[-1]:          # keep ≥1 key on the hot side
                    cut = uniq[-2]
                points = np.insert(points, r, cut)
                owners = np.insert(owners, r, cold)
                changed = True
            else:
                # the hot range is one atomic key (or the table is at its
                # size cap): peel the hot partition's lightest non-empty
                # other range off to the coldest, if that strictly lowers
                # the pair's max (no ping-pong)
                others = hot_ranges[(hot_ranges != r)
                                    & (range_load[hot_ranges] > 0)]
                if len(others):
                    mv = int(others[range_load[others].argmin()])
                    if part_load[cold] + range_load[mv] < part_load[hot]:
                        owners[mv] = cold
                        changed = True
                        continue
                # a single atomic key hotter than the mean is the floor
                frozen[hot] = True
        if not changed:
            return table
        # merge: adjacent ranges with the same owner collapse (the
        # "merges cold ones" half of the adaptation)
        keep = np.append(owners[:-1] != owners[1:], True)
        points, owners = points[keep], owners[keep]
        return RoutingTable.from_points(points, owners, n, table.epoch + 1)


_STRATEGIES = {
    "static": StaticHashStrategy,
    "consistent": ConsistentHashStrategy,
    "skew": SkewAwareStrategy,
}


def get_strategy(name_or_instance) -> PartitionStrategy:
    """Resolve a strategy by name ("static" | "consistent" | "skew"),
    passing instances through; "" / None mean static."""
    if isinstance(name_or_instance, PartitionStrategy):
        return name_or_instance
    name = name_or_instance or "static"
    try:
        return _STRATEGIES[name]()
    except KeyError:
        raise ValueError(f"unknown partition strategy {name!r}; "
                         f"known: {sorted(_STRATEGIES)}") from None


# ================================================================== assignment
class PartitionAssignment:
    """business-key partitions → worker assignment with rebalancing
    (paper §3.2: on failure/scale events the coordinator reassigns and the
    cache-migration trigger fires for workers whose key set changed).

    ``rebalance`` is a sticky, load-aware greedy LPT: partitions are
    placed heaviest-first onto the least-loaded worker, preferring the
    current owner among equals — so survivors keep their partitions (and
    their caches) and a scale event moves ~1/n_workers of the load, where
    the old round-robin reshuffle moved nearly everything."""

    def __init__(self, n_partitions: int, workers: Sequence[str]):
        self.n_partitions = n_partitions
        self.assignment: Dict[int, str] = {}
        self.rebalance(list(workers))

    def rebalance(self, workers: List[str],
                  weights: Optional[np.ndarray] = None,
                  slack: float = 1.1) -> Dict[str, List[int]]:
        """Reassign all partitions across ``workers``; ``weights`` (one
        non-negative load figure per partition, e.g. observed records)
        drives the balance — uniform when omitted. Sticky: walking the
        partitions heaviest-first, the CURRENT owner keeps a partition as
        long as its projected load stays within ``slack`` × the balanced
        mean — so a partition only moves when balance demands it (every
        move costs its new owner a cache migration); the remainder fills
        least-loaded-first. Returns ``{worker: sorted gained partitions}``
        with EVERY worker present (an empty list means nothing moved to
        it), so callers can fire cache-migration triggers without
        special-casing survivors."""
        if not workers:
            raise ValueError("no workers alive")
        n = self.n_partitions
        if weights is None:
            wts = np.ones(n)
        else:
            wts = np.asarray(weights, np.float64)
            assert len(wts) == n, "one weight per partition"
            wts = np.maximum(wts, 0.0)
        target = slack * wts.sum() / len(workers)
        # count budget keeps zero-weight partitions spread too (future
        # load has to land somewhere)
        count_target = max(1, int(np.ceil(slack * n / len(workers))))
        old = dict(self.assignment)
        load = {w: 0.0 for w in workers}
        count = {w: 0 for w in workers}
        rank = {w: i for i, w in enumerate(workers)}
        for p in np.argsort(-wts, kind="stable"):
            p = int(p)
            ow = old.get(p)
            if ow in load and load[ow] + wts[p] <= target \
                    and count[ow] < count_target:
                best = ow
            else:
                best = min(workers,
                           key=lambda w: (load[w],
                                          0 if ow == w else 1,
                                          count[w], rank[w]))
            self.assignment[p] = best
            load[best] += float(wts[p])
            count[best] += 1
        changed: Dict[str, List[int]] = {w: [] for w in workers}
        for p, w in self.assignment.items():
            if old.get(p) != w:
                changed[w].append(p)
        return {w: sorted(ps) for w, ps in changed.items()}

    def grow(self, n_partitions: int) -> None:
        """Adopt an expanded partition count (new partitions are assigned
        on the next ``rebalance``)."""
        assert n_partitions >= self.n_partitions
        self.n_partitions = n_partitions

    def partitions_of(self, worker: str) -> List[int]:
        return sorted(p for p, w in self.assignment.items() if w == worker)

    def worker_of(self, partition: int) -> str:
        return self.assignment[partition]
