"""Log-based Change Data Capture (paper §3.1.1).

``ChangeLog`` models the source database's append-only binlog: *one shared
log for all tables* (MySQL semantics — the property behind the Listener
saturation in the paper's Fig. 5: every Listener scans the whole log and
filters its own table). Writes go through ``apply`` exactly as a database
would serialize transactions; the production tables themselves live in
``SourceDatabase`` and are NEVER read by the ETL path — only the log is.

``SourceDatabase.lookup_*`` exists solely for the *baseline* stream
processor (the paper's unmodified-framework comparison), which performs
look-backs against the source; DOD-ETL never calls it.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.records import OP_INSERT, RecordBatch


class ChangeLog:
    """Append-only shared change log with LSN ordering.

    Every ``append`` is stamped with a monotonic wall-clock *event time*.
    This is where a record's end-to-end freshness clock starts: the
    concurrent runtime (``repro.runtime.cluster``) computes per-record
    latency as ``load_time - event_time(lsn)``, so the reported p50/p95/p99
    freshness covers the whole Fig. 2 path — extraction, queueing,
    buffering, transform and warehouse load."""

    def __init__(self):
        self._batches: List[RecordBatch] = []
        self._next_lsn = 0
        self._lock = threading.Lock()
        # event-time stamps: one (first_lsn, append_time) entry per append
        self._seg_lsns: List[int] = []
        self._seg_times: List[float] = []

    @property
    def next_lsn(self) -> int:
        return self._next_lsn

    @staticmethod
    def clock() -> float:
        """The log's monotonic clock (seconds). Latency consumers must
        subtract ``event_times`` from THIS clock, not ``time.time()``."""
        return time.perf_counter()

    def append(self, batch: RecordBatch) -> Tuple[int, int]:
        """Assigns LSNs; returns (first_lsn, next_lsn)."""
        with self._lock:
            n = len(batch)
            batch.lsn[:] = np.arange(self._next_lsn, self._next_lsn + n)
            first = self._next_lsn
            self._next_lsn += n
            self._batches.append(batch)
            self._seg_lsns.append(first)
            self._seg_times.append(self.clock())
            return first, self._next_lsn

    def event_times(self, lsns: np.ndarray) -> np.ndarray:
        """Event-time stamp (seconds on ``clock()``) for each LSN, at append
        granularity: every record of one ``append`` shares its stamp."""
        with self._lock:
            seg_lsns = np.asarray(self._seg_lsns, np.int64)
            seg_times = np.asarray(self._seg_times, np.float64)
        if not len(seg_lsns):
            return np.zeros(len(lsns), np.float64)
        idx = np.clip(np.searchsorted(seg_lsns, lsns, side="right") - 1,
                      0, len(seg_lsns) - 1)
        return seg_times[idx]

    def read_from(self, lsn: int, limit: Optional[int] = None
                  ) -> Tuple[RecordBatch, int]:
        """Sequential scan from ``lsn`` (a Listener never touches tables).

        Returns (batch, records_scanned). ``records_scanned`` counts every
        log entry visited — the Fig. 5 cost model: reading the shared log is
        O(total log), not O(own-table entries). The in-memory constant is
        kept small: appends assign monotonically increasing LSNs, so the
        segment index bisects straight to the first relevant batch, only a
        boundary batch needs row filtering, and the result needs no re-sort
        (the 'seek' over older segments is still billed to ``scanned``).
        """
        with self._lock:              # appends race with Listener scans
            batches = list(self._batches)
            seg_lsns = np.asarray(self._seg_lsns, np.int64)
        start = int(np.searchsorted(seg_lsns, lsn, side="right")) - 1
        start = max(start, 0)
        # skipped-over segments: seek cost, still "on disk" for Fig. 5
        scanned = int(seg_lsns[start]) if len(seg_lsns) else 0
        out = []
        for b in batches[start:]:
            if len(b) == 0 or b.lsn[-1] < lsn:
                scanned += len(b)
                continue
            if b.lsn[0] >= lsn:
                out.append(b)                   # whole batch: zero-copy
                scanned += len(b)
            else:
                mask = b.lsn >= lsn             # boundary batch only
                scanned += int(mask.sum())
                out.append(b.filter(mask))
        batch = RecordBatch.concat(out)         # append order IS lsn order
        if limit is not None and len(batch) > limit:
            batch = batch.take(np.arange(limit))
        return batch, scanned

    def size(self) -> int:
        return self._next_lsn


class SourceDatabase:
    """Production tables + binlog. ``apply`` is the transactional write path
    (table update + log append). The impact model: every ``lookup`` performed
    by a non-CDC consumer adds contention units, which the benchmarks report
    as 'source load' — DOD-ETL's is zero by construction (paper Table 1:
    log-based CDC removes extraction pressure)."""

    def __init__(self):
        self.log = ChangeLog()
        self.tables: Dict[int, Dict[int, np.ndarray]] = {}
        self.table_txn: Dict[int, Dict[int, int]] = {}
        self.lookup_count = 0       # baseline-induced source pressure
        self.scan_count = 0

    def apply(self, batch: RecordBatch) -> None:
        tbl = self.tables
        for i in range(len(batch)):
            t = int(batch.table_id[i])
            tbl.setdefault(t, {})[int(batch.row_key[i])] = batch.payload[i]
            self.table_txn.setdefault(t, {})[int(batch.row_key[i])] = \
                int(batch.txn_time[i])
        self.log.append(batch)

    # ------------------------------------------------------------ baseline
    def lookup_row(self, table_id: int, row_key: int) -> Optional[np.ndarray]:
        self.lookup_count += 1
        return self.tables.get(table_id, {}).get(row_key)

    def scan_table(self, table_id: int) -> Dict[int, np.ndarray]:
        self.scan_count += 1
        self.lookup_count += len(self.tables.get(table_id, {}))
        return self.tables.get(table_id, {})
