"""Log-based Change Data Capture (paper §3.1.1).

``ChangeLog`` models the source database's append-only binlog: *one shared
log for all tables* (MySQL semantics — the property behind the Listener
saturation in the paper's Fig. 5: every Listener scans the whole log and
filters its own table). Writes go through ``apply`` exactly as a database
would serialize transactions; the production tables themselves live in
``SourceDatabase`` and are NEVER read by the ETL path — only the log is.

``SourceDatabase.lookup_*`` exists solely for the *baseline* stream
processor (the paper's unmodified-framework comparison), which performs
look-backs against the source; DOD-ETL never calls it.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.records import OP_INSERT, RecordBatch


class ChangeLog:
    """Append-only shared change log with LSN ordering."""

    def __init__(self):
        self._batches: List[RecordBatch] = []
        self._next_lsn = 0
        self._lock = threading.Lock()

    @property
    def next_lsn(self) -> int:
        return self._next_lsn

    def append(self, batch: RecordBatch) -> Tuple[int, int]:
        """Assigns LSNs; returns (first_lsn, next_lsn)."""
        with self._lock:
            n = len(batch)
            batch.lsn[:] = np.arange(self._next_lsn, self._next_lsn + n)
            first = self._next_lsn
            self._next_lsn += n
            self._batches.append(batch)
            return first, self._next_lsn

    def read_from(self, lsn: int, limit: Optional[int] = None
                  ) -> Tuple[RecordBatch, int]:
        """Sequential scan from ``lsn`` (a Listener never touches tables).

        Returns (batch, records_scanned). ``records_scanned`` counts every
        log entry visited — the Fig. 5 cost model: reading the shared log is
        O(total log), not O(own-table entries).
        """
        out = []
        scanned = 0
        for b in self._batches:
            if len(b) == 0 or b.lsn[-1] < lsn:
                scanned += len(b)  # skipped via index seek; still on disk
                continue
            mask = b.lsn >= lsn
            scanned += int(mask.sum())
            out.append(b.filter(mask))
        batch = RecordBatch.concat(out).sort_by_lsn()
        if limit is not None and len(batch) > limit:
            batch = batch.take(np.arange(limit))
        return batch, scanned

    def size(self) -> int:
        return self._next_lsn


class SourceDatabase:
    """Production tables + binlog. ``apply`` is the transactional write path
    (table update + log append). The impact model: every ``lookup`` performed
    by a non-CDC consumer adds contention units, which the benchmarks report
    as 'source load' — DOD-ETL's is zero by construction (paper Table 1:
    log-based CDC removes extraction pressure)."""

    def __init__(self):
        self.log = ChangeLog()
        self.tables: Dict[int, Dict[int, np.ndarray]] = {}
        self.table_txn: Dict[int, Dict[int, int]] = {}
        self.lookup_count = 0       # baseline-induced source pressure
        self.scan_count = 0

    def apply(self, batch: RecordBatch) -> None:
        tbl = self.tables
        for i in range(len(batch)):
            t = int(batch.table_id[i])
            tbl.setdefault(t, {})[int(batch.row_key[i])] = batch.payload[i]
            self.table_txn.setdefault(t, {})[int(batch.row_key[i])] = \
                int(batch.txn_time[i])
        self.log.append(batch)

    # ------------------------------------------------------------ baseline
    def lookup_row(self, table_id: int, row_key: int) -> Optional[np.ndarray]:
        self.lookup_count += 1
        return self.tables.get(table_id, {}).get(row_key)

    def scan_table(self, table_id: int) -> Dict[int, np.ndarray]:
        self.scan_count += 1
        self.lookup_count += len(self.tables.get(table_id, {}))
        return self.tables.get(table_id, {})
