"""Listener + Message Producer (paper §3.1.1, Change Tracker).

One Listener per extracted table. Each Listener scans the *shared* CDC log
from its own offset and filters its table's records — MySQL-binlog
semantics, which is exactly why the paper's Fig. 5 saturates: the scan cost
is O(total log), the yield is O(own-table records). Listeners never query
production tables.

The Message Producer partitions extracted records per the table nature
(master -> row key, operational -> business key) and publishes to the queue.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.dod_etl import ETLConfig, TableConfig
from repro.core.cdc import ChangeLog
from repro.core.message_queue import MessageQueue, TopicConfig
from repro.core.records import RecordBatch


class Listener:
    def __init__(self, table: TableConfig, table_id: int, log: ChangeLog,
                 queue: MessageQueue, topic: str):
        self.table = table
        self.table_id = table_id
        self.log = log
        self.queue = queue
        self.topic = topic
        self.offset = 0              # LSN position in the shared log
        self.records_extracted = 0
        self.records_scanned = 0
        self.wall_s = 0.0

    def poll(self, limit: Optional[int] = None) -> int:
        """One extraction round: scan log from offset, filter own table,
        publish. Returns records extracted."""
        t0 = time.perf_counter()
        batch, scanned = self.log.read_from(self.offset, limit)
        self.records_scanned += scanned
        if len(batch):
            mine = batch.filter(batch.table_id == self.table_id)
            if len(mine):
                self.queue.publish(self.topic, mine)
                self.records_extracted += len(mine)
            # advance the offset only AFTER publishing: extraction-lag
            # watchers treat `offset == log head` as "everything scanned is
            # in the queue", so the reverse order opened a window where a
            # drain check could declare the stream complete mid-publish
            self.offset = int(batch.lsn[-1]) + 1
            n = len(mine)
        else:
            n = 0
        self.wall_s += time.perf_counter() - t0
        return n


class ChangeTracker:
    """All Listeners for a deployment + topic bootstrap."""

    def __init__(self, cfg: ETLConfig, log: ChangeLog, queue: MessageQueue):
        self.cfg = cfg
        self.listeners: List[Listener] = []
        self.table_ids: Dict[str, int] = {}
        # extraction lock: a durability capture acquires it so listener
        # offsets and broker content are snapshotted at a poll boundary —
        # capturing between a Listener's publish and its offset advance
        # would journal the records AND an offset that re-extracts them
        # (duplicates on replay)
        self.lock = threading.Lock()
        for tid, table in enumerate(cfg.tables):
            self.table_ids[table.name] = tid
            topic_name = f"topic.{table.name}"
            queue.create_topic(TopicConfig(
                name=topic_name,
                table_id=tid,
                n_partitions=cfg.n_partitions,
                partition_by=("business_key" if table.nature == "operational"
                              else "row_key"),
                compacted=table.nature == "master",
            ))
            self.listeners.append(Listener(table, tid, log, queue, topic_name))

    def poll_all(self, limit_per_table: Optional[int] = None) -> int:
        """One extraction round over every Listener. Master tables are
        polled FIRST: their records feed the In-memory caches (§3.1.2), so
        giving them extraction priority warms caches before the operational
        records that join against them — fewer records take the late-buffer
        detour on a cold start."""
        ordered = sorted(self.listeners,
                         key=lambda l: l.table.nature != "master")
        with self.lock:
            return sum(l.poll(limit_per_table) for l in ordered)

    def topic_of(self, table_name: str) -> str:
        return f"topic.{table_name}"
