"""Operational Message Buffer (paper §3.1.2 / §3.2, 'unsynchronized
consistency').

Operational records whose master data has not yet arrived are buffered with
their transaction time. At each new operational batch the Data Transformer
retries exactly the buffered records whose ``txn_time`` is older than the
In-memory cache watermark ('only reprocesses buffer messages with
transaction dates older than the latest transaction date from the In-memory
cache, which avoids reprocessing operational messages that still have no
master data').

The buffer state lives in the coordinator's replicated store (the paper used
Zookeeper) so any worker can resume reprocessing after a failure: on a
§4.1.3 failover, ``repro.runtime.cluster.ConcurrentCluster`` drains a dead
worker's buffer into a survivor and re-homes every buffered record to its
partition's current owner.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.records import RecordBatch


class OperationalMessageBuffer:
    def __init__(self, capacity: int):
        self.capacity = capacity
        self._batch: RecordBatch = RecordBatch.empty()
        self.dropped = 0
        self.total_buffered = 0
        self.total_retried = 0

    def __len__(self) -> int:
        return len(self._batch)

    def push(self, late: RecordBatch) -> None:
        if not len(late):
            return
        self.total_buffered += len(late)
        merged = RecordBatch.concat([self._batch, late])
        if len(merged) > self.capacity:
            # drop oldest beyond capacity (recorded; tests assert zero drops
            # under the paper's workloads)
            self.dropped += len(merged) - self.capacity
            merged = merged.take(np.arange(len(merged) - self.capacity,
                                           len(merged)))
        self._batch = merged

    def pop_ready(self, watermark: int,
                  limit: Optional[int] = None) -> RecordBatch:
        """Remove and return records eligible for retry (txn_time <=
        watermark). ``limit`` bounds one retry sweep (oldest-first), so a
        mass-late cold start is drained in micro-batches instead of one
        giant dispatch."""
        if not len(self._batch):
            return RecordBatch.empty()
        ready_mask = self._batch.txn_time <= watermark
        if limit is not None and ready_mask.sum() > limit:
            keep_off = np.nonzero(ready_mask)[0][limit:]
            ready_mask = ready_mask.copy()
            ready_mask[keep_off] = False
        ready = self._batch.filter(ready_mask)
        self._batch = self._batch.filter(~ready_mask)
        self.total_retried += len(ready)
        return ready

    def drain(self) -> RecordBatch:
        """Remove and return ALL buffered records (failover handoff: a dead
        worker's replicated buffer is adopted by a survivor)."""
        out = self._batch
        self._batch = RecordBatch.empty()
        return out

    # ---------------------------------------------------------- durability
    def export_state(self) -> dict:
        return {"batch": self._batch.as_dict(), "dropped": self.dropped}

    @staticmethod
    def restore(state: dict, capacity: int) -> "OperationalMessageBuffer":
        buf = OperationalMessageBuffer(capacity)
        buf._batch = RecordBatch(**{k: np.asarray(v)
                                    for k, v in state["batch"].items()})
        buf.dropped = state.get("dropped", 0)
        return buf


class DeadLetterBuffer:
    """Quarantine for poison records — operational records whose transform
    deterministically raises. Instead of crash-looping the worker, the load
    stage commits their offsets (a quarantined record counts as *handled*:
    it will never replay) and parks the records here for operator triage.

    Append-only during a run; ``drain()`` is the operator's exit (see
    docs/OPERATIONS.md). Exported/restored with worker state so a
    checkpoint+recovery cannot silently lose quarantined records whose
    offsets are already committed."""

    def __init__(self):
        self._batch: RecordBatch = RecordBatch.empty()
        self.reasons: list = []
        self.total_quarantined = 0

    def __len__(self) -> int:
        return len(self._batch)

    def push(self, dead: RecordBatch, reason: str = "transform-error") -> None:
        if not len(dead):
            return
        self.total_quarantined += len(dead)
        self.reasons.append({"reason": reason, "records": int(len(dead))})
        self._batch = RecordBatch.concat([self._batch, dead])

    def peek(self) -> RecordBatch:
        return self._batch

    def drain(self) -> RecordBatch:
        out = self._batch
        self._batch = RecordBatch.empty()
        self.reasons = []
        return out

    # ---------------------------------------------------------- durability
    def export_state(self) -> dict:
        return {"batch": self._batch.as_dict(),
                "reasons": list(self.reasons),
                "total": self.total_quarantined}

    @staticmethod
    def restore(state: Optional[dict]) -> "DeadLetterBuffer":
        dlq = DeadLetterBuffer()
        if state is None:     # journal predates the dead-letter plane
            return dlq
        dlq._batch = RecordBatch(**{k: np.asarray(v)
                                    for k, v in state["batch"].items()})
        dlq.reasons = list(state.get("reasons", []))
        dlq.total_quarantined = int(state.get("total", len(dlq._batch)))
        return dlq
