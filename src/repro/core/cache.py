"""In-memory master-data cache (paper §3.1.2, In-memory Table Updater).

The paper gives each Spark worker an embedded H2 instance holding only the
master rows for its assigned business keys. On TPU the worker-local store is
a device-resident open-addressing hash table:

  keys   : i64 [n_slots]   (-1 = empty)   — the JOIN key of the table
  values : f32 [n_slots, W]               — master row payload
  txn    : i64 [n_slots]                  — row transaction time (watermark)

Slot assignment happens host-side at update time (updates are rare next to
lookups); the hot path — the probe inside the Data Transformer — goes
through the pluggable compute-backend layer (``repro.core.backend``):
``numpy`` host probing, ``jax`` jitted linear probing (``lookup_ref``
below), or the Pallas ``hash_join`` kernel on TPU. All three are
contract-identical.

Fault tolerance / elasticity (paper §3.2): ``reset_from_snapshot`` re-dumps
the compacted master topic filtered by the newly assigned business keys —
the 'cache reset trigger'. The measured cost of this dump is the Fig. 4
initialization overhead.
"""
from __future__ import annotations

from typing import Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.records import PAYLOAD_WIDTH

MAX_PROBES = 16


def hash32_np(keys: np.ndarray) -> np.ndarray:
    """32-bit mix (lowbias32), identical on host and device — JAX runs with
    x64 disabled, so the cache hash must be 32-bit exact on both sides."""
    with np.errstate(over="ignore"):
        x = (np.asarray(keys).astype(np.int64) & 0xFFFFFFFF).astype(np.uint32)
        x ^= x >> np.uint32(16)
        x *= np.uint32(0x7FEB352D)
        x ^= x >> np.uint32(15)
        x *= np.uint32(0x846CA68B)
        x ^= x >> np.uint32(16)
    return x


def hash32_jnp(keys: jax.Array) -> jax.Array:
    x = keys.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


class InMemoryTable:
    def __init__(self, n_slots: int, width: int = PAYLOAD_WIDTH,
                 backend=None):
        self.n_slots = n_slots
        self.width = width
        self._backend = backend          # name/instance; resolved lazily
        self.keys = np.full(n_slots, -1, np.int32)
        self.values = np.zeros((n_slots, width), np.float32)
        self.txn = np.zeros(n_slots, np.int64)
        self.watermark = 0           # latest master txn_time seen
        self.n_rows = 0
        self.init_dump_s = 0.0       # Fig. 4: cache initialization overhead
        self._device = None          # lazily mirrored jnp arrays
        self._dirty = {"keys", "values", "txn"}   # components to re-upload
        self.version = 0             # bumped on every mutation
        self._snap = None            # memoized CacheSnapshot
        self._snap_version = -1

    # ------------------------------------------------------------ updates
    def _slot_of(self, key: int) -> int:
        """Find the key's slot within the device probe budget; grow+rehash
        when a chain would exceed MAX_PROBES (the jitted lookup stops there,
        so a longer host-side chain would make the row invisible)."""
        key32 = int(np.int32(np.int64(key) & 0xFFFFFFFF))
        while True:
            h = int(hash32_np(np.array([key32]))[0] % self.n_slots)
            for p in range(MAX_PROBES):
                s = (h + p) % self.n_slots
                k = self.keys[s]
                if k == -1 or k == key32:
                    return s
            self._grow()

    def _grow(self) -> None:
        old_keys, old_vals, old_txn = self.keys, self.values, self.txn
        self.n_slots *= 2
        self.keys = np.full(self.n_slots, -1, np.int32)
        self.values = np.zeros((self.n_slots, self.width), np.float32)
        self.txn = np.zeros(self.n_slots, np.int64)
        self.n_rows = 0
        live = np.nonzero(old_keys != -1)[0]
        for s in live:
            d = self._slot_of(int(old_keys[s]))
            if self.keys[d] == -1:
                self.n_rows += 1
            self.keys[d] = old_keys[s]
            self.values[d] = old_vals[s]
            self.txn[d] = old_txn[s]
        self._device = None
        self._dirty = {"keys", "values", "txn"}

    def upsert(self, keys: np.ndarray, payloads: np.ndarray,
               txn_times: np.ndarray) -> None:
        """Last-writer-wins BY TRANSACTION TIME (not arrival order): cache
        state is then independent of snapshot/stream interleaving — the
        property the §4.1.3 consistency check relies on.

        Fully vectorized (one hash pass + one probe loop over MAX_PROBES
        steps for the whole batch): the per-row Python loop this replaces
        cost ~19us/row and sat on the GIL inside every worker's ingest
        stage — the master pump was the single largest host cost of a
        streaming step."""
        n = len(keys)
        if n == 0:
            return
        keys = np.asarray(keys, np.int64)
        txn_times = np.asarray(txn_times, np.int64)
        payloads = np.asarray(payloads, np.float32)
        # watermark advances over ALL arriving rows, stale or not (same as
        # the per-row loop: it tracked skipped rows' txn times too)
        self.watermark = max(self.watermark, int(txn_times.max()))

        # one winner per key: latest txn_time, arrival order breaking ties
        # (identical to applying the rows one by one)
        order = np.lexsort((np.arange(n), txn_times, keys))
        last = np.nonzero(np.append(keys[order][1:] != keys[order][:-1],
                                    True))[0]
        win = order[last]
        key32 = (keys[win] & 0xFFFFFFFF).astype(np.int32)
        vals, txns = payloads[win], txn_times[win]

        wrote_vals = False           # any slot payload/txn written
        wrote_keys = False           # any NEW key claimed a slot
        while True:
            h = (hash32_np(key32) % np.uint32(self.n_slots)).astype(np.int64)
            pending = np.arange(len(key32))
            for p in range(MAX_PROBES):
                if not len(pending):
                    break
                cand = (h[pending] + p) % self.n_slots
                slot_keys = self.keys[cand]
                # existing slot for this key: overwrite unless stale
                hit = slot_keys == key32[pending]
                upd = pending[hit][txns[pending[hit]] >=
                                   self.txn[cand[hit]]]
                if len(upd):
                    s = (h[upd] + p) % self.n_slots
                    self.keys[s] = key32[upd]
                    self.values[s] = vals[upd]
                    self.txn[s] = txns[upd]
                    wrote_vals = True    # key lane rewritten with the SAME
                                         # content — values/txn dirty only
                # empty slot: first distinct key per slot claims it, the
                # rest continue probing (a valid sequential insert order)
                empty = np.nonzero(slot_keys == -1)[0]
                claimed = np.zeros(len(pending), bool)
                if len(empty):
                    uniq_slots, first = np.unique(cand[empty],
                                                  return_index=True)
                    winners = pending[empty[first]]
                    s = (h[winners] + p) % self.n_slots
                    self.keys[s] = key32[winners]
                    self.values[s] = vals[winners]
                    self.txn[s] = txns[winners]
                    self.n_rows += len(winners)
                    claimed[empty[first]] = True
                    wrote_keys = wrote_vals = True
                pending = pending[~(hit | claimed)]
            if not len(pending):
                break
            # probe chains exhausted: grow + rehash, retry the remainder
            keep = pending
            key32, vals, txns = key32[keep], vals[keep], txns[keep]
            self._grow()
        # device-mirror reuse: re-upload ONLY the components this upsert
        # touched. Steady-state master updates overwrite existing rows'
        # payloads, so the (large, rarely changing) key lane keeps its
        # device buffer; an all-stale batch re-uploads nothing at all.
        if wrote_keys:
            self._dirty.add("keys")
        if wrote_vals:
            self._dirty.update(("values", "txn"))
        self.version += 1

    def retain_only(self, keep_bkeys: np.ndarray,
                    bk_col: int = 1) -> Tuple[int, int]:
        """Surgical cache migration, drop side: keep ONLY the rows whose
        business key (``values[:, bk_col]`` — every master payload carries
        its equipment/business key there) is in ``keep_bkeys``; rows of
        moved-away key ranges are dropped. Returns (kept, dropped) row
        counts.

        Open addressing cannot delete in place (an emptied slot would cut
        the probe chains of keys hashed past it, making them invisible to
        the bounded device probe), so the retained rows are re-inserted
        through the vectorized ``upsert`` — still a pure LOCAL operation:
        unlike the paper's cache-reset trigger it never touches the broker
        snapshot, which is exactly what makes a rebalance keep its
        survivors warm. The watermark is preserved (it tracks the master
        STREAM, not this worker's slice of it)."""
        live = np.nonzero(self.keys != -1)[0]
        if not len(live):
            return 0, 0
        bks = self.values[live, bk_col].astype(np.int64)
        keep_sorted = np.unique(np.asarray(keep_bkeys, np.int64))
        from repro.core.partitioning import isin_sorted
        mask = isin_sorted(keep_sorted, bks)
        kept = live[mask]
        dropped = len(live) - len(kept)
        if dropped == 0:
            return len(kept), 0
        keys = self.keys[kept].astype(np.int64)   # fancy index: copies
        vals = self.values[kept]
        txns = self.txn[kept]
        watermark = self.watermark
        self.keys[:] = -1
        self.values[:] = 0
        self.txn[:] = 0
        self.n_rows = 0
        self._dirty = {"keys", "values", "txn"}
        self.version += 1
        if len(kept):
            self.upsert(keys, vals, txns)
        self.watermark = watermark
        return len(kept), dropped

    def reset_from_snapshot(self, row_keys: np.ndarray, payloads: np.ndarray,
                            txn_times: np.ndarray) -> float:
        """Paper's cache-reset trigger: wipe + re-dump compacted snapshot.
        Returns the dump wall time (Fig. 4)."""
        import time
        t0 = time.perf_counter()
        self.keys[:] = -1
        self.values[:] = 0
        self.txn[:] = 0
        self.n_rows = 0
        self.watermark = 0
        self._dirty = {"keys", "values", "txn"}
        self.version += 1
        self.upsert(row_keys, payloads, txn_times)
        self.init_dump_s = time.perf_counter() - t0
        return self.init_dump_s

    # ------------------------------------------------------------ metrics
    def stats(self) -> Dict[str, float]:
        """Health-snapshot view of the table: occupancy, mutation version,
        watermark and the last re-dump cost. Lock-free — every field is
        one GIL-atomic read."""
        return {"rows": self.n_rows, "slots": self.n_slots,
                "fill": round(self.n_rows / self.n_slots, 4)
                if self.n_slots else 0.0,
                "version": self.version, "watermark": self.watermark,
                "init_dump_s": round(self.init_dump_s, 6)}

    # ------------------------------------------------------------ lookups
    def device_state(self) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Device mirror of (keys, values, txn), component-dirty tracked:
        only arrays whose host content changed since the last mirror are
        re-uploaded (``jnp.asarray`` COPIES host->device, so mirrors
        already pinned by older ``CacheSnapshot``s stay immutable). A
        steady-state bucket whose master data hasn't moved re-uploads
        nothing — the device arrays are reused dispatch after dispatch."""
        if self._device is None or self._dirty:
            k, v, t = self._device or (None, None, None)
            if k is None or "keys" in self._dirty:
                k = jnp.asarray(self.keys)
            if v is None or "values" in self._dirty:
                v = jnp.asarray(self.values)
            if t is None or "txn" in self._dirty:
                t = jnp.asarray(self.txn)
            self._device = (k, v, t)
            self._dirty.clear()
        return self._device

    def snapshot_view(self, device: bool) -> "CacheSnapshot":
        """Immutable point-in-time view for LOCK-FREE probing. The caller
        holds the cache lock only for this call; the returned snapshot is
        safe to probe while concurrent upserts mutate the live table. For
        device backends it pins the (immutable) device mirror; for host
        backends it copies the arrays. Memoized per `version`, so in steady
        state (master data changes rarely — the paper's premise) it is a
        few attribute reads."""
        if self._snap is None or self._snap_version != (self.version,
                                                        device):
            if device:
                state = self.device_state()
                self._snap = CacheSnapshot(None, None, None, self.watermark,
                                           state, backend=self._backend)
            else:
                self._snap = CacheSnapshot(
                    self.keys.copy(), self.values.copy(), self.txn.copy(),
                    self.watermark, None, backend=self._backend)
            self._snap_version = (self.version, device)
        return self._snap


class CacheSnapshot:
    """Frozen view of an ``InMemoryTable`` (see ``snapshot_view``): exactly
    the read surface the compute backends touch, nothing else."""

    __slots__ = ("keys", "values", "txn", "watermark", "_device", "_backend")

    def __init__(self, keys, values, txn, watermark, device, backend=None):
        self.keys = keys
        self.values = values
        self.txn = txn
        self.watermark = watermark
        self._device = device
        self._backend = backend      # name/instance; resolved lazily

    def device_state(self):
        return self._device

    @property
    def backend(self):
        """Resolved ComputeBackend (explicit > config/env default)."""
        from repro.core.backend import ComputeBackend, get_backend
        if not isinstance(self._backend, ComputeBackend):
            self._backend = get_backend(self._backend)
        return self._backend

    def lookup(self, query_keys
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized probe through the compute backend. Returns host
        (values [n, W], found [n] bool, txn_times [n])."""
        be = self.backend
        state = (self.device_state() if be.device
                 else (self.keys, self.values, self.txn))
        return be.hash_probe(query_keys, *state)


@jax.jit
def lookup_ref(query_keys: jax.Array, keys_tbl: jax.Array,
               vals_tbl: jax.Array, txn_tbl: jax.Array
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pure-jnp linear probing (oracle twin of kernels/hash_join).

    The probe scan touches ONLY the key lane (4 B/slot/step); the winning
    slot index is carried through the scan and the 32 B value rows + txn
    are gathered ONCE at the end. Probing is memory-bound, so the narrow
    scan is both faster and far kinder to concurrent worker threads
    sharing a memory bus than gathering full rows every step."""
    n_slots = keys_tbl.shape[0]
    q = query_keys.astype(jnp.int32)
    h = (hash32_jnp(q) % jnp.uint32(n_slots)).astype(jnp.int32)

    def probe(carry, p):
        done, idx = carry
        cand = (h + p) % n_slots
        k = keys_tbl[cand]
        hit = (k == q) & (~done)
        empty = (k == -1) & (~done)
        idx = jnp.where(hit, cand, idx)
        done = done | hit | empty    # stop probing on hit or empty slot
        return (done, idx), None

    n = q.shape[0]
    init = (jnp.zeros(n, bool), jnp.full(n, -1, jnp.int32))
    (done, idx), _ = jax.lax.scan(probe, init, jnp.arange(MAX_PROBES))
    found = idx >= 0
    safe = jnp.maximum(idx, 0)
    val = jnp.where(found[:, None], vals_tbl[safe], 0)
    txn = jnp.where(found, txn_tbl[safe], 0)
    return val, found, txn
