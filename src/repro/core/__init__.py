"""DOD-ETL core: the paper's contribution as a composable library.

Change Tracker (cdc + listener) -> Message Queue (partitioned topics with
compaction) -> Stream Processor (In-memory Table Updater = cache, Data
Transformer = transformer + buffer, Target Database Updater = loader),
wired by pipeline; baseline is the unmodified-framework comparison point.
"""
from repro.core.records import RecordBatch, make_batch, PAYLOAD_WIDTH  # noqa: F401
from repro.core.backend import (  # noqa: F401
    ComputeBackend,
    FactBlock,
    available_backends,
    get_backend,
    register_backend,
)
from repro.core.cdc import ChangeLog, SourceDatabase  # noqa: F401
from repro.core.message_queue import MessageQueue, Topic, TopicConfig  # noqa: F401
from repro.core.listener import ChangeTracker, Listener  # noqa: F401
from repro.core.cache import InMemoryTable, lookup_ref  # noqa: F401
from repro.core.buffer import OperationalMessageBuffer  # noqa: F401
from repro.core.transformer import (  # noqa: F401
    DataTransformer,
    transform_kernel,
    FACT_COLUMNS,
)
from repro.core.loader import StarSchemaWarehouse, WarehouseView  # noqa: F401
from repro.core.metrics import LatencyRecorder, percentiles_ms  # noqa: F401
from repro.core.pipeline import DODETLPipeline, StreamProcessorWorker  # noqa: F401
from repro.core.baseline import BaselineStreamProcessor  # noqa: F401
from repro.core.partitioning import (  # noqa: F401
    PartitionAssignment,
    PartitionStrategy,
    RoutingTable,
    get_strategy,
    hash_key,
    partition_of,
)
