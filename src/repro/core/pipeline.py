"""DOD-ETL pipeline orchestration (paper Fig. 2).

Wires Change Tracker -> Message Queue -> Stream Processor (In-memory Table
Updater + Data Transformer + Target Database Updater) for one worker set,
with the paper's fault-tolerance semantics: restartable consumption
(committed offsets), compacted-snapshot cache recovery, replicated late
buffer, and the cache-reset trigger on partition reassignment.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.dod_etl import ETLConfig
from repro.core.backend import get_backend
from repro.core.buffer import OperationalMessageBuffer
from repro.core.cache import InMemoryTable
from repro.core.cdc import SourceDatabase
from repro.core.listener import ChangeTracker
from repro.core.message_queue import MessageQueue
from repro.core.loader import StarSchemaWarehouse
from repro.core.partitioning import (PartitionAssignment, isin_sorted,
                                     partition_of)
from repro.core.records import RecordBatch
from repro.core.transformer import DataTransformer


@dataclasses.dataclass
class StageMetrics:
    records: int = 0
    wall_s: float = 0.0

    @property
    def rate(self) -> float:
        return self.records / self.wall_s if self.wall_s > 0 else 0.0


class StreamProcessorWorker:
    """One Stream Processor node: assigned business-key partitions, local
    in-memory caches (filtered by assigned keys), transformer + loader.

    Hot path is COALESCED: every step consumes all assigned partitions into
    one columnar batch and issues ONE device dispatch through the compute
    backend; facts split back per partition only at ``warehouse.load`` time.
    """

    def __init__(self, name: str, cfg: ETLConfig, queue: MessageQueue,
                 warehouse: StarSchemaWarehouse, join_depth: int = 1,
                 backend=None):
        self.name = name
        self.cfg = cfg
        self.queue = queue
        self.warehouse = warehouse
        self.backend = get_backend(backend or cfg.backend or None)
        self._partitions: List[int] = []
        self._bkeys_memo: Dict[int, np.ndarray] = {}
        self.equipment = InMemoryTable(cfg.cache_slots, cfg.cache_row_width,
                                       backend=self.backend)
        self.quality = InMemoryTable(cfg.cache_slots, cfg.cache_row_width,
                                     backend=self.backend)
        self.buffer = OperationalMessageBuffer(cfg.buffer_capacity)
        # n_units wires the fused transform_and_rollup: every transform
        # dispatch also carries the per-unit KPI aggregate (equipment ids
        # ARE the business keys), feeding warehouse.kpi_running in O(1)
        self.transformer = DataTransformer(self.equipment, self.quality,
                                           self.buffer, join_depth,
                                           backend=self.backend,
                                           n_units=cfg.n_business_keys)
        self.metrics = StageMetrics()
        self.group = f"sp.{name}"

    # ----------------------------------------------------------- cache mgmt
    @property
    def partitions(self) -> tuple:
        # a copy: in-place mutation would bypass the setter and leave the
        # business-key memo stale
        return tuple(self._partitions)

    @partitions.setter
    def partitions(self, value) -> None:
        self._partitions = list(value)
        self._bkeys_memo.clear()     # reassignment invalidates the key memo

    def assigned_business_keys(self, n_business_keys: int) -> np.ndarray:
        """Sorted i64 array of this worker's business keys, memoized until
        the partition assignment changes (no per-pump set rebuilds)."""
        memo = self._bkeys_memo.get(n_business_keys)
        if memo is None:
            keys = np.arange(n_business_keys, dtype=np.int64)
            parts = partition_of(keys, self.cfg.n_partitions)
            mask = np.isin(parts, np.asarray(self._partitions, np.int32))
            memo = keys[mask]        # arange slice => already sorted
            self._bkeys_memo[n_business_keys] = memo
        return memo

    def _filter_assigned(self, batch: RecordBatch) -> RecordBatch:
        """Vectorized business-key membership via binary search against the
        memoized sorted key array (replaces per-pump ``np.isin`` on a
        freshly rebuilt Python set)."""
        bkeys = self.assigned_business_keys(self.cfg.n_business_keys)
        if not len(bkeys):
            return RecordBatch.empty()
        return batch.filter(isin_sorted(bkeys, batch.business_key))

    def reset_caches(self, master_topics: Dict[str, str],
                     n_business_keys: int) -> float:
        """The paper's trigger: on (re)assignment, dump compacted snapshots
        filtered by assigned business keys. Returns dump seconds (Fig. 4)."""
        bkeys = self.assigned_business_keys(n_business_keys)
        total = 0.0
        for cache, topic_name in (
                (self.equipment, master_topics["equipment"]),
                (self.quality, master_topics["quality"])):
            topic = self.queue.topics[topic_name]
            rks, pls, tts = topic.snapshot(bkeys)
            # quality cache joins by prod_id (payload col 3); equipment by
            # business key (payload col 1)
            if cache is self.quality and len(rks):
                join_keys = pls[:, 3].astype(np.int64)
            elif len(rks):
                join_keys = pls[:, 1].astype(np.int64)
            else:
                join_keys = rks
            total += cache.reset_from_snapshot(join_keys, pls, tts)
        return total

    # ----------------------------------------------------- master ingestion
    def pump_master(self, topic: str, cache: InMemoryTable,
                    max_records: Optional[int] = None) -> int:
        """In-memory Table Updater: consume ALL master partitions as one
        coalesced batch, filter by assigned business keys (vectorized),
        upsert into the local cache in one pass."""
        batch, counts = self.queue.consume_many(
            self.group, topic, self.partitions_for_master(topic), max_records)
        for p, c in counts.items():
            self.queue.commit(self.group, topic, p, c)
        if not len(batch):
            return 0
        mine = self._filter_assigned(batch)
        if not len(mine):
            return 0
        if cache is self.quality:
            join_keys = mine.payload[:, 3].astype(np.int64)
        else:
            join_keys = mine.payload[:, 1].astype(np.int64)
        cache.upsert(join_keys, mine.payload, mine.txn_time)
        return len(mine)

    def partitions_for_master(self, topic: str) -> List[int]:
        # master topics are row-key partitioned: a worker's business keys can
        # live in any partition, so every worker consumes all partitions and
        # filters (exactly the paper's design — the filter is the key step)
        return list(range(self.queue.topics[topic].cfg.n_partitions))

    # ------------------------------------------------------------ transform
    def fetch_operational(self, topic: str, max_records: Optional[int] = None
                          ) -> Tuple[RecordBatch, Dict[int, int]]:
        """Position-advancing coalesced read of this worker's partitions,
        WITHOUT committing (the concurrent runtime's ingest stage; commits
        happen after warehouse load in its load stage). Returns
        (batch, {partition: records_read})."""
        return self.queue.fetch_many(self.group, topic, self.partitions,
                                     max_records)

    def process_operational(self, topic: str, max_records: Optional[int] = None
                            ) -> int:
        """One micro-batch step over this worker's partitions: coalesced
        consume -> ONE fused transform+rollup dispatch (device-resident
        ``FactBlock``) -> materialize at the warehouse-load boundary ->
        split facts per partition at load time, folding the fused per-unit
        KPI rollup into the warehouse's running aggregate. ``max_records``
        still bounds each partition's read so offset/rebalance semantics
        are unchanged."""
        t0 = time.perf_counter()
        batch, counts = self.queue.consume_many(
            self.group, topic, self.partitions, max_records)
        for p, c in counts.items():
            self.queue.commit(self.group, topic, p, c)
        block, merged = self.transformer.process_block(batch)
        if block is None:
            self.metrics.wall_s += time.perf_counter() - t0
            return 0
        block.start_host_copy()          # D2H rides behind the compute
        facts, _ = self.transformer.finish(block, merged)
        done = self.warehouse.load_partitioned(facts, self.cfg.n_partitions,
                                               rollup=block.rollup_host())
        self.metrics.records += done
        self.metrics.wall_s += time.perf_counter() - t0
        return done


class DODETLPipeline:
    """Single-process pipeline over a worker set (the distributed runtime in
    ``repro.runtime`` schedules the same workers with failures/elasticity)."""

    def __init__(self, cfg: ETLConfig, source: SourceDatabase,
                 n_workers: int = 1, join_depth: int = 1, backend=None):
        self.cfg = cfg
        self.source = source
        self.backend = get_backend(backend or cfg.backend or None)
        self.queue = MessageQueue()
        self.tracker = ChangeTracker(cfg, source.log, self.queue)
        self.warehouse = StarSchemaWarehouse(backend=self.backend)
        self.workers = [
            StreamProcessorWorker(f"w{i}", cfg, self.queue, self.warehouse,
                                  join_depth, backend=self.backend)
            for i in range(n_workers)]
        self.assignment = PartitionAssignment(
            cfg.n_partitions, [w.name for w in self.workers])
        self._apply_assignment()
        self.operational_topics = [self.tracker.topic_of(t.name)
                                   for t in cfg.operational_tables]
        self.master_topic_map = self._master_topics()

    def _master_topics(self) -> Dict[str, str]:
        """Logical master role -> topic. The simple schema has 'equipment'
        and 'quality'; the ISA-95 complex schema maps its first two master
        tables onto those roles (extra tables exercise join_depth)."""
        masters = [t for t in self.cfg.tables if t.nature == "master"]
        eq = next((t for t in masters if "equipment" in t.name), masters[0])
        qu = next((t for t in masters if "quality" in t.name), masters[-1])
        return {"equipment": self.tracker.topic_of(eq.name),
                "quality": self.tracker.topic_of(qu.name)}

    def _apply_assignment(self):
        for w in self.workers:
            w.partitions = self.assignment.partitions_of(w.name)

    # ------------------------------------------------------------- running
    def extract(self, limit_per_table: Optional[int] = None) -> int:
        return self.tracker.poll_all(limit_per_table)

    def bootstrap_caches(self) -> float:
        """Initial snapshot dump for every worker (Fig. 4 overhead)."""
        total = 0.0
        for w in self.workers:
            total += w.reset_caches(self.master_topic_map,
                                    self.cfg.n_business_keys)
        return total

    def step(self, max_records_per_partition: Optional[int] = None) -> int:
        """One streaming micro-batch across all workers: pump master topics
        into caches, then transform operational partitions."""
        done = 0
        for w in self.workers:
            w.pump_master(self.master_topic_map["equipment"], w.equipment)
            w.pump_master(self.master_topic_map["quality"], w.quality)
        for w in self.workers:
            for topic in self.operational_topics:
                done += w.process_operational(topic,
                                              max_records_per_partition)
        return done

    def run_to_completion(self, max_steps: int = 1000) -> int:
        total = 0
        stalls = 0
        for _ in range(max_steps):
            n = self.step()
            total += n
            buffered = sum(len(w.buffer) for w in self.workers)
            if n == 0 and buffered == 0:
                break
            # stall: buffered records whose master data never arrives keep
            # waiting on the watermark (paper semantics); don't spin
            stalls = stalls + 1 if n == 0 else 0
            if stalls >= 3:
                break
        return total

    # ------------------------------------------------------ fault tolerance
    def _rebalance_and_transfer(self, prior_workers) -> float:
        """Reassign partitions across the current worker set; every
        partition whose owner changed transfers its committed offset to the
        new owner's consumer group (exactly-once handoff) and the new owner
        fires the cache-reset trigger (paper §3.2). Returns re-dump secs."""
        old_owner = {p: w for p, w in self.assignment.assignment.items()}
        old_groups = {w.name: w.group for w in prior_workers}
        self.assignment.rebalance([w.name for w in self.workers])
        self._apply_assignment()
        for topic in self.operational_topics:
            for p, new_name in self.assignment.assignment.items():
                old_name = old_owner.get(p)
                if old_name is None or old_name == new_name:
                    continue
                old_group = old_groups.get(old_name)
                if old_group is None:
                    continue
                new_w = next(w for w in self.workers if w.name == new_name)
                committed = self.queue.committed(old_group, topic, p)
                own = self.queue.committed(new_w.group, topic, p)
                if committed > own:
                    self.queue.commit(new_w.group, topic, p, committed - own)
        redump = 0.0
        for w in self.workers:
            redump += w.reset_caches(self.master_topic_map,
                                     self.cfg.n_business_keys)
        return redump

    def fail_workers(self, names: List[str]) -> float:
        """Kill workers; coordinator reassigns; survivors adopt offsets and
        the failed workers' late buffers (replicated store)."""
        prior = list(self.workers)
        dead = [w for w in self.workers if w.name in names]
        self.workers = [w for w in self.workers if w.name not in names]
        if not self.workers:
            raise RuntimeError("all workers failed")
        redump = self._rebalance_and_transfer(prior)
        for d in dead:
            self.workers[0].buffer.push(d.buffer.drain())
        return redump

    def add_workers(self, n: int, join_depth: int = 1) -> float:
        """Elastic scale-up: new Stream Processor nodes join, partitions
        rebalance, caches re-dump filtered by the new key sets."""
        prior = list(self.workers)
        start = len(self.workers)
        for i in range(n):
            self.workers.append(StreamProcessorWorker(
                f"w{start + i}", self.cfg, self.queue, self.warehouse,
                join_depth, backend=self.backend))
        return self._rebalance_and_transfer(prior)

    def checkpoint(self) -> Dict:
        return {
            "offsets": self.queue.export_offsets(),
            "buffers": {w.name: w.buffer.export_state()
                        for w in self.workers},
            "listener_offsets": {l.table.name: l.offset
                                 for l in self.tracker.listeners},
        }

    def restore(self, state: Dict) -> None:
        self.queue.restore_offsets(state["offsets"])
        for w in self.workers:
            if w.name in state["buffers"]:
                w.buffer = OperationalMessageBuffer.restore(
                    state["buffers"][w.name], self.cfg.buffer_capacity)
                w.transformer.buffer = w.buffer
        for l in self.tracker.listeners:
            if l.table.name in state["listener_offsets"]:
                l.offset = state["listener_offsets"][l.table.name]
