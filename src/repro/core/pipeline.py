"""DOD-ETL pipeline orchestration (paper Fig. 2).

Wires Change Tracker -> Message Queue -> Stream Processor (In-memory Table
Updater + Data Transformer + Target Database Updater) for one worker set,
with the paper's fault-tolerance semantics: restartable consumption
(committed offsets), compacted-snapshot cache recovery, replicated late
buffer, and the cache-reset trigger on partition reassignment.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.dod_etl import ETLConfig
from repro.core.backend import get_backend
from repro.core.buffer import DeadLetterBuffer, OperationalMessageBuffer
from repro.core.cache import InMemoryTable
from repro.core.cdc import SourceDatabase
from repro.core.listener import ChangeTracker
from repro.core.message_queue import MessageQueue
from repro.core.loader import StarSchemaWarehouse
from repro.core.partitioning import (PartitionAssignment, RoutingTable,
                                     get_strategy, isin_sorted, partition_of)
from repro.core.records import RecordBatch
from repro.core.transformer import DataTransformer
from repro.durability.faults import (COMMIT_POST, INGEST_FETCH,
                                     LOAD_PRE_COMMIT, NULL_INJECTOR,
                                     REPARTITION_MID, TRANSFORM_DONE)
from repro.observability.health import build_pipeline_health
from repro.observability.registry import MetricsRegistry, MetricsShard
from repro.observability.tracer import NULL_TRACER


@dataclasses.dataclass
class StageMetrics:
    records: int = 0
    wall_s: float = 0.0

    @property
    def rate(self) -> float:
        return self.records / self.wall_s if self.wall_s > 0 else 0.0


@dataclasses.dataclass
class CacheMigrationStats:
    """One surgical cache migration: what survived, what moved, what the
    (now gained-keys-only) snapshot dump cost."""

    retained_rows: int = 0       # master rows kept across the migration
    dropped_rows: int = 0        # rows of moved-away key ranges
    gained_rows: int = 0         # rows dumped for newly owned keys
    dump_s: float = 0.0
    prev_keys: int = 0
    new_keys: int = 0
    gained_keys: int = 0

    @property
    def retention(self) -> float:
        """Fraction of pre-migration cache rows retained (1.0 when the
        cache was empty — nothing to lose)."""
        total = self.retained_rows + self.dropped_rows
        return self.retained_rows / total if total else 1.0

    def merge(self, other: "CacheMigrationStats") -> "CacheMigrationStats":
        return CacheMigrationStats(
            self.retained_rows + other.retained_rows,
            self.dropped_rows + other.dropped_rows,
            self.gained_rows + other.gained_rows,
            self.dump_s + other.dump_s,
            self.prev_keys + other.prev_keys,
            self.new_keys + other.new_keys,
            self.gained_keys + other.gained_keys)


def migration_summary(epoch: int, moved_key_fraction: float,
                      stats: CacheMigrationStats,
                      initial_rows: int) -> Dict[str, float]:
    """One migration's user-facing stats dict, shared by the sequential
    and concurrent coordinators. ``cache_retention`` is computed against
    the PRE-migration row count: a multi-phase migration (reroute, then
    ownership rebalance) runs ``migrate_caches`` more than once per
    worker, and summing the per-phase ``retained_rows`` would count every
    surviving row once per phase — only the drops are additive."""
    retained = max(initial_rows - stats.dropped_rows, 0)
    retention = retained / initial_rows if initial_rows else 1.0
    return {"epoch": epoch,
            "moved_key_fraction": round(moved_key_fraction, 4),
            "cache_retention": round(retention, 4),
            "retained_rows": retained,
            "dropped_rows": stats.dropped_rows,
            "gained_rows": stats.gained_rows,
            "dump_s": round(stats.dump_s, 6)}


class StreamProcessorWorker:
    """One Stream Processor node: assigned business-key partitions, local
    in-memory caches (filtered by assigned keys), transformer + loader.

    Hot path is COALESCED: every step consumes all assigned partitions into
    one columnar batch and issues ONE device dispatch through the compute
    backend; facts split back per partition only at ``warehouse.load`` time.
    """

    def __init__(self, name: str, cfg: ETLConfig, queue: MessageQueue,
                 warehouse: StarSchemaWarehouse, join_depth: int = 1,
                 backend=None):
        self.name = name
        self.cfg = cfg
        self.queue = queue
        self.warehouse = warehouse
        self.backend = get_backend(backend or cfg.backend or None)
        self._partitions: List[int] = []
        self._bkeys_memo: Dict[int, tuple] = {}   # n_keys -> (sig, keys)
        # routing-epoch awareness: the pipeline points these at its
        # operational topics so the worker's business-key filter covers the
        # UNION of live routing epochs (records published under a draining
        # old epoch keep finding their master rows). None = legacy static.
        self._routing_topics: Optional[List[str]] = None
        self._pending_tables: tuple = ()   # tables acked but not yet switched
        self.equipment = InMemoryTable(cfg.cache_slots, cfg.cache_row_width,
                                       backend=self.backend)
        self.quality = InMemoryTable(cfg.cache_slots, cfg.cache_row_width,
                                     backend=self.backend)
        self.buffer = OperationalMessageBuffer(cfg.buffer_capacity)
        # poison-record quarantine: records whose transform deterministically
        # raises are parked here (offsets committed) instead of crash-looping
        self.dead_letter = DeadLetterBuffer()
        # n_units wires the fused transform_and_rollup: every transform
        # dispatch also carries the per-unit KPI aggregate (equipment ids
        # ARE the business keys), feeding warehouse.kpi_running in O(1)
        self.transformer = DataTransformer(self.equipment, self.quality,
                                           self.buffer, join_depth,
                                           backend=self.backend,
                                           n_units=cfg.n_business_keys)
        self.metrics = StageMetrics()
        self.group = f"sp.{name}"
        # fault seams (tests): the pipeline points this at its injector;
        # the default never trips (one dict get per seam)
        self.fault = NULL_INJECTOR
        # observability seams, same pattern: the pipeline swaps in its
        # tracer/registry shard; the defaults are free-standing no-ops
        self.tracer = NULL_TRACER
        self.mshard: MetricsShard = MetricsShard(name)
        self._bind_instruments()

    def attach_metrics(self, shard: MetricsShard) -> None:
        """Point this worker's instruments at the pipeline registry's
        shard (one read path for cluster-wide totals)."""
        self.mshard = shard
        self._bind_instruments()

    def _bind_instruments(self) -> None:
        shard = self.mshard
        self._c_hits = shard.counter("worker.cache_hits")
        self._c_misses = shard.counter("worker.cache_misses")
        self._c_dead = shard.counter("worker.dead_lettered")
        shard.gauge_fn("buffer_occupancy", lambda: len(self.buffer))
        shard.gauge_fn("dead_letter_occupancy", lambda: len(self.dead_letter))
        shard.gauge_fn("cache_rows",
                       lambda: self.equipment.n_rows + self.quality.n_rows)

    # ----------------------------------------------------------- cache mgmt
    @property
    def partitions(self) -> tuple:
        # a copy: in-place mutation would bypass the setter and leave the
        # business-key memo stale
        return tuple(self._partitions)

    @partitions.setter
    def partitions(self, value) -> None:
        self._partitions = list(value)
        self._bkeys_memo.clear()     # reassignment invalidates the key memo

    def set_pending_tables(self, tables) -> None:
        """Routing tables the coordinator has announced but not yet
        switched publishers to (phase 1 of an epoch migration): the
        business-key filter covers them so the worker is ready before the
        first record routed by the new epoch exists."""
        self._pending_tables = tuple(tables)

    def _routing_sig(self):
        """Memo key: changes whenever a routing epoch advances/retires or
        a pending (acked-but-unswitched) table appears."""
        if self._routing_topics is None:
            return None
        return (tuple(self.queue.topics[t].routing_signature()
                      for t in self._routing_topics),
                tuple(t.epoch for t in self._pending_tables))

    def _live_tables(self) -> List[RoutingTable]:
        """Routing tables whose records this worker may still encounter:
        every operational topic's live epochs plus any pending table the
        coordinator has announced but not yet switched to (operational
        topics share one routing timeline, so tables dedupe by epoch)."""
        tables: Dict[int, RoutingTable] = {}
        for t in self._routing_topics:
            for tab in self.queue.topics[t].live_tables():
                tables[tab.epoch] = tab
        for tab in self._pending_tables:
            tables[tab.epoch] = tab
        return list(tables.values())

    def assigned_business_keys(self, n_business_keys: int) -> np.ndarray:
        """Sorted i64 array of this worker's business keys — every key any
        LIVE routing epoch maps into an owned partition — memoized until
        the partition assignment or a routing epoch changes (no per-pump
        set rebuilds)."""
        sig = self._routing_sig()
        entry = self._bkeys_memo.get(n_business_keys)
        if entry is not None and entry[0] == sig:
            return entry[1]
        keys = np.arange(n_business_keys, dtype=np.int64)
        if self._routing_topics is None:     # legacy static fallback
            parts = partition_of(keys, self.cfg.n_partitions)
            mask = np.isin(parts, np.asarray(self._partitions, np.int32))
        else:
            mask = np.zeros(n_business_keys, bool)
            owned = np.asarray(sorted(self._partitions), np.int64)
            for tab in self._live_tables():
                mask |= isin_sorted(owned,
                                    tab.partition_of(keys).astype(np.int64))
        memo = keys[mask]            # arange slice => already sorted
        self._bkeys_memo[n_business_keys] = (sig, memo)
        return memo

    def _filter_assigned(self, batch: RecordBatch) -> RecordBatch:
        """Vectorized business-key membership via binary search against the
        memoized sorted key array (replaces per-pump ``np.isin`` on a
        freshly rebuilt Python set)."""
        bkeys = self.assigned_business_keys(self.cfg.n_business_keys)
        if not len(bkeys):
            return RecordBatch.empty()
        return batch.filter(isin_sorted(bkeys, batch.business_key))

    def reset_caches(self, master_topics: Dict[str, str],
                     n_business_keys: int) -> float:
        """The paper's trigger: on (re)assignment, dump compacted snapshots
        filtered by assigned business keys. Returns dump seconds (Fig. 4)."""
        bkeys = self.assigned_business_keys(n_business_keys)
        total = 0.0
        for cache, topic_name in (
                (self.equipment, master_topics["equipment"]),
                (self.quality, master_topics["quality"])):
            topic = self.queue.topics[topic_name]
            rks, pls, tts = topic.snapshot(bkeys)
            # quality cache joins by prod_id (payload col 3); equipment by
            # business key (payload col 1)
            if cache is self.quality and len(rks):
                join_keys = pls[:, 3].astype(np.int64)
            elif len(rks):
                join_keys = pls[:, 1].astype(np.int64)
            else:
                join_keys = rks
            total += cache.reset_from_snapshot(join_keys, pls, tts)
        return total

    def migrate_caches(self, master_topics: Dict[str, str],
                       n_business_keys: int,
                       prev_bkeys: np.ndarray) -> CacheMigrationStats:
        """SURGICAL replacement for the reset-everything trigger: retain
        cached master rows for business keys still owned under any live
        routing epoch, drop only the moved-away ranges, and dump from the
        compacted master topics ONLY the keys gained since ``prev_bkeys``
        — so a survivor that merely gains (or loses) a slice of the key
        space keeps its cache warm instead of re-dumping the world (the
        post-rebalance throughput crater PR 2 measured)."""
        t0 = time.perf_counter()
        new_bkeys = self.assigned_business_keys(n_business_keys)
        gained = np.setdiff1d(new_bkeys, prev_bkeys)
        stats = CacheMigrationStats(prev_keys=len(prev_bkeys),
                                    new_keys=len(new_bkeys),
                                    gained_keys=len(gained))
        for cache, topic_name in (
                (self.equipment, master_topics["equipment"]),
                (self.quality, master_topics["quality"])):
            kept, dropped = cache.retain_only(new_bkeys)
            stats.retained_rows += kept
            stats.dropped_rows += dropped
            if len(gained):
                rks, pls, tts = self.queue.topics[topic_name].snapshot(gained)
                if len(rks):
                    if cache is self.quality:
                        join_keys = pls[:, 3].astype(np.int64)
                    else:
                        join_keys = pls[:, 1].astype(np.int64)
                    cache.upsert(join_keys, pls, tts)
                    stats.gained_rows += len(rks)
        stats.dump_s = time.perf_counter() - t0
        return stats

    # ----------------------------------------------------- master ingestion
    def pump_master(self, topic: str, cache: InMemoryTable,
                    max_records: Optional[int] = None) -> int:
        """In-memory Table Updater: consume ALL master partitions as one
        coalesced batch, filter by assigned business keys (vectorized),
        upsert into the local cache in one pass."""
        batch, counts = self.queue.consume_many(
            self.group, topic, self.partitions_for_master(topic), max_records)
        for p, c in counts.items():
            self.queue.commit(self.group, topic, p, c)
        if not len(batch):
            return 0
        mine = self._filter_assigned(batch)
        if not len(mine):
            return 0
        if cache is self.quality:
            join_keys = mine.payload[:, 3].astype(np.int64)
        else:
            join_keys = mine.payload[:, 1].astype(np.int64)
        cache.upsert(join_keys, mine.payload, mine.txn_time)
        return len(mine)

    def partitions_for_master(self, topic: str) -> List[int]:
        # master topics are row-key partitioned: a worker's business keys can
        # live in any partition, so every worker consumes all partitions and
        # filters (exactly the paper's design — the filter is the key step)
        return list(range(self.queue.topics[topic].cfg.n_partitions))

    # ------------------------------------------------------------ transform
    def fetch_operational(self, topic: str, max_records: Optional[int] = None
                          ) -> Tuple[RecordBatch, Dict[int, int]]:
        """Position-advancing coalesced read of this worker's partitions,
        WITHOUT committing (the concurrent runtime's ingest stage; commits
        happen after warehouse load in its load stage). Returns
        (batch, {partition: records_read})."""
        return self.queue.fetch_many(self.group, topic, self.partitions,
                                     max_records)

    def process_operational(self, topic: str, max_records: Optional[int] = None
                            ) -> int:
        """One micro-batch step over this worker's partitions: coalesced
        consume -> ONE fused transform+rollup dispatch (device-resident
        ``FactBlock``) -> materialize at the warehouse-load boundary ->
        split facts per partition at load time, folding the fused per-unit
        KPI rollup into the warehouse's running aggregate. ``max_records``
        still bounds each partition's read so offset/rebalance semantics
        are unchanged."""
        t0 = time.perf_counter()
        with self.tracer.span("ingest.fetch") as sp:
            batch, counts = self.queue.consume_many(
                self.group, topic, self.partitions, max_records)
            if not len(batch):
                sp.drop()                # keep idle polls out of the trace
        self.fault.trip(INGEST_FETCH)
        buffered0 = self.buffer.total_buffered
        with self.tracer.span("transform.dispatch") as sp:
            block, merged = self.transformer.process_block(batch)
            if block is None:
                sp.drop()
        if block is None:                # counts is empty on this path
            self.metrics.wall_s += time.perf_counter() - t0
            return 0
        self.fault.trip(TRANSFORM_DONE)
        block.start_host_copy()          # D2H rides behind the compute
        with self.tracer.span("load.commit") as sp:
            facts, _ = self.transformer.finish(block, merged)
            done = self.warehouse.load_partitioned(
                facts, self.cfg.n_partitions, rollup=block.rollup_host(),
                routing_epoch=self.queue.topics[topic].routing.epoch)
            self.fault.trip(LOAD_PRE_COMMIT)
            # commit AFTER the warehouse load (crash-consistency: a death
            # between load and commit re-serves the records, but recovery
            # rolls the warehouse back to its checkpoint first, so nothing
            # double-loads; committing first would LOSE records instead —
            # same order the concurrent runtime's load stage has always
            # used)
            for p, c in counts.items():
                self.queue.commit(self.group, topic, p, c)
            sp.put("records", done)
        self.fault.trip(COMMIT_POST)
        # join-level cache accounting: a loaded fact's probes all hit; a
        # record deferred to the late buffer missed its master rows
        self._c_hits.inc(done)
        self._c_misses.inc(self.buffer.total_buffered - buffered0)
        self.metrics.records += done
        self.metrics.wall_s += time.perf_counter() - t0
        return done


class DODETLPipeline:
    """Single-process pipeline over a worker set (the distributed runtime in
    ``repro.runtime`` schedules the same workers with failures/elasticity)."""

    def __init__(self, cfg: ETLConfig, source: SourceDatabase,
                 n_workers: int = 1, join_depth: int = 1, backend=None,
                 fault=None, tracer=None, metrics=None):
        self.cfg = cfg
        self.source = source
        self.backend = get_backend(backend or cfg.backend or None)
        # deterministic fault injection (tests): shared by every worker and
        # the repartition coordinator; the default injector never trips
        self.fault = fault or NULL_INJECTOR
        # observability plane: one registry per pipeline (workers, broker
        # topics and the coordinator all shard off it) and one tracer
        # shared by every stage seam — both default to free no-ops
        self.tracer = tracer or NULL_TRACER
        self.metrics = metrics or MetricsRegistry()
        self._coord_shard = self.metrics.shard("coordinator")
        self._c_repartitions = self._coord_shard.counter(
            "pipeline.repartitions")
        self._c_rebalances = self._coord_shard.counter("pipeline.rebalances")
        self._coord_shard.gauge_fn(
            "routing_epoch", lambda: self.current_routing().epoch)
        self.queue = MessageQueue(metrics=self.metrics)
        self.tracker = ChangeTracker(cfg, source.log, self.queue)
        self.warehouse = StarSchemaWarehouse(backend=self.backend)
        self.operational_topics = [self.tracker.topic_of(t.name)
                                   for t in cfg.operational_tables]
        self.master_topic_map = self._master_topics()
        # pluggable partitioning: operational topics share ONE routing
        # timeline produced by the configured strategy ("static" keeps the
        # exact legacy hash%n behavior at epoch 0)
        self.strategy = get_strategy(cfg.partition_strategy)
        table = self.strategy.initial_table(cfg.n_partitions)
        for t in self.operational_topics:
            self.queue.topics[t].set_routing(table)
        self.workers = [self._new_worker(f"w{i}", join_depth)
                        for i in range(n_workers)]
        self.assignment = PartitionAssignment(
            cfg.n_partitions, [w.name for w in self.workers])
        self._apply_assignment()

    def _new_worker(self, name: str,
                    join_depth: int = 1) -> StreamProcessorWorker:
        w = StreamProcessorWorker(name, self.cfg, self.queue, self.warehouse,
                                  join_depth, backend=self.backend)
        w._routing_topics = self.operational_topics
        w.fault = self.fault
        w.tracer = self.tracer
        w.attach_metrics(self.metrics.shard(name))
        return w

    def _master_topics(self) -> Dict[str, str]:
        """Logical master role -> topic. The simple schema has 'equipment'
        and 'quality'; the ISA-95 complex schema maps its first two master
        tables onto those roles (extra tables exercise join_depth)."""
        masters = [t for t in self.cfg.tables if t.nature == "master"]
        eq = next((t for t in masters if "equipment" in t.name), masters[0])
        qu = next((t for t in masters if "quality" in t.name), masters[-1])
        return {"equipment": self.tracker.topic_of(eq.name),
                "quality": self.tracker.topic_of(qu.name)}

    def _apply_assignment(self):
        for w in self.workers:
            w.partitions = self.assignment.partitions_of(w.name)

    # ------------------------------------------------------------- running
    def extract(self, limit_per_table: Optional[int] = None) -> int:
        return self.tracker.poll_all(limit_per_table)

    def bootstrap_caches(self) -> float:
        """Initial snapshot dump for every worker (Fig. 4 overhead)."""
        total = 0.0
        for w in self.workers:
            total += w.reset_caches(self.master_topic_map,
                                    self.cfg.n_business_keys)
        return total

    def step(self, max_records_per_partition: Optional[int] = None) -> int:
        """One streaming micro-batch across all workers: pump master topics
        into caches, then transform operational partitions."""
        done = 0
        for w in self.workers:
            w.pump_master(self.master_topic_map["equipment"], w.equipment)
            w.pump_master(self.master_topic_map["quality"], w.quality)
        for w in self.workers:
            for topic in self.operational_topics:
                done += w.process_operational(topic,
                                              max_records_per_partition)
        return done

    def run_to_completion(self, max_steps: int = 1000) -> int:
        total = 0
        stalls = 0
        for _ in range(max_steps):
            n = self.step()
            total += n
            buffered = sum(len(w.buffer) for w in self.workers)
            if n == 0 and buffered == 0:
                break
            # stall: buffered records whose master data never arrives keep
            # waiting on the watermark (paper semantics); don't spin
            stalls = stalls + 1 if n == 0 else 0
            if stalls >= 3:
                break
        return total

    # ---------------------------------------------------- routing epochs
    def current_routing(self) -> RoutingTable:
        """The operational topics' shared routing table (current epoch)."""
        return self.queue.topics[self.operational_topics[0]].routing

    def _committed_by_partition(self, topic: str) -> Dict[int, int]:
        group_of = {w.name: w.group for w in self.workers}
        out: Dict[int, int] = {}
        for p, owner in self.assignment.assignment.items():
            g = group_of.get(owner)
            out[p] = self.queue.committed(g, topic, p) if g else 0
        return out

    def retire_routing(self) -> bool:
        """Drop routing epochs whose records are fully committed; when any
        retire, buffered late records are re-homed so none starves at a
        worker about to release the retired epoch's key ranges."""
        retired = False
        for t in self.operational_topics:
            retired |= self.queue.topics[t].retire_epochs(
                self._committed_by_partition(t))
        if retired:
            self._rehome_buffers()
        return retired

    def _rehome_buffers(self) -> None:
        """Re-home every buffered late record to its business key's owner
        under the CURRENT routing epoch (replicated-store semantics)."""
        merged = RecordBatch.concat([w.buffer.drain() for w in self.workers])
        if not len(merged):
            return
        parts = self.current_routing().partition_of(
            merged.business_key).astype(np.int64)
        owner_of = self.assignment.assignment
        for w in self.workers:
            owned = np.asarray(sorted(
                p for p, o in owner_of.items() if o == w.name), np.int64)
            w.buffer.push(merged.filter(isin_sorted(owned, parts)))

    def backlog_weights(self) -> np.ndarray:
        """Per-partition UNDRAINED record counts (high watermark minus the
        owner's committed offset, summed over operational topics). The
        backlog sits wherever its publication epoch routed it — a
        load-aware reassignment must weigh it in, or the old hot
        partitions' drain work lands on one worker."""
        w = np.zeros(self.assignment.n_partitions)
        for t in self.operational_topics:
            committed = self._committed_by_partition(t)
            parts = self.queue.topics[t].partitions
            for p in range(min(len(parts), len(w))):
                w[p] += max(0, parts[p].length - committed.get(p, 0))
        return w

    def observed_loads(self):
        """(per-partition publish counts, business keys, per-key counts)
        aggregated over the operational topics — the skew strategy's
        input, straight from the broker's publish counters."""
        part_loads = np.zeros(self.assignment.n_partitions, np.int64)
        key_tot: Dict[int, int] = {}
        for t in self.operational_topics:
            pl, ks, cs = self.queue.topics[t].load_stats()
            part_loads[:len(pl)] += pl
            for k, c in zip(ks.tolist(), cs.tolist()):
                key_tot[k] = key_tot.get(k, 0) + c
        keys = np.fromiter(key_tot.keys(), np.int64, len(key_tot))
        counts = np.fromiter(key_tot.values(), np.int64, len(key_tot))
        return part_loads, keys, counts

    def repartition(self) -> Dict[str, float]:
        """Adaptive repartition (sequential runtime): observe load → new
        routing epoch from the strategy → workers pre-migrate caches
        surgically for the superset of live epochs → topics switch →
        load-aware sticky partition reassignment with exactly-once offset
        transfer → buffers re-homed. Returns migration stats."""
        self.retire_routing()
        initial_rows = sum(w.equipment.n_rows + w.quality.n_rows
                           for w in self.workers)
        part_loads, keys, counts = self.observed_loads()
        cur = self.current_routing()
        new_table = self.strategy.rebalanced_table(cur, part_loads,
                                                   (keys, counts))
        stats = CacheMigrationStats()
        moved = 0.0
        if new_table.epoch != cur.epoch:
            # phase 1: workers prepare — their key filter grows to the
            # union of live + pending epochs and caches migrate surgically
            with self.tracer.span("repartition.prepare"):
                for w in self.workers:
                    prev = w.assigned_business_keys(self.cfg.n_business_keys)
                    w.set_pending_tables((new_table,))
                    stats = stats.merge(w.migrate_caches(
                        self.master_topic_map, self.cfg.n_business_keys,
                        prev))
            # phase 2: atomically switch the publish epoch
            with self.tracer.span("repartition.epoch_switch"):
                for t in self.operational_topics:
                    self.queue.topics[t].set_routing(new_table)
                for w in self.workers:
                    w.set_pending_tables(())
            # sharded serving plane: shard ownership follows the routing
            # epoch — only moved segments/warehouse chunks migrate (the
            # mesh twin of the workers' surgical cache migration)
            srv = self.warehouse._serving
            if srv is not None and hasattr(srv, "reown"):
                with self.tracer.span("repartition.shard_reown"):
                    srv.reown(new_table)
                    self.warehouse.reown_shards(srv.ownership)
            # mid-repartition crash seam: new epoch published, ownership
            # not yet rebalanced — the hardest recovery window (a restart
            # must resume with the new epoch live AND re-run the rebalance)
            self.fault.trip(REPARTITION_MID)
            moved = cur.moved_fraction(
                new_table, np.arange(self.cfg.n_business_keys))
        # phase 3: rebalance partition ownership, transferring offsets
        # exactly-once. Weight = undrained backlog (sitting wherever its
        # publication epoch routed it) + expected future arrivals (the
        # observed key rates mapped through the NEW table)
        weights = self.backlog_weights()
        if len(keys):
            np.add.at(weights,
                      self.current_routing().partition_of(keys), counts)
        with self.tracer.span("repartition.rebalance"):
            stats = stats.merge(self._rebalance_and_transfer(
                list(self.workers), weights=weights, surgical=True))
            self._rehome_buffers()
        self._c_repartitions.inc()
        return migration_summary(self.current_routing().epoch, moved,
                                 stats, initial_rows)

    # ------------------------------------------------------ fault tolerance
    def _rebalance_and_transfer(self, prior_workers, weights=None,
                                surgical: bool = False) -> CacheMigrationStats:
        """Reassign partitions across the current worker set; every
        partition whose owner changed transfers its committed offset to the
        new owner's consumer group (exactly-once handoff) and the new owner
        fires the cache-migration trigger (paper §3.2): the legacy full
        snapshot re-dump by default, the surgical retain+gained-only dump
        when ``surgical``. Returns aggregated migration stats (``dump_s``
        is the Fig. 4 re-dump cost)."""
        nbk = self.cfg.n_business_keys
        prev_bkeys = {w.name: w.assigned_business_keys(nbk)
                      for w in self.workers} if surgical else {}
        old_owner = {p: w for p, w in self.assignment.assignment.items()}
        old_groups = {w.name: w.group for w in prior_workers}
        self.assignment.rebalance([w.name for w in self.workers], weights)
        self._apply_assignment()
        self._c_rebalances.inc()
        for topic in self.operational_topics:
            for p, new_name in self.assignment.assignment.items():
                old_name = old_owner.get(p)
                if old_name is None or old_name == new_name:
                    continue
                old_group = old_groups.get(old_name)
                if old_group is None:
                    continue
                new_w = next(w for w in self.workers if w.name == new_name)
                committed = self.queue.committed(old_group, topic, p)
                own = self.queue.committed(new_w.group, topic, p)
                if committed > own:
                    self.queue.commit(new_w.group, topic, p, committed - own)
        stats = CacheMigrationStats()
        for w in self.workers:
            if surgical:
                stats = stats.merge(w.migrate_caches(
                    self.master_topic_map, nbk,
                    prev_bkeys.get(w.name, np.zeros(0, np.int64))))
            else:
                stats = stats.merge(CacheMigrationStats(
                    dump_s=w.reset_caches(self.master_topic_map, nbk)))
        return stats

    def fail_workers(self, names: List[str]) -> float:
        """Kill workers; coordinator reassigns; survivors adopt offsets and
        the failed workers' late buffers (replicated store)."""
        prior = list(self.workers)
        dead = [w for w in self.workers if w.name in names]
        self.workers = [w for w in self.workers if w.name not in names]
        if not self.workers:
            raise RuntimeError("all workers failed")
        redump = self._rebalance_and_transfer(prior).dump_s
        for d in dead:
            self.workers[0].buffer.push(d.buffer.drain())
        return redump

    def add_workers(self, n: int, join_depth: int = 1) -> float:
        """Elastic scale-up: new Stream Processor nodes join, partitions
        rebalance, caches re-dump filtered by the new key sets."""
        prior = list(self.workers)
        start = len(self.workers)
        for i in range(n):
            self.workers.append(self._new_worker(f"w{start + i}", join_depth))
        return self._rebalance_and_transfer(prior).dump_s

    # -------------------------------------------------------- observability
    def health(self) -> Dict:
        """One structured health snapshot (see
        ``repro.observability.health`` for the schema): per-worker
        throughput and cache state, commit lag per topic/partition,
        routing epoch, and the registry's merged counters."""
        return build_pipeline_health(self)

    def checkpoint(self) -> Dict:
        return {
            "offsets": self.queue.export_offsets(),
            "buffers": {w.name: w.buffer.export_state()
                        for w in self.workers},
            "listener_offsets": {l.table.name: l.offset
                                 for l in self.tracker.listeners},
        }

    def restore(self, state: Dict) -> None:
        self.queue.restore_offsets(state["offsets"])
        for w in self.workers:
            if w.name in state["buffers"]:
                w.buffer = OperationalMessageBuffer.restore(
                    state["buffers"][w.name], self.cfg.buffer_capacity)
                w.transformer.buffer = w.buffer
        for l in self.tracker.listeners:
            if l.table.name in state["listener_offsets"]:
                l.offset = state["listener_offsets"][l.table.name]
