"""Record model shared by every DOD-ETL stage.

A *record batch* is a struct-of-arrays (host numpy; device jnp inside the
Stream Processor): integer identity/ordering fields plus a fixed-width f32
payload — the TPU-native stand-in for a database row. Fixed widths keep
every stage jit-compatible.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

PAYLOAD_WIDTH = 8

# op codes
OP_INSERT, OP_UPDATE, OP_DELETE = 0, 1, 2


@dataclasses.dataclass
class RecordBatch:
    """Columnar batch of change records (host side)."""

    table_id: np.ndarray       # i32 [n]
    op: np.ndarray             # i32 [n]
    row_key: np.ndarray        # i64 [n]   unique row identifier
    business_key: np.ndarray   # i64 [n]   domain partition key
    txn_time: np.ndarray       # i64 [n]   transaction timestamp (ns ticks)
    lsn: np.ndarray            # i64 [n]   log sequence number
    payload: np.ndarray        # f32 [n, PAYLOAD_WIDTH]

    def __post_init__(self):
        n = len(self.row_key)
        assert all(len(a) == n for a in
                   (self.table_id, self.op, self.business_key,
                    self.txn_time, self.lsn, self.payload)), "ragged batch"

    def __len__(self) -> int:
        return len(self.row_key)

    @staticmethod
    def empty() -> "RecordBatch":
        z = np.zeros(0, np.int64)
        return RecordBatch(z.astype(np.int32), z.astype(np.int32), z, z, z, z,
                           np.zeros((0, PAYLOAD_WIDTH), np.float32))

    @staticmethod
    def concat(batches) -> "RecordBatch":
        batches = [b for b in batches if len(b)]
        if not batches:
            return RecordBatch.empty()
        return RecordBatch(
            *(np.concatenate([getattr(b, f.name) for b in batches])
              for f in dataclasses.fields(RecordBatch)))

    def take(self, idx: np.ndarray) -> "RecordBatch":
        return RecordBatch(
            *(getattr(self, f.name)[idx]
              for f in dataclasses.fields(RecordBatch)))

    def slice(self, lo: int, hi: int) -> "RecordBatch":
        """Zero-copy contiguous row range (column views). The broker's read
        path slices frozen batches, so sharing the storage is safe."""
        return RecordBatch(
            *(getattr(self, f.name)[lo:hi]
              for f in dataclasses.fields(RecordBatch)))

    def filter(self, mask: np.ndarray) -> "RecordBatch":
        return self.take(np.nonzero(mask)[0])

    def sort_by_lsn(self) -> "RecordBatch":
        return self.take(np.argsort(self.lsn, kind="stable"))

    def split_by_partition(self, n_partitions: int,
                           key: str = "business_key", router=None
                           ) -> List[Tuple[int, "RecordBatch"]]:
        """Bucket rows by hash partition with ONE stable gather; the
        per-partition batches are zero-copy slices of the reordered columns.
        Returns [(partition, batch)] for non-empty partitions only.
        ``router`` (a ``partitioning.RoutingTable``) buckets by that
        routing epoch instead of the static hash."""
        from repro.core.partitioning import partition_bounds
        if not len(self):
            return []
        order, bounds = partition_bounds(getattr(self, key), n_partitions,
                                         router)
        cols = [getattr(self, f.name)[order]
                for f in dataclasses.fields(RecordBatch)]
        return [(p, RecordBatch(*(c[bounds[p]:bounds[p + 1]] for c in cols)))
                for p in range(n_partitions) if bounds[p + 1] > bounds[p]]

    def freeze(self) -> "RecordBatch":
        """Mark every column read-only. Published batches are shared across
        worker threads (the broker hands out views, not copies), so freezing
        at publish time turns a CONSUMER's accidental mutation into an
        immediate ``ValueError`` instead of a data race. (Guard is
        consumer-side only: a producer still holding the base arrays of a
        view column could mutate through them.)"""
        for f in dataclasses.fields(RecordBatch):
            getattr(self, f.name).flags.writeable = False
        return self

    def as_dict(self) -> Dict[str, np.ndarray]:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(RecordBatch)}


def make_batch(table_id: int, op: int, row_key, business_key, txn_time,
               payload, lsn_start: int = 0) -> RecordBatch:
    n = len(row_key)
    if n == 0:
        return RecordBatch.empty()
    return RecordBatch(
        table_id=np.full(n, table_id, np.int32),
        op=np.full(n, op, np.int32),
        row_key=np.asarray(row_key, np.int64),
        business_key=np.asarray(business_key, np.int64),
        txn_time=np.asarray(txn_time, np.int64),
        lsn=np.arange(lsn_start, lsn_start + n, dtype=np.int64),
        payload=np.asarray(payload, np.float32).reshape(n, -1),
    )
